"""Online serving load generator: Poisson arrivals against the HTTP/SSE
gateway, measuring what the offline trace replay cannot — TTFT at the
first SSE frame (not at request completion), per-output-token latency
(TPOT) from inter-frame gaps, and queue wait under admission control.

By default the benchmark boots an in-process gateway (smoke config,
ephemeral port) and drives it over real sockets; ``--target URL`` points
the client at an externally launched ``python -m repro.launch.serve
--http`` instead. Client-side percentiles plus the server's own
``/metrics`` queue-wait land in ``BENCH_serving.json`` (merged into the
offline serving numbers, ``gateway_*`` keys).

``--smoke`` is the CI leg: a short trace, then hard assertions that SSE
frames arrived *incrementally* (a stream that buffers until completion
has first-frame == last-frame time), that sampled streams are
seed-reproducible, that a mid-stream disconnect frees its KV pages, that
``GET /metrics`` is valid Prometheus exposition text carrying the
serving counters and latency histograms (``/metrics.json`` stays the
JSON twin), that ``GET /health`` reports the node's serving context, and
that shutdown is clean.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit_bench, record  # noqa: E402
from repro.serving.metrics import percentile  # noqa: E402


# ---------------------------------------------------------------------------
# minimal asyncio HTTP client (stdlib only, one connection per request)


async def _read_head(reader) -> Tuple[int, Dict[str, str]]:
    line = await reader.readline()
    status = int(line.split()[1])
    headers: Dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            return status, headers
        k, _, v = raw.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()


async def request_raw(host: str, port: int, method: str, path: str,
                      body: Optional[dict] = None
                      ) -> Tuple[int, Dict[str, str], bytes]:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        payload = json.dumps(body).encode() if body is not None else b""
        head = (f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(payload)}\r\n\r\n")
        writer.write(head.encode() + payload)
        await writer.drain()
        status, headers = await _read_head(reader)
        raw = await reader.read()
        return status, headers, raw
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def request_json(host: str, port: int, method: str, path: str,
                       body: Optional[dict] = None) -> Tuple[int, dict]:
    status, _, raw = await request_raw(host, port, method, path, body)
    return status, json.loads(raw) if raw else {}


class StreamResult:
    def __init__(self, rid: Optional[str] = None):
        self.rid = rid
        self.status: Optional[int] = None
        self.tokens: List = []
        self.frame_times: List[float] = []  # monotonic, per token frame
        self.finish_reason: Optional[str] = None
        self.t_submit = 0.0

    @property
    def ttft(self) -> Optional[float]:
        return (self.frame_times[0] - self.t_submit
                if self.frame_times else None)

    @property
    def tpot(self) -> Optional[float]:
        if len(self.frame_times) < 2:
            return None
        return ((self.frame_times[-1] - self.frame_times[0])
                / (len(self.frame_times) - 1))


async def stream_completion(host: str, port: int, body: dict, *,
                            cancel_after: Optional[int] = None
                            ) -> StreamResult:
    """POST a streaming completion and consume its SSE frames.

    ``cancel_after=n`` disconnects after the n-th token frame — the
    mid-flight cancellation path (the server must abort the request)."""
    from repro.server.sse import DONE, SSEParser

    res = StreamResult()
    res.t_submit = time.monotonic()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        payload = json.dumps({**body, "stream": True}).encode()
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload)
        await writer.drain()
        res.status, _ = await _read_head(reader)
        if res.status != 200:
            res.finish_reason = f"http_{res.status}"
            return res
        parser = SSEParser()
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return res
            for event in parser.feed(chunk):
                if event == DONE:
                    return res
                obj = json.loads(event)
                if res.rid is None:
                    res.rid = obj.get("id")
                choice = obj["choices"][0]
                toks = choice["delta"]["token_ids"]
                if toks:
                    res.tokens.extend(toks)
                    res.frame_times.append(time.monotonic())
                if choice["finish_reason"]:
                    res.finish_reason = choice["finish_reason"]
            if cancel_after is not None and len(res.tokens) >= cancel_after:
                res.finish_reason = "client_cancelled"
                return res  # close the socket mid-stream
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


# ---------------------------------------------------------------------------
# load generation


async def run_load(host: str, port: int, *, requests: int, rate: float,
                   prompt_len: int, gen_len: int, vocab: int, seed: int,
                   temperature: float) -> Tuple[List[StreamResult], float]:
    """Open-loop Poisson arrivals; every request is an SSE stream."""
    import numpy as np

    rng = np.random.default_rng(seed)
    t0 = time.monotonic()

    async def one(i: int, delay: float) -> StreamResult:
        await asyncio.sleep(delay)
        body = {"prompt": rng.integers(0, vocab, (prompt_len,)).tolist(),
                "max_tokens": gen_len, "temperature": temperature,
                "seed": int(rng.integers(0, 2**31)), "top_k": 50}
        return await stream_completion(host, port, body)

    delays, t = [], 0.0
    for _ in range(requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        delays.append(t)
    results = await asyncio.gather(
        *[one(i, d) for i, d in enumerate(delays)])
    return list(results), time.monotonic() - t0


def percentiles(results: List[StreamResult]) -> Dict[str, float]:
    ttfts = [r.ttft for r in results if r.ttft is not None]
    tpots = [r.tpot for r in results if r.tpot is not None]
    toks = sum(len(r.tokens) for r in results)
    return {
        "gateway_completed": float(
            sum(r.finish_reason in ("stop", "length", "capacity")
                for r in results)),
        "gateway_rejected": float(
            sum((r.finish_reason or "").startswith("http_")
                for r in results)),
        "gateway_tokens": float(toks),
        "gateway_ttft_p50_s": percentile(ttfts, 0.50),
        "gateway_ttft_p95_s": percentile(ttfts, 0.95),
        "gateway_tpot_p50_s": percentile(tpots, 0.50),
        "gateway_tpot_p95_s": percentile(tpots, 0.95),
    }


# ---------------------------------------------------------------------------
# in-process server (no --target)


def _boot(arch: str, smoke: bool, slots: int, max_len: int,
          page_size: Optional[int], max_queue: int):
    import jax

    from repro.configs import get_config, get_rules, get_smoke_config
    from repro.core.lns import LNSFormat
    from repro.core.quantizer import QuantConfig
    from repro.distributed.sharding import shard_ctx
    from repro.launch.mesh import make_host_mesh
    from repro.optim.madam import MadamConfig
    from repro.serving import Engine
    from repro.server.driver import EngineDriver
    from repro.training import init_train_state

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    mesh = make_host_mesh(data=jax.device_count())
    with shard_ctx(mesh, get_rules(arch)):
        params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    engine = Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                    max_len=max_len, page_size=page_size)
    driver = EngineDriver(engine, max_inflight=max_queue).start()
    return cfg, engine, driver


async def _amain(args) -> Dict[str, float]:
    driver = gateway = engine = None
    if args.target:
        host, _, port = args.target.rpartition("//")[-1].rpartition(":")
        host, port = host or "127.0.0.1", int(port)
        vocab = args.vocab
    else:
        from repro.server.app import Gateway
        cfg, engine, driver = _boot(args.arch, args.smoke, args.slots,
                                    args.max_len, args.page_size,
                                    args.max_queue)
        gateway = await Gateway(driver, port=0, model=cfg.name).start()
        host, port = gateway.address
        vocab = cfg.vocab_size
        print(f"in-process gateway on {host}:{port} "
              f"(arch={cfg.name} slots={args.slots} "
              f"page_size={args.page_size})")

    try:
        # warm the jit caches so percentiles measure serving, not compiles
        warm = await stream_completion(host, port, {
            "prompt": list(range(1, min(args.prompt_len, 8) + 1)),
            "max_tokens": 2})
        assert warm.status == 200, f"warmup failed: {warm.status}"

        results, wall = await run_load(
            host, port, requests=args.requests, rate=args.rate,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            vocab=vocab, seed=args.seed, temperature=args.temperature)
        out = percentiles(results)
        out["gateway_wall_s"] = wall
        out["gateway_offered_rps"] = args.rate

        # queue wait is a server-side number: admission timestamps live
        # in the engine clock, so read it off /metrics.json (the
        # machine-readable twin of the Prometheus /metrics text). The
        # gateway maps NaN percentiles (no completion yet) to JSON null
        # — coerce back to NaN so arithmetic and the print stay safe.
        status, stats = await request_json(host, port, "GET",
                                           "/metrics.json")
        assert status == 200, f"/metrics.json failed: {status}"
        for key in ("queued_p50_s", "queued_p95_s"):
            v = stats.get(key)
            out[f"gateway_{key}"] = float("nan") if v is None else float(v)

        if args.smoke:
            await _smoke_asserts(host, port, results, stats, engine)
        return out
    finally:
        if gateway is not None:
            await gateway.stop()
        if driver is not None:
            driver.shutdown()
            assert not driver.alive, "driver thread failed to stop"


async def _smoke_asserts(host, port, results, stats, engine) -> None:
    """CI-leg invariants (in-process server only for the page checks)."""
    # every stream finished and its frames arrived incrementally — a
    # gateway that buffers until completion collapses all frame times
    for r in results:
        assert r.finish_reason in ("stop", "length"), \
            f"stream ended with {r.finish_reason}"
        assert len(r.frame_times) >= 2, "stream produced < 2 token frames"
        assert r.frame_times[-1] > r.frame_times[0], \
            "SSE frames were not incremental (all arrived at once)"
    # sampled outputs are reproducible per seed
    body = {"prompt": [3, 1, 4, 1, 5], "max_tokens": 6,
            "temperature": 0.8, "top_k": 50, "seed": 1234}
    a = await stream_completion(host, port, body)
    b = await stream_completion(host, port, body)
    assert a.tokens == b.tokens and len(a.tokens) == 6, \
        f"seeded sampling not reproducible: {a.tokens} vs {b.tokens}"
    c = await stream_completion(host, port, {**body, "seed": 99})
    assert c.tokens != a.tokens, "distinct seeds produced identical output"
    # mid-stream disconnect aborts the request and frees its pages
    if engine is not None and engine.page_size:
        before = engine.allocator.available
        r = await stream_completion(
            host, port, {"prompt": [1, 2, 3, 4], "max_tokens": 64},
            cancel_after=2)
        assert r.finish_reason == "client_cancelled"
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if not engine.scheduler.running \
                    and engine.allocator.available >= before:
                break
            await asyncio.sleep(0.05)
        assert engine.allocator.available >= before, \
            "cancelled stream leaked KV pages"
    # Prometheus scrape: /metrics must be valid exposition text carrying
    # the serving counters and latency histograms a stock Prometheus
    # server would ingest (parse_prometheus_text enforces TYPE-before-
    # sample ordering, float values, and histogram completeness)
    from repro.obs import parse_prometheus_text

    status, headers, raw = await request_raw(host, port, "GET", "/metrics")
    assert status == 200, f"/metrics failed: {status}"
    ctype = headers.get("content-type", "")
    assert ctype.startswith("text/plain"), f"/metrics content-type {ctype}"
    metrics = parse_prometheus_text(raw.decode())
    for want in ("repro_build_info", "repro_completed_total",
                 "repro_ttft_seconds", "repro_tpot_seconds",
                 "repro_queue_wait_seconds"):
        assert want in metrics, f"/metrics missing series {want}"
    assert metrics["repro_completed_total"]["type"] == "counter"
    assert metrics["repro_ttft_seconds"]["type"] == "histogram"
    completed = [v for s, v in metrics["repro_completed_total"]["samples"]
                 if s["__name__"] == "repro_completed_total"]
    assert completed and completed[0] >= len(results), \
        f"completed_total {completed} below client count {len(results)}"
    ttft_count = [v for s, v in metrics["repro_ttft_seconds"]["samples"]
                  if s["__name__"] == "repro_ttft_seconds_count"]
    assert ttft_count and ttft_count[0] > 0, "ttft histogram is empty"
    # /health carries the readiness context operators page against
    status, health = await request_json(host, port, "GET", "/health")
    assert status == 200, f"/health failed: {status}"
    for want in ("status", "backend", "arch", "checkpoint_id",
                 "num_slots", "max_len", "max_inflight", "paged"):
        assert want in health, f"/health missing field {want!r}"
    assert health["status"] == "ok"
    if health["paged"]:  # smoke boots a paged engine
        assert health["alloc_policy"] in ("reserve", "ondemand")
        assert health["num_pages"] > 0
    print("gateway smoke asserts passed: incremental SSE, seeded "
          "reproducibility, cancellation frees pages, Prometheus "
          "/metrics + /health readiness")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke config + CI invariants")
    ap.add_argument("--target", default=None,
                    help="URL of an already-running gateway "
                         "(default: boot one in-process)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/s (0 = burst)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=512,
                    help="prompt id range when using --target")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.requests is None:
        args.requests = 6 if args.smoke else 16

    out = asyncio.run(_amain(args))

    # append into the offline serving trajectory: emit_bench merges these
    # gateway_* records into the same-sha entry benchmarks/serving.py
    # wrote earlier in the CI job, keeping its records intact
    emit_bench("serving", [
        record(k, v, unit="s" if k.endswith("_s") else "count")
        for k, v in out.items()
        if isinstance(v, (int, float)) and not isinstance(v, bool)])

    print("name,value,unit,derived")
    print(f"gateway_ttft_p50,{out['gateway_ttft_p50_s'] * 1e6:.1f},"
          f"us_per_call,p95={out['gateway_ttft_p95_s']:.3f}s")
    print(f"gateway_tpot_p50,{out['gateway_tpot_p50_s'] * 1e6:.1f},"
          f"us_per_call,p95={out['gateway_tpot_p95_s']:.3f}s")
    print(f"gateway_queued_p50,{out['gateway_queued_p50_s'] * 1e6:.1f},"
          f"us_per_call,p95={out['gateway_queued_p95_s']:.3f}s")
    print(f"gateway_wall,{out['gateway_wall_s'] * 1e6:.1f},us_per_call,"
          f"completed={int(out['gateway_completed'])}/"
          f"{args.requests} rejected={int(out['gateway_rejected'])} "
          f"tokens={int(out['gateway_tokens'])}")


if __name__ == "__main__":
    main()
