"""Tables 8 + Figs. 2/10 reproduction: per-iteration training energy.

Analytical model calibrated once on the Table-8 ResNet-50/LNS cell (see
core/energy.py); prints model-vs-paper for all 16 Table-8 cells, the GPT
1B..1T scaling sweep (Fig. 10), and extends the table to the ten assigned
architectures (per-iteration at train_4k token counts).
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row
from repro.configs import ARCHS, SHAPES, get_config
from repro.core import energy


def run() -> list[str]:
    rows = []
    t0 = time.monotonic()
    pred = energy.paper_table8()
    for model, want_row in energy.PAPER_TABLE8_MJ.items():
        for fmt, want in want_row.items():
            got = pred[model][fmt]
            rows.append(csv_row(
                f"table8_{model}_{fmt}", 0.0,
                f"model_mJ={got:.2f} paper_mJ={want:.2f} "
                f"ratio={got / want:.2f}"))

    for name, row in energy.gpt_scaling().items():
        rows.append(csv_row(
            f"fig10_{name}", 0.0,
            f"lns={row['lns8']:.1f}mJ fp8={row['fp8']:.1f}mJ "
            f"fp16={row['fp16']:.1f}mJ fp32={row['fp32']:.1f}mJ"))

    # beyond-paper: the assigned architectures (fwd MACs ≈ active params x
    # tokens; per-iteration at the train_4k shape)
    spec = SHAPES["train_4k"]
    tokens = spec.global_batch * spec.seq_len
    for arch in ARCHS:
        cfg = get_config(arch)
        macs = cfg.active_params_count() * tokens
        lns = energy.per_iteration_energy_mj(macs, "lns8")
        fp8 = energy.per_iteration_energy_mj(macs, "fp8")
        fp32 = energy.per_iteration_energy_mj(macs, "fp32")
        rows.append(csv_row(
            f"energy_{arch}", 0.0,
            f"lns={lns / 1e3:.2f}J fp8={fp8 / 1e3:.2f}J fp32={fp32 / 1e3:.2f}J"))
    us = (time.monotonic() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:  # backfill the shared per-row wall time
        r.value = us
    return rows
