"""Fig. 4 reproduction: weight-update quantization error r_t for GD vs
multiplicative rules over learning rate and base factor sweeps.

Also appends a *measured* per-layer trajectory to BENCH_quant_error.json:
a short instrumented tiny-LM run whose in-graph update-site counters
(DESIGN.md §14) report the realized Thm.-1 quantity ``qerr_rel`` per
layer — the synthetic Fig.-4 sweep above is the closed-form view, the
per-layer rows are the same quantity on a live training step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, record, train_tiny_lm_numerics
from repro.core import error_analysis as ea
from repro.core.quantizer import QuantConfig


def run(trials: int = 24, d: int = 2048) -> list[str]:
    key = jax.random.PRNGKey(0)
    # weights span decades of magnitude (real nets do); gradients at the
    # normalized ~3e-3 scale the paper's Fig. 4 operates in
    w = jnp.exp2(jax.random.normal(key, (d,)) * 2.0)
    g2 = jnp.full((d,), 0.003 ** 2)
    rows = []

    t0 = time.monotonic()
    # sweep learning rate at γ = 2^10 (paper App. §.2 setting)
    for eta in (2.0 ** -8, 2.0 ** -6, 2.0 ** -4):
        accum = {"gd": 0.0, "mul": 0.0, "signmul": 0.0, "madam": 0.0}
        for t in range(trials):
            g = jax.random.normal(jax.random.fold_in(key, t), (d,)) * 0.003
            out = ea.measure_all(jax.random.fold_in(key, 1000 + t), w, g,
                                 eta, 2.0 ** 10, g2)
            for k, v in out.items():
                accum[k] += float(v) / trials
        derived = " ".join(f"{k}={v:.3e}" for k, v in accum.items())
        rows.append(csv_row(f"fig4_eta_{eta:g}", 0.0, derived))

    # sweep base factor at η = 2^-6
    for gamma in (2.0 ** 6, 2.0 ** 10, 2.0 ** 14):
        accum = {"gd": 0.0, "mul": 0.0, "signmul": 0.0, "madam": 0.0}
        for t in range(trials):
            g = jax.random.normal(jax.random.fold_in(key, t), (d,)) * 0.003
            out = ea.measure_all(jax.random.fold_in(key, 2000 + t), w, g,
                                 2.0 ** -6, gamma, g2)
            for k, v in out.items():
                accum[k] += float(v) / trials
        derived = " ".join(f"{k}={v:.3e}" for k, v in accum.items())
        rows.append(csv_row(f"fig4_gamma_2^{int(np.log2(gamma))}", 0.0, derived))

    us = (time.monotonic() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:  # backfill the shared per-row wall time
        r.value = us

    # measured per-layer update error from a live instrumented run
    steps = max(4, min(trials, 12))
    _, per_layer = train_tiny_lm_numerics(QuantConfig.lns_madam(),
                                          steps=steps)
    for layer, stats in sorted(per_layer.items()):
        rows.append(record(
            f"layer_qerr_rel.{layer}", stats["qerr_rel"], unit="ratio",
            derived=f"gap_ratio={stats['qerr_gap_ratio']:.3f} "
                    f"sat_hi={stats['sat_hi']:.4f} over {steps} steps"))
    if per_layer:
        rows.append(record(
            "layer_qerr_rel_mean",
            sum(s["qerr_rel"] for s in per_layer.values()) / len(per_layer),
            unit="ratio", derived=f"{len(per_layer)} layers"))
    # headline check: multiplicative << GD at every setting
    return rows
