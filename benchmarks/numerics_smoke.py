"""Numerics-telemetry smoke: the CI leg for DESIGN.md §14.

Runs a few supervised smollm steps with the :class:`NumericsObserver`
attached and asserts the whole telemetry contract end to end:

* the instrumented train step returns the per-layer numerics aux tree
  (update-site + grad-encode-site stats for every LNS layer);
* the observer's Prometheus rendering round-trips through
  ``parse_prometheus_text`` and carries per-layer *labeled* gauge samples
  (``repro_numerics_update_sat_hi{layer="..."}``);
* the exported Chrome trace passes ``validate_train_trace`` — i.e.
  ``python -m repro.obs.validate <trace> --train`` would accept it —
  with every REQUIRED_TRAIN_COUNTERS track present;
* the jsonl step log parses line-per-step;
* the serving side exposes a numerics block (weight-tree code-rail
  occupancy + draft re-grid error) through ``Engine.numerics_snapshot``.

Exits nonzero on the first violated assertion; prints a one-line summary
per check so the CI log reads as a checklist.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.quantizer import QuantConfig
from repro.obs.numerics import (NumericsObserver, REQUIRED_TRAIN_COUNTERS,
                                validate_train_trace)
from repro.obs.prom import parse_prometheus_text
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM
from repro.training.loop import SupervisorConfig, run_supervised

STEPS = 4


def main() -> None:
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(lr=2.0 ** -7)

    with tempfile.TemporaryDirectory() as tmp:
        log_path = os.path.join(tmp, "steps.jsonl")
        obs = NumericsObserver(log_path=log_path, quiet=True)
        state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
        step = jax.jit(build_train_step(cfg, qcfg, mcfg, numerics=True))
        data = SyntheticLM(cfg, batch=2, seq=16, seed=0)
        ckpt = CheckpointManager(os.path.join(tmp, "ckpt"), keep=2)
        report = run_supervised(
            step, state, data, ckpt,
            SupervisorConfig(max_steps=STEPS, save_every=100),
            device_put_batch=lambda b: jax.tree.map(jnp.asarray, b),
            observer=obs)
        assert report.steps_done == STEPS
        assert obs.n_steps == STEPS
        print(f"[numerics-smoke] trained {STEPS} steps, observer saw "
              f"{obs.n_steps}")

        # ---- Prometheus round-trip with per-layer labels
        text = obs.prom_text()
        families = parse_prometheus_text(text)
        assert "repro_numerics_update_sat_hi" in families, \
            sorted(families)[:20]
        fam = families["repro_numerics_update_sat_hi"]
        labeled = [(lab, v) for lab, v in fam["samples"]
                   if lab.get("layer")]
        assert labeled, "per-layer labeled samples missing"
        layers = {lab["layer"] for lab, _ in labeled}
        assert len(layers) >= 2, layers
        for lab, v in labeled:
            assert 0.0 <= v <= 1.0, (lab, v)
        print(f"[numerics-smoke] prometheus ok: {len(families)} families, "
              f"{len(labeled)} per-layer saturation samples")

        # ---- Chrome trace export + the --train validator contract
        paths = obs.export(tmp, tag="smoke")
        with open(paths["trace"]) as f:
            doc = json.load(f)
        stats = validate_train_trace(doc)
        assert stats["steps"] == STEPS, stats
        for track in REQUIRED_TRAIN_COUNTERS:
            assert any(track in t for t in stats["tracks"]), \
                (track, stats["tracks"])
        print(f"[numerics-smoke] trace ok: {stats['counter_events']} "
              f"counter events over {len(stats['tracks'])} tracks")

        # ---- jsonl step log: one parseable row per step
        obs.close()
        with open(log_path) as f:
            lines = [json.loads(x) for x in f if x.strip()]
        assert len(lines) == STEPS
        assert all("numerics" in row and "loss" in row for row in lines)
        print(f"[numerics-smoke] jsonl ok: {len(lines)} rows")

    # ---- serving-side numerics block
    from repro.serving.engine import Engine
    eng = Engine(cfg, qcfg, mcfg, state.params, num_slots=2, max_len=32,
                 speculate_k=2, draft_bitwidth=6)
    eng._draft_params(6)
    snap = eng.numerics_snapshot()
    assert snap["weights"]["elements"] > 0
    assert 0.0 <= snap["weights"]["maxcode_frac"] <= 1.0
    dr = snap["draft_requant"]["b6"]
    assert dr["rel_err_mean"] >= 0.0 and dr["elements"] > 0
    print(f"[numerics-smoke] serving ok: b6 draft rel_err="
          f"{dr['rel_err_mean']:.4f} sat_hi={dr['sat_hi_frac']:.4f}")
    print("[numerics-smoke] all checks passed")


if __name__ == "__main__":
    main()
