"""Table 3 reproduction (trend): base-factor selection at B=8.

The paper trains ResNet-50/ImageNet; here the CPU-scale LM plays that role:
for each γ we train with forward+backward quantization at (8, γ) and report
the final loss. The paper's findings to reproduce: γ=1 diverges (NaN-level),
mid γ (4-16) works best, γ=32's narrow dynamic range degrades again.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row, train_tiny_lm
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig


def run(steps: int = 50) -> list[str]:
    rows = []
    for gamma in (1, 2, 4, 8, 16, 32):
        fmt = LNSFormat(bits=8, gamma=gamma)
        qcfg = QuantConfig(weight=fmt, act=fmt, err=fmt, grad=fmt,
                           update=fmt.with_bits(16))
        t0 = time.monotonic()
        losses = train_tiny_lm(qcfg, steps=steps)
        us = (time.monotonic() - t0) * 1e6 / steps
        final = sum(losses[-5:]) / 5
        rows.append(csv_row(
            f"table3_gamma_{gamma}", us,
            f"final_loss={final:.4f} range=(0,{fmt.dynamic_range:.3g})"))
    return rows
