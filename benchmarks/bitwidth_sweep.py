"""Table 6 reproduction (trend): activation-gradient bitwidth 4..8.

The paper varies the Q_E bitwidth against BHQ; BHQ's numbers are cited, our
side sweeps LNS-Madam. Claim: graceful degradation down to 5-bit, usable at
4-bit.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import csv_row, train_tiny_lm
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig


def run(steps: int = 50) -> list[str]:
    rows = []
    for bits in (4, 5, 6, 7, 8):
        err_fmt = LNSFormat(bits=bits, gamma=max(1, 8 >> (8 - bits)))
        qcfg = dataclasses.replace(QuantConfig.lns_madam(), err=err_fmt)
        t0 = time.monotonic()
        losses = train_tiny_lm(qcfg, steps=steps)
        us = (time.monotonic() - t0) * 1e6 / steps
        rows.append(csv_row(f"table6_egrad_{bits}bit", us,
                            f"final_loss={sum(losses[-5:]) / 5:.4f}"))
    return rows
