"""Perf-regression gate over the BENCH_*.json trajectories.

Two kinds of checks, run after the CI smoke benchmarks have appended the
current commit's entry:

* **Invariants** — machine-independent claims that must hold in the
  freshest entry itself, whatever hardware produced it. Today:
  ``paged_vs_dense_tok_ratio >= 1.0`` (the paged serving path must not be
  slower than dense on the same trace — the ISSUE-6 acceptance bar),
  ``spec_vs_paged_tok_ratio >= 1.3`` with ``spec_accept_rate_b8 >= 0.95``
  (self-speculative decoding must beat the one-token-per-launch paged
  engine, and the identity draft must accept essentially everything), and
  ``fwd_weight_bytes_ratio`` staying well under 1.0 (the dispatch path
  must never silently re-densify the weights).

* **Trends** — the freshest entry vs the last entry from a *different*
  commit. Deterministic counters (prefill token counts, byte ratios) get
  a tight tolerance; wall-clock-derived metrics (tok/s, speedups) get a
  wide one, because trajectory entries may come from different machines.
  Metrics whose healthy value sits near zero (``obs_overhead_pct``, the
  train step's ``numerics_overhead_pct`` and per-site saturation
  fractions) are tracked in absolute units instead — see ``TRACKED_ABS``.

Waiving: an intentional baseline change passes ``--waive`` (or puts
``[bench-baseline]`` in the HEAD commit message) — the gate then reports
trend failures but exits 0. Invariant failures are never waivable by the
marker alone; they need ``--waive`` explicitly.

Exit status: 0 green / waived, 1 regression, 2 missing trajectory data.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import read_bench  # noqa: E402

# relative drop tolerances per metric class
TOL_TIGHT = 0.01   # deterministic counters: must reproduce exactly-ish
TOL_RATIO = 0.25   # dimensionless speedups/ratios: jitter-tolerant
TOL_WALL = 0.50    # raw wall-clock rates: machines differ wildly

# (suite, metric name) -> (tolerance, higher_is_better)
TRACKED = {
    ("serving", "paged_vs_dense_tok_ratio"): (TOL_RATIO, True),
    ("serving", "engine_speedup_vs_lockstep"): (TOL_RATIO, True),
    ("serving", "dense_tok_s"): (TOL_WALL, True),
    ("serving", "paged_tok_s"): (TOL_WALL, True),
    ("serving", "spec_tok_s"): (TOL_WALL, True),
    ("serving", "spec_vs_paged_tok_ratio"): (TOL_RATIO, True),
    # accept rates are deterministic on the fixed bench trace (seeded
    # weights, greedy decode) — a drift means the accept rule or the
    # re-grid transform changed, so hold them tight
    ("serving", "spec_accept_rate_b6"): (TOL_TIGHT, True),
    ("serving", "spec_accept_rate_b7"): (TOL_TIGHT, True),
    ("serving", "spec_accept_rate_b8"): (TOL_TIGHT, True),
    ("serving", "prefix_tok_s"): (TOL_WALL, True),
    # mesh rows exist only when the bench ran with >= 4 devices (the CI
    # mesh-smoke leg); trend-tracked for GSPMD-overhead drift, with no
    # invariant until a real multi-chip baseline lands
    ("serving", "mesh_tok_s"): (TOL_WALL, True),
    ("serving", "mesh_vs_single_tok_ratio"): (TOL_RATIO, True),
    ("serving", "prefix_prefill_tokens"): (TOL_TIGHT, False),
    ("serving", "prefix_reused_tokens"): (TOL_TIGHT, True),
    ("train_step", "fwd_weight_bytes_ratio"): (TOL_TIGHT, False),
    ("train_step", "speedup"): (TOL_RATIO, True),
}

# trend metrics compared in *absolute* units, not relative change:
# (suite, name) -> (max_abs_worsening, higher_is_better). Used for
# metrics whose healthy value sits near zero — obs_overhead_pct is the
# percentage-point cost of running with the observability layer on, and
# a relative tolerance around ~0 would reject any nonzero jitter.
TRACKED_ABS = {
    ("serving", "obs_overhead_pct"): (5.0, False),
    # numerics telemetry in the train step: in-graph epilogue counters,
    # budgeted at 5 abs pts over the plain dispatch step (ISSUE-10 bar)
    ("train_step", "numerics_overhead_pct"): (5.0, False),
    # the saturation fraction itself is a health trend: the tiny-LM first
    # step is seeded/deterministic, so a jump past 5 abs pts means a clip
    # site started railing codes (format, scale, or update-rule change)
    ("train_step", "numerics_sat_hi_frac"): (0.05, False),
    ("train_step", "numerics_sat_lo_frac"): (0.05, False),
}

# invariants evaluated on the freshest entry alone:
# (suite, name) -> (min_allowed, max_allowed)
INVARIANTS = {
    ("serving", "paged_vs_dense_tok_ratio"): (1.0, None),
    # speculating must beat the same paged engine decoding one token per
    # launch (the ISSUE-7 acceptance bar: >= 1.3 on the bimodal trace)
    ("serving", "spec_vs_paged_tok_ratio"): (1.3, None),
    # the B=8 draft is the identity re-grid: every draft token must match
    # the target sample modulo the bonus-token slot, so accept stays ~1
    ("serving", "spec_accept_rate_b8"): (0.95, None),
    ("train_step", "fwd_weight_bytes_ratio"): (None, 0.9),
}


def _latest_two(doc) -> (Optional[Dict], Optional[Dict]):
    """(freshest entry, last entry from a different sha)."""
    traj = doc.get("trajectory", [])
    if not traj:
        return None, None
    head = traj[-1]
    for entry in reversed(traj[:-1]):
        if entry.get("sha") != head.get("sha"):
            return head, entry
    return head, None


def _values(entry) -> Dict[str, float]:
    return {r["name"]: r["value"] for r in entry.get("records", [])}


def _head_commit_waives(root: str) -> bool:
    try:
        out = subprocess.run(["git", "log", "-1", "--format=%B"], cwd=root,
                             capture_output=True, text=True, timeout=10)
        return out.returncode == 0 and "[bench-baseline]" in out.stdout
    except (OSError, subprocess.SubprocessError):
        return False


def check(root: Optional[str] = None, *, suites=("serving", "train_step"),
          waive: bool = False) -> int:
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    invariant_fails: List[str] = []
    trend_fails: List[str] = []
    missing: List[str] = []

    docs = {s: read_bench(s, root=root) for s in suites}
    for suite, doc in docs.items():
        head, prev = _latest_two(doc)
        if head is None:
            missing.append(suite)
            continue
        vals = _values(head)

        for (s, name), (lo, hi) in INVARIANTS.items():
            if s != suite or name not in vals:
                continue
            v = vals[name]
            if lo is not None and v < lo:
                invariant_fails.append(
                    f"{suite}:{name} = {v:.4f} < required {lo}")
            if hi is not None and v > hi:
                invariant_fails.append(
                    f"{suite}:{name} = {v:.4f} > allowed {hi}")

        if prev is None:
            print(f"[gate] {suite}: first trajectory entry "
                  f"({head.get('sha', '?')[:10]}) — trend check bootstraps")
            continue
        base = _values(prev)
        for (s, name), (tol, up) in TRACKED.items():
            if s != suite or name not in vals or name not in base:
                continue
            new, old = vals[name], base[name]
            if old == 0:
                continue
            # regression = the tracked direction got worse beyond tol
            change = (new - old) / abs(old)
            worse = -change if up else change
            if worse > tol:
                trend_fails.append(
                    f"{suite}:{name} {old:.4f} -> {new:.4f} "
                    f"({'-' if up else '+'}{worse * 100:.1f}% vs "
                    f"tol {tol * 100:.0f}%, "
                    f"baseline sha {prev.get('sha', '?')[:10]})")
            else:
                print(f"[gate] ok {suite}:{name} {old:.4f} -> {new:.4f}")
        for (s, name), (tol, up) in TRACKED_ABS.items():
            if s != suite or name not in vals or name not in base:
                continue
            new, old = vals[name], base[name]
            worse = (old - new) if up else (new - old)
            if worse > tol:
                trend_fails.append(
                    f"{suite}:{name} {old:.4f} -> {new:.4f} "
                    f"(worsened {worse:.2f} abs vs tol {tol:g}, "
                    f"baseline sha {prev.get('sha', '?')[:10]})")
            else:
                print(f"[gate] ok {suite}:{name} {old:.4f} -> {new:.4f} "
                      f"(abs)")

    if missing:
        print(f"[gate] no trajectory entries for: {', '.join(missing)} — "
              f"run `python benchmarks/run.py --smoke` first")
        return 2

    waived = waive or _head_commit_waives(root)
    status = 0
    if invariant_fails:
        print("[gate] INVARIANT FAILURES (the claim the repo commits to):")
        for f in invariant_fails:
            print(f"  {f}")
        status = 1
    if trend_fails:
        print("[gate] trend regressions vs committed trajectory:")
        for f in trend_fails:
            print(f"  {f}")
        if status == 0:
            status = 1
    if status and waived:
        if invariant_fails and not waive:
            print("[gate] [bench-baseline] marker does not waive "
                  "invariants — pass --waive explicitly")
            return 1
        print("[gate] regressions WAIVED (baseline update)")
        return 0
    if status == 0:
        print("[gate] green")
    return status


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="directory holding BENCH_*.json (default: repo root)")
    ap.add_argument("--suites", default="serving,train_step")
    ap.add_argument("--waive", action="store_true",
                    help="report regressions but exit 0 (baseline update)")
    args = ap.parse_args()
    sys.exit(check(args.root, suites=tuple(args.suites.split(",")),
                   waive=args.waive))


if __name__ == "__main__":
    main()
