"""Speculative-decode CI smoke: equivalence and page accounting, asserted.

The self-speculative engine's contract is that speculation is a pure
throughput optimization — never a behavior change. This leg drives the
smoke model through the paged ondemand engine twice (baseline and
speculating) over one mixed greedy/seeded trace and hard-asserts:

  * token-for-token equality per request (greedy AND seeded sampling:
    accepted drafts are the target's own samples, the sampler fold
    rewinds with the slot cursor),
  * the KV page pool refcounts back to the baseline engine's after the
    run (rollback trimmed every overshoot page) and back to *full* after
    a mid-flight abort,
  * spec counters actually moved (the run really speculated).

Exit 0 on success; any assertion failing the contract exits non-zero.
A summary record lands in ``BENCH_serving.json`` (``spec_smoke_*`` keys)
so the trajectory shows the leg ran.
"""
from __future__ import annotations

import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import csv_row, emit_bench, record  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.core.lns import LNSFormat  # noqa: E402
from repro.core.quantizer import QuantConfig  # noqa: E402
from repro.optim.madam import MadamConfig  # noqa: E402
from repro.serving import Engine, Request  # noqa: E402
from repro.server.sampling import SamplingParams  # noqa: E402
from repro.training import init_train_state  # noqa: E402


def _trace(vocab: int, n: int = 6, gen: int = 12):
    """Greedy and seeded-sampled rows interleaved, varied lengths."""
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(n):
        sp = None if i % 2 == 0 else SamplingParams(
            temperature=0.8, top_k=0 if i % 4 == 1 else 16, seed=40 + i)
        prompt = rng.integers(0, vocab, (5 + (i % 3) * 4,)).tolist()
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=gen - (i % 3), sampling=sp))
    return reqs


def run(k: int = 4, draft_bits: int = 7) -> list:
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    num_pages = 18
    kw = dict(num_slots=3, max_len=48, page_size=8, num_pages=num_pages,
              prefix_cache=False, alloc_policy="ondemand")
    reqs = _trace(cfg.vocab_size)

    base = Engine(cfg, qcfg, mcfg, params, **kw)
    base.run(reqs)
    want = {rs.request.rid: list(rs.generated) for rs in base.finished}

    spec = Engine(cfg, qcfg, mcfg, params, **kw,
                  speculate_k=k, draft_bitwidth=draft_bits)
    spec.run(reqs)
    got = {rs.request.rid: list(rs.generated) for rs in spec.finished}

    mismatched = [rid for rid in want if got.get(rid) != want[rid]]
    assert not mismatched, (
        f"spec engine diverged from baseline on rids {mismatched}: "
        f"speculation must be a pure perf optimization")
    assert spec.spec_cycles > 0 and spec.spec_drafted > 0, \
        "the spec engine never speculated — the smoke asserted nothing"
    assert spec.allocator.available == base.allocator.available, (
        f"page pool drifted: spec leaves {spec.allocator.available} free "
        f"vs baseline {base.allocator.available} — rollback leaked pages")
    accept = spec.spec_accept_rate
    cycles, trimmed = spec.spec_cycles, spec.spec_pages_trimmed

    # mid-flight abort: every page goes back, including lookahead pages
    # grown for draft tokens that will now never be verified
    spec.reset()
    for r in _trace(cfg.vocab_size, n=3, gen=24):
        spec.submit(r)
    for _ in range(4):  # prefill + a spec cycle or two
        spec.step()
    assert spec.allocator.available < num_pages, "abort smoke never admitted"
    for rid in range(3):
        spec.abort(rid)
    while spec.step():
        pass
    assert spec.allocator.available == num_pages, (
        f"abort leaked pages: {spec.allocator.available}/{num_pages} free")

    rows = [
        csv_row("spec_smoke", 0.0,
                f"requests={len(reqs)} k={k} draft_bits={draft_bits} "
                f"accept_rate={accept:.3f} cycles={cycles} "
                f"pages_trimmed={trimmed} equal=yes"),
        record("spec_smoke_requests", len(reqs), unit="count"),
        record("spec_smoke_accept_rate", accept, unit="ratio",
               derived=f"k={k} draft_bits={draft_bits} seeded+greedy "
                       f"token-equal to baseline"),
    ]
    emit_bench("serving", rows[1:])  # the csv row is terminal output only
    return rows


def main() -> None:
    for row in run():
        print(row)
    print("spec smoke: ok", flush=True)


if __name__ == "__main__":
    main()
