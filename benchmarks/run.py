"""Benchmark runner — one module per paper table/figure.

Prints ``name,value,unit,derived`` CSV and appends each suite's records
to its ``BENCH_<suite>.json`` trajectory (one entry per commit — see
``benchmarks/common.py``). Fast subset by default; pass ``--full`` for
the longer training sweeps used in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# allow plain ``python benchmarks/run.py`` (repo root not on sys.path then)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training sweeps (EXPERIMENTS.md numbers)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal steps/trials — CI entry-point check only")
    ap.add_argument("--only", default=None, help="comma-list of modules")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "reference"],
                    help="kernel backend override (sets REPRO_KERNEL_BACKEND)")
    ap.add_argument("--interpret", default=None, choices=["auto", "0", "1"],
                    help="Pallas interpret mode (sets REPRO_KERNEL_INTERPRET;"
                         " default: auto-detect, compiled on real TPU)")
    args = ap.parse_args()

    # must land in the environment before jax/kernels trace anything
    if args.backend:
        os.environ["REPRO_KERNEL_BACKEND"] = args.backend
    if args.interpret:
        os.environ["REPRO_KERNEL_INTERPRET"] = args.interpret

    from benchmarks import (base_factor, bitwidth_sweep, conversion_approx,
                            energy, format_comparison, kernels, quant_error,
                            serving, train_step, update_precision)

    steps = 60 if args.full else (4 if args.smoke else 25)
    suites = {
        "quant_error": lambda: quant_error.run(
            trials=24 if args.full else (2 if args.smoke else 8)),
        "base_factor": lambda: base_factor.run(steps=steps),
        "format_comparison": lambda: format_comparison.run(steps=steps),
        "update_precision": lambda: update_precision.run(steps=steps),
        "bitwidth_sweep": lambda: bitwidth_sweep.run(steps=steps),
        "conversion_approx": lambda: conversion_approx.run(
            steps=30 if args.full else (4 if args.smoke else 10)),
        "energy": energy.run,
        "kernels": kernels.run,
        # fused-vs-unfused dispatch-path guard: always-on (incl. --smoke)
        # so a regression that silently re-densifies the weights shows up
        # as a fwd_weight_bytes ratio of 1.0 in CI
        "train_step": lambda: train_step.run(
            steps=2 if args.smoke else (6 if args.full else 3)),
        # serving keeps its default trace in --smoke: jit compiles dominate
        # its cost, and the tiny-trace regime is prefill-bound (lock-step
        # flattery, not the decode-bound regime the comparison is about)
        "serving": lambda: serving.run(sweep=args.full),
    }
    if args.only:
        keep = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(keep) - set(suites))
        if unknown or not keep:
            # an unmatched filter used to silently run *nothing* and exit 0
            ap.error(f"--only: unknown suite(s) {unknown or args.only!r}; "
                     f"valid names: {', '.join(suites)}")
        suites = {k: suites[k] for k in suites if k in keep}

    from benchmarks.common import BenchRecord, emit_bench

    print("name,value,unit,derived")
    failed = 0
    for name, fn in suites.items():
        try:
            rows = fn()
            for row in rows:
                print(row, flush=True)
            emit_bench(name, [r for r in rows
                              if isinstance(r, BenchRecord)])
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},NaN,,SUITE FAILED", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
