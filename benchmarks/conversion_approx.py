"""Table 10 reproduction: conversion-approximation LUT sweep.

Two halves:
  * accuracy — approximation-aware training of the CPU-scale LM with the
    hybrid Mitchell/LUT decode simulated inside every forward GEMM
    (LUT = 1/2/4/8); claim: negligible accuracy loss at any LUT size.
  * energy — the per-op cost of each setting from the calibrated datapath
    model (the paper's measured 12.29..19.02 fJ/op row).
Also benchmarks the bit-exact Pallas kernel at each LUT size.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timed, train_tiny_lm
from repro.core.energy import DATAPATH_FJ_PER_OP
from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.core.quantizer import QuantConfig
from repro.kernels.lns_matmul import lns_matmul_pallas


def run(steps: int = 40) -> list[str]:
    rows = []
    for lut in (1, 2, 4, 8):
        qcfg = QuantConfig.lns_madam(approx_lut=lut)
        t0 = time.monotonic()
        losses = train_tiny_lm(qcfg, steps=steps, batch=8, seq=16)
        us = (time.monotonic() - t0) * 1e6 / steps
        fj = DATAPATH_FJ_PER_OP[f"lns8_lut{lut}"]
        rows.append(csv_row(
            f"table10_lut{lut}", us,
            f"final_loss={sum(losses[-5:]) / 5:.4f} energy_fj_per_op={fj}"))

    # kernel-level: bit-exact datapath at each LUT size (interpret mode)
    fmt = LNSFormat(bits=8, gamma=8)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (128, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (64, 128))
    sa, sb = compute_scale(a), compute_scale(b)
    pa = lns_pack(*lns_encode(a, fmt, sa), fmt)
    pb = lns_pack(*lns_encode(b, fmt, sb), fmt)
    exact = jnp.dot(a, b)
    for lut in (1, 2, 4, 8):
        out = lns_matmul_pallas(pa, pb, fmt, lut_entries=lut, block_k=16)
        val = out.astype(jnp.float32) * sa * sb / (1 << 16)
        err = float(jnp.max(jnp.abs(val - exact)) / jnp.max(jnp.abs(exact)))
        us = timed(lambda: lns_matmul_pallas(pa, pb, fmt, lut_entries=lut,
                                             block_k=16), iters=2)
        rows.append(csv_row(f"table10_kernel_lut{lut}", us,
                            f"rel_err_vs_fp32={err:.4f}"))
    return rows
