"""Table 4 reproduction (trend): LNS-Madam vs FP8 vs FP32 final loss.

Claim validated: 8-bit LNS-Madam ends within noise of the full-precision
baseline and at-or-better than FP8 (paper: 76.14 vs 75.83 vs 76.38 on
ImageNet — here the analogous loss ordering on the CPU-scale LM).
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row, train_tiny_lm
from repro.core.quantizer import QuantConfig


def run(steps: int = 60) -> list[str]:
    rows = []
    for name, qcfg in (
        ("lns_madam", QuantConfig.lns_madam()),
        ("fp8", QuantConfig.fp8()),
        ("fp32", QuantConfig.full_precision()),
    ):
        t0 = time.monotonic()
        losses = train_tiny_lm(qcfg, steps=steps)
        us = (time.monotonic() - t0) * 1e6 / steps
        final = sum(losses[-5:]) / 5
        rows.append(csv_row(f"table4_{name}", us,
                            f"final_loss={final:.4f} first={losses[0]:.4f}"))
    return rows
