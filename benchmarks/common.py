"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.paper_models import TINY_LM
from repro.core.quantizer import QuantConfig
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM

__all__ = ["timed", "train_tiny_lm", "csv_row", "write_bench_json"]

# repo root — benchmark JSON artifacts land here so CI can glob them
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6  # us per call


def train_tiny_lm(qcfg: QuantConfig, *, optimizer="madam", steps=60,
                  lr=2.0 ** -6, seed=0, cfg=TINY_LM, batch=16, seq=32,
                  update_fmt=None) -> List[float]:
    """Train the CPU-scale LM for a few steps; returns the loss curve.

    ``optimizer``: "madam" (LNS-native) or "sgd_q"/"adamw_q" (Eq.-4
    quantized-update baselines used by the Fig.-7 comparison).
    """
    data = SyntheticLM(cfg, batch=batch, seq=seq, seed=seed)
    losses = []
    if optimizer == "madam":
        mcfg = MadamConfig(lr=lr, update_format=update_fmt) if update_fmt \
            else MadamConfig(lr=lr)
        state = init_train_state(jax.random.PRNGKey(seed), cfg, mcfg)
        step = jax.jit(build_train_step(cfg, qcfg, mcfg))
        for i, b in zip(range(steps), data):
            state, m = step(state, jax.tree.map(jnp.asarray, b))
            losses.append(float(m["loss"]))
        return losses

    # fp-weight baselines with the Eq.-4 quantized-update wrapper
    from repro.core.quantizer import quantize_grads
    from repro.models import init_params, lm_loss
    from repro.optim import adamw, quantized_update, sgd
    opt = {"sgd": sgd(lr=0.3, weight_decay=0.0),
           "adamw": adamw(lr=3e-3)}[optimizer.split("_")[0]]
    if optimizer.endswith("_q"):
        opt = quantized_update(opt, update_fmt)
    init, update = opt
    params = init_params(jax.random.PRNGKey(seed), cfg)
    st = init(params)

    @jax.jit
    def step(params, st, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg, qcfg, remat=False))(params)
        grads = quantize_grads(grads, qcfg)
        params, st = update(grads, st, params)
        return params, st, loss

    for i, b in zip(range(steps), data):
        params, st, loss = step(params, st, jax.tree.map(jnp.asarray, b))
        losses.append(float(loss))
    return losses


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def write_bench_json(suite: str, payload: Dict) -> str:
    """Write ``BENCH_<suite>.json`` at the repo root (machine-readable
    perf trajectory — CI uploads these from the smoke job). Returns the
    path. Values should be plain floats/ints/strings."""
    path = os.path.join(_ROOT, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
