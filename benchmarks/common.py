"""Shared benchmark harness utilities and the versioned BenchRecord
contract.

Every suite emits :class:`BenchRecord` rows (``record(...)``; the
historical ``csv_row`` constructor is a deprecated alias that now also
returns a record). ``emit_bench`` appends one entry per commit to
``BENCH_<suite>.json`` — an **append-only trajectory** keyed by git sha
with a flat ``latest`` name->value view for existing consumers (the
gateway merge, CI artifact upload, the regression gate). Re-runs on the
same sha merge by record name instead of appending, so one CI job's
serving + gateway passes land in a single entry.

``kernel_roofline`` attaches bytes/FLOP estimates (from
``repro.launch.roofline`` hardware constants) to kernel records so
measured-vs-roofline gaps stay visible next to the wall-clock numbers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.paper_models import TINY_LM
from repro.core.quantizer import QuantConfig
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM

__all__ = ["BenchRecord", "SCHEMA_VERSION", "record", "csv_row",
           "kernel_roofline", "timed", "train_tiny_lm",
           "train_tiny_lm_numerics", "emit_bench", "read_bench",
           "write_bench_json"]

# repo root — benchmark JSON artifacts land here so CI can glob them
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA_VERSION = 1

# append-only, but bounded: one entry per commit, oldest dropped past this
_MAX_TRAJECTORY = 200


@dataclasses.dataclass
class BenchRecord:
    """One measured benchmark quantity — the contract every suite emits.

    ``unit`` says what ``value`` means (``us_per_call`` for wall times,
    ``tok_s``, ``ratio``, ``bytes``, ``count`` ...); ``derived`` keeps the
    human-facing annotation string from the CSV era; ``extra`` holds
    structured attachments (e.g. the roofline dict). Backend/interpret/sha
    are stamped per *trajectory entry* by ``emit_bench``, not per record.
    """

    name: str
    value: float
    unit: str = "us_per_call"
    derived: str = ""
    extra: Optional[Dict[str, Any]] = None

    def __str__(self) -> str:  # the runner's CSV line
        return f"{self.name},{self.value:.1f},{self.unit},{self.derived}"

    def to_json(self) -> Dict[str, Any]:
        d = {"name": self.name, "value": float(self.value),
             "unit": self.unit}
        if self.derived:
            d["derived"] = self.derived
        if self.extra is not None:
            d["extra"] = self.extra
        return d


def record(name: str, value: float, *, unit: str = "us_per_call",
           derived: str = "", extra: Optional[Dict[str, Any]] = None
           ) -> BenchRecord:
    """Constructor sugar for :class:`BenchRecord`."""
    return BenchRecord(name=name, value=float(value), unit=unit,
                       derived=derived, extra=extra)


def csv_row(name: str, us: float, derived: str) -> BenchRecord:
    """Deprecated: historical ``name,us,derived`` row constructor — now
    returns a :class:`BenchRecord` (unit ``us_per_call``). New code should
    call :func:`record` with an explicit unit."""
    return record(name, us, derived=derived)


def kernel_roofline(flops: float, hbm_bytes: float) -> Dict[str, Any]:
    """Roofline estimate for one kernel record's ``extra`` attachment.

    Uses the TPU-class constants from ``repro.launch.roofline`` (197
    TFLOP/s, 819 GB/s HBM): ideal compute/memory time, arithmetic
    intensity, and which wall the kernel sits against — so the measured
    time can be read as a multiple of its ideal.
    """
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    t_c = flops / PEAK_FLOPS
    t_m = hbm_bytes / HBM_BW
    return {
        "flops": float(flops),
        "hbm_bytes": float(hbm_bytes),
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "arithmetic_intensity": flops / hbm_bytes if hbm_bytes else 0.0,
        "bound": "memory" if t_m >= t_c else "compute",
        "ideal_us": max(t_c, t_m) * 1e6,
    }


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6  # us per call


def train_tiny_lm(qcfg: QuantConfig, *, optimizer="madam", steps=60,
                  lr=2.0 ** -6, seed=0, cfg=TINY_LM, batch=16, seq=32,
                  update_fmt=None) -> List[float]:
    """Train the CPU-scale LM for a few steps; returns the loss curve.

    ``optimizer``: "madam" (LNS-native) or "sgd_q"/"adamw_q" (Eq.-4
    quantized-update baselines used by the Fig.-7 comparison).
    """
    data = SyntheticLM(cfg, batch=batch, seq=seq, seed=seed)
    losses = []
    if optimizer == "madam":
        mcfg = MadamConfig(lr=lr, update_format=update_fmt) if update_fmt \
            else MadamConfig(lr=lr)
        state = init_train_state(jax.random.PRNGKey(seed), cfg, mcfg)
        step = jax.jit(build_train_step(cfg, qcfg, mcfg))
        for i, b in zip(range(steps), data):
            state, m = step(state, jax.tree.map(jnp.asarray, b))
            losses.append(float(m["loss"]))
        return losses

    # fp-weight baselines with the Eq.-4 quantized-update wrapper
    from repro.core.quantizer import quantize_grads
    from repro.models import init_params, lm_loss
    from repro.optim import adamw, quantized_update, sgd
    opt = {"sgd": sgd(lr=0.3, weight_decay=0.0),
           "adamw": adamw(lr=3e-3)}[optimizer.split("_")[0]]
    if optimizer.endswith("_q"):
        opt = quantized_update(opt, update_fmt)
    init, update = opt
    params = init_params(jax.random.PRNGKey(seed), cfg)
    st = init(params)

    @jax.jit
    def step(params, st, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg, qcfg, remat=False))(params)
        grads = quantize_grads(grads, qcfg)
        params, st = update(grads, st, params)
        return params, st, loss

    for i, b in zip(range(steps), data):
        params, st, loss = step(params, st, jax.tree.map(jnp.asarray, b))
        losses.append(float(loss))
    return losses


def train_tiny_lm_numerics(qcfg: QuantConfig, *, steps=8, lr=2.0 ** -6,
                           seed=0, cfg=TINY_LM, batch=8, seq=32,
                           update_fmt=None):
    """Instrumented tiny-LM run: loss curve + per-layer update-site health.

    Runs the same LNS-Madam step as :func:`train_tiny_lm` but with the
    in-graph numerics counters on (``build_train_step(numerics=True)``)
    and returns ``(losses, per_layer)`` where ``per_layer`` maps layer
    path -> mean-over-steps of each update-site stat (``sat_hi``,
    ``qerr_rel``, ``dead_frac``, ...). This is what the quant-error and
    update-precision suites use to put per-layer trajectory records into
    their BENCH JSONs.
    """
    mcfg = MadamConfig(lr=lr, update_format=update_fmt) if update_fmt \
        else MadamConfig(lr=lr)
    data = SyntheticLM(cfg, batch=batch, seq=seq, seed=seed)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, mcfg)
    step = jax.jit(build_train_step(cfg, qcfg, mcfg, numerics=True))
    losses: List[float] = []
    acc: Dict[str, Dict[str, float]] = {}
    for i, b in zip(range(steps), data):
        state, m = step(state, jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
        upd = jax.device_get(m["numerics"]["update"])
        for layer, stats in upd.items():
            dst = acc.setdefault(layer, {})
            for k, v in stats.items():
                dst[k] = dst.get(k, 0.0) + float(v) / steps
    return losses, acc


# ---------------------------------------------------------------------------
# trajectory persistence


def _git_sha(root: str) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=root,
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _bench_path(suite: str, root: Optional[str]) -> str:
    return os.path.join(root or _ROOT, f"BENCH_{suite}.json")


def _migrate(doc: Any, suite: str) -> Dict[str, Any]:
    """Lift any prior on-disk shape into the trajectory schema.

    Legacy files were one flat ``{name: value}`` snapshot (overwritten in
    place per run); they become a single synthetic trajectory entry with
    ``sha: "legacy"`` so history starts from what was actually recorded.
    """
    if isinstance(doc, dict) and "trajectory" in doc:
        doc.setdefault("schema_version", SCHEMA_VERSION)
        doc.setdefault("suite", suite)
        doc.setdefault("latest", {})
        return doc
    traj = []
    if isinstance(doc, dict) and doc:
        recs = [record(k, v, unit="value").to_json()
                for k, v in sorted(doc.items())
                if isinstance(v, (int, float)) and not isinstance(v, bool)]
        if recs:
            traj = [{"sha": "legacy", "records": recs}]
    return {"schema_version": SCHEMA_VERSION, "suite": suite,
            "latest": {}, "trajectory": traj}


def read_bench(suite: str, *, root: Optional[str] = None) -> Dict[str, Any]:
    """Load (and schema-migrate, in memory) one suite's trajectory doc."""
    path = _bench_path(suite, root)
    if not os.path.exists(path):
        return {"schema_version": SCHEMA_VERSION, "suite": suite,
                "latest": {}, "trajectory": []}
    with open(path) as f:
        return _migrate(json.load(f), suite)


def _rebuild_latest(traj: List[Dict[str, Any]]) -> Dict[str, float]:
    """Flat name->value view, last entry wins per name (union across
    entries, so a same-sha gateway pass extends the serving entry's keys
    without erasing them)."""
    latest: Dict[str, float] = {}
    for entry in traj:
        for r in entry.get("records", []):
            latest[r["name"]] = r["value"]
    return latest


def emit_bench(suite: str, records: List[BenchRecord], *,
               root: Optional[str] = None,
               sha: Optional[str] = None) -> str:
    """Append one per-commit entry of ``records`` to the suite trajectory.

    An existing entry for the same sha is merged record-by-name (later
    values replace earlier ones — the CI job runs serving then gateway
    against one checkout) rather than duplicated. Returns the path.
    """
    from repro.kernels.dispatch import resolve_backend, resolve_interpret
    doc = read_bench(suite, root=root)
    sha = sha or _git_sha(root or _ROOT)
    entry = None
    if doc["trajectory"] and doc["trajectory"][-1].get("sha") == sha:
        entry = doc["trajectory"][-1]
    if entry is None:
        entry = {"sha": sha, "records": []}
        doc["trajectory"].append(entry)
    entry["time"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    entry["backend"] = resolve_backend(None)
    entry["interpret"] = resolve_interpret(None)
    by_name = {r["name"]: i for i, r in enumerate(entry["records"])}
    for rec in records:
        j = rec.to_json()
        if rec.name in by_name:
            entry["records"][by_name[rec.name]] = j
        else:
            by_name[rec.name] = len(entry["records"])
            entry["records"].append(j)
    doc["trajectory"] = doc["trajectory"][-_MAX_TRAJECTORY:]
    doc["latest"] = _rebuild_latest(doc["trajectory"])
    path = _bench_path(suite, root)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def write_bench_json(suite: str, payload: Dict) -> str:
    """Deprecated shim for the flat-snapshot era: converts ``payload`` to
    records (unit ``value``) and appends through :func:`emit_bench`."""
    recs = [record(k, v, unit="value") for k, v in payload.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)]
    return emit_bench(suite, recs)
