"""Kernel micro-benchmarks (interpret mode): wall time is NOT TPU-meaningful
on CPU; the derived columns report the *structural* numbers that matter —
bytes moved per element (the LNS bandwidth win) and accuracy vs fp32 — and
each record carries a ``kernel_roofline`` extra (ideal compute/memory time
at TPU-class constants) so measured-vs-roofline gaps land in the
trajectory next to the wall clock."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import kernel_roofline, record, timed
from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels import (lns_qmatmul, madam_step, madam_step_packed,
                           quantize_pack)
from repro.kernels.dispatch import fused_sample, paged_attend

FMT = LNSFormat(bits=8, gamma=8)


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    M = K = N = 256
    a = jax.random.normal(key, (M, K))
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    sa, sb = compute_scale(a), compute_scale(b)
    pa = lns_pack(*lns_encode(a, FMT, sa), FMT)
    pb = lns_pack(*lns_encode(b, FMT, sb), FMT)

    out = lns_qmatmul(pa, pb, FMT, sa, sb)
    exact = jnp.dot(a, b)
    rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
    us = timed(lambda: lns_qmatmul(pa, pb, FMT, sa, sb), iters=2)
    hbm_ratio = (pa.size + pb.size) / ((a.size + b.size) * 2)  # vs bf16
    rows.append(record(
        "qmatmul_256", us,
        derived=f"rel_err={rel:.4f} operand_bytes_vs_bf16={hbm_ratio:.2f}",
        extra=kernel_roofline(2.0 * M * K * N,
                              pa.size + pb.size + out.size * 4)))

    x = jax.random.normal(key, (512, 512))
    us = timed(lambda: quantize_pack(x, FMT, scale_axis=0), iters=2)
    rows.append(record("quantize_pack_512", us,
                       derived="bytes_out_per_elem=1",
                       extra=kernel_roofline(4.0 * x.size,
                                             x.size * 4 + x.size)))

    code = jnp.zeros((512, 512), jnp.int16)
    sign = jnp.ones((512, 512), jnp.int8)
    g = jax.random.normal(key, (512, 512))
    v = jnp.ones((512, 512))
    ufmt = LNSFormat(bits=16, gamma=2048)
    us = timed(lambda: madam_step(code, sign, g, v, jnp.asarray(1), ufmt,
                                  lr=2.0 ** -7), iters=2)
    rows.append(record(
        "madam_step_512", us,
        derived="hbm_per_param_bytes=3r+8rw (code+sign+g+v)",
        extra=kernel_roofline(10.0 * g.size, 11 * g.size)))

    packed = lns_pack(sign, code, ufmt)
    us = timed(lambda: madam_step_packed(packed, g, v, jnp.asarray(1), ufmt,
                                         lr=2.0 ** -7), iters=2)
    rows.append(record(
        "madam_step_packed_512", us,
        derived="hbm_per_param_bytes=2r+6rw (word+g+v, sign in-word)",
        extra=kernel_roofline(10.0 * g.size, 8 * g.size)))

    rows += _paged_attend_bench()
    rows += _fused_sample_bench()
    return rows


def _paged_attend_bench() -> list:
    """Fused paged-attend kernel (interpret) vs the jnp reference on one
    decode-shaped batch — the CSV reports both, the roofline extra gives
    the DMA-bound ideal (KV page reads dominate)."""
    rng = np.random.default_rng(0)
    B, h, kv, hd, page, mp = 4, 8, 2, 64, 16, 8
    P = B * mp
    q = jnp.asarray(rng.normal(size=(B, 1, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P + 1, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P + 1, page, kv, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, mp)), jnp.int32)
    lengths = jnp.full((B,), mp * page, jnp.int32)

    def call(backend):
        return paged_attend(q, kp, vp, None, None, tbl, lengths,
                            fmt=None, softcap=None, sm_scale=0.125,
                            backend=backend, interpret=True)

    cap = mp * page
    flops = 4.0 * B * h * hd * cap          # qk + pv
    kv_bytes = 2.0 * B * cap * kv * hd * 4  # the gathered pages (f32 here)
    roof = kernel_roofline(flops, kv_bytes + q.size * 4)
    us_ref = timed(lambda: call("reference"), iters=2)
    us_ker = timed(lambda: call("pallas"), iters=2)
    return [
        record("paged_attend_ref", us_ref,
               derived=f"B={B} pages={mp} page={page}", extra=roof),
        record("paged_attend_kernel_interp", us_ker,
               derived="interpret-mode wall time (not TPU-meaningful)",
               extra=roof),
    ]


def _fused_sample_bench() -> list:
    """Fused sampler epilogue (greedy + temperature legs), kernel vs jnp."""
    rng = np.random.default_rng(1)
    B, V = 8, 2048
    lg = jnp.asarray(rng.normal(size=(B, V)), jnp.float32)
    gum = jnp.asarray(rng.gumbel(size=(B, V)), jnp.float32)
    temp = jnp.asarray(rng.uniform(0.2, 1.2, (B,)), jnp.float32)
    roof = kernel_roofline(3.0 * B * V, B * V * 8)  # lg + gumbel reads
    us_ref = timed(lambda: fused_sample(lg, gum, temp,
                                        backend="reference"), iters=2)
    us_ker = timed(lambda: fused_sample(lg, gum, temp, backend="pallas",
                                        interpret=True), iters=2)
    return [
        record("fused_sample_ref", us_ref, derived=f"B={B} V={V}",
               extra=roof),
        record("fused_sample_kernel_interp", us_ker,
               derived="interpret-mode wall time (not TPU-meaningful)",
               extra=roof),
    ]
