"""Kernel micro-benchmarks (interpret mode): wall time is NOT TPU-meaningful
on CPU; the derived columns report the *structural* numbers that matter —
bytes moved per element (the LNS bandwidth win) and accuracy vs fp32."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timed
from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels import (lns_qmatmul, madam_step, madam_step_packed,
                           quantize_pack)

FMT = LNSFormat(bits=8, gamma=8)


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    M = K = N = 256
    a = jax.random.normal(key, (M, K))
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    sa, sb = compute_scale(a), compute_scale(b)
    pa = lns_pack(*lns_encode(a, FMT, sa), FMT)
    pb = lns_pack(*lns_encode(b, FMT, sb), FMT)

    out = lns_qmatmul(pa, pb, FMT, sa, sb)
    exact = jnp.dot(a, b)
    rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
    us = timed(lambda: lns_qmatmul(pa, pb, FMT, sa, sb), iters=2)
    hbm_ratio = (pa.size + pb.size) / ((a.size + b.size) * 2)  # vs bf16
    rows.append(csv_row("qmatmul_256", us,
                        f"rel_err={rel:.4f} operand_bytes_vs_bf16={hbm_ratio:.2f}"))

    x = jax.random.normal(key, (512, 512))
    us = timed(lambda: quantize_pack(x, FMT, scale_axis=0), iters=2)
    rows.append(csv_row("quantize_pack_512", us, "bytes_out_per_elem=1"))

    code = jnp.zeros((512, 512), jnp.int16)
    sign = jnp.ones((512, 512), jnp.int8)
    g = jax.random.normal(key, (512, 512))
    v = jnp.ones((512, 512))
    ufmt = LNSFormat(bits=16, gamma=2048)
    us = timed(lambda: madam_step(code, sign, g, v, jnp.asarray(1), ufmt,
                                  lr=2.0 ** -7), iters=2)
    rows.append(csv_row("madam_step_512", us,
                        "hbm_per_param_bytes=3r+8rw (code+sign+g+v)"))

    packed = lns_pack(sign, code, ufmt)
    us = timed(lambda: madam_step_packed(packed, g, v, jnp.asarray(1), ufmt,
                                         lr=2.0 ** -7), iters=2)
    rows.append(csv_row("madam_step_packed_512", us,
                        "hbm_per_param_bytes=2r+6rw (word+g+v, sign in-word)"))
    return rows
