"""Serving throughput: continuous batching vs lock-step batching.

Replays one mixed-length request trace through two harnesses over the same
packed-LNS weights and decode step:

  lockstep — requests are processed in fixed groups of ``slots``; every
    group decodes until its *longest* request finishes (the old
    ``launch/serve.py`` shape: finished sequences squat on their slot).
  engine   — ``repro.serving.Engine``: a finished sequence frees its slot
    and cache rows immediately and the next request is admitted mid-decode.

Both paths are run once to warm the jit caches and timed on a second
replay. ``--full`` adds an offered-load sweep (arrival rate -> goodput).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.serving import Engine, Request, max_trace_len, synthetic_trace
from repro.training import build_decode_step, init_train_state


def run_lockstep(cfg, qcfg, mcfg, params, trace: List[Request], *,
                 slots: int, max_len: int, decode=None):
    """Fixed-group serving; returns (useful_new_tokens, wall_seconds).
    Pass a pre-jitted ``decode`` to share compile caches across replays."""
    if decode is None:
        decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    useful = 0
    t0 = time.monotonic()
    for g0 in range(0, len(trace), slots):
        group = trace[g0:g0 + slots]
        pmax = max(r.prompt_len for r in group)
        gmax = max(r.max_new_tokens for r in group)
        tokens = np.zeros((slots, pmax), np.int32)
        for j, r in enumerate(group):
            # left-pad shorter prompts so every row's last prompt token
            # lands at pmax-1 (the lock-step script's fixed-shape premise)
            tokens[j, pmax - r.prompt_len:] = np.asarray(r.prompt)
        caches = init_caches(slots, max_len, cfg)
        logits, caches = decode(params, caches,
                                {"tokens": jnp.asarray(tokens)},
                                jnp.zeros((slots,), jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        for step in range(1, gmax):
            pos = jnp.full((slots,), pmax + step - 1, jnp.int32)
            logits, caches = decode(params, caches, {"tokens": tok}, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        jax.block_until_ready(tok)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.monotonic() - t0


def run(requests: int = 24, slots: int = 4, prompt_len: int = 16,
        gen_len: int = 24, sweep: bool = False) -> list[str]:
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    # bimodal lengths: the regime where lock-step groups stall on their
    # longest member while continuous batching keeps slots occupied
    trace = synthetic_trace(cfg, requests=requests, prompt_len=prompt_len,
                            gen_len=gen_len, lengths="bimodal")
    # distribution bound (covers the sweep's re-drawn traces too)
    max_len = max_trace_len(prompt_len, gen_len, "bimodal")

    rows = []
    decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                 max_len=max_len, decode=decode)  # warm-up: compiles
    useful, wall = run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                                max_len=max_len, decode=decode)
    tps_lock = useful / wall
    rows.append(csv_row("serving_lockstep", wall * 1e6,
                        f"tok_s={tps_lock:.1f} requests={requests} "
                        f"slots={slots}"))

    engine = Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                    max_len=max_len)
    engine.run(trace)      # warm-up: compiles every prefill bucket
    engine.reset()
    agg = engine.run(trace)
    tps_eng = agg["tokens_per_s"]
    rows.append(csv_row(
        "serving_engine", agg["wall_s"] * 1e6,
        f"tok_s={tps_eng:.1f} speedup_vs_lockstep={tps_eng / tps_lock:.2f} "
        f"ttft_p95_s={agg['ttft_p95_s']:.3f}"))

    if sweep:  # offered load -> goodput curve
        for rate in (2.0, 4.0, 8.0, 16.0):
            engine.reset()
            agg = engine.run(synthetic_trace(
                cfg, requests=requests, prompt_len=prompt_len,
                gen_len=gen_len, lengths="bimodal", rate=rate))
            rows.append(csv_row(
                f"serving_load_{rate:g}rps", agg["wall_s"] * 1e6,
                f"tok_s={agg['tokens_per_s']:.1f} "
                f"ttft_p95_s={agg['ttft_p95_s']:.3f} "
                f"latency_p95_s={agg['latency_p95_s']:.3f}"))
    return rows


if __name__ == "__main__":
    for row in run(sweep=True):
        print(row)
