"""Serving throughput: continuous batching vs lock-step batching, dense vs
block-paged KV, and prefix-cache reuse.

Replays one mixed-length request trace through the harnesses over the same
packed-LNS weights and decode step:

  lockstep — requests are processed in fixed groups of ``slots``; every
    group decodes until its *longest* request finishes (the old
    ``launch/serve.py`` shape: finished sequences squat on their slot).
  engine   — ``repro.serving.Engine``: a finished sequence frees its slot
    and cache rows immediately and the next request is admitted mid-decode.
  paged    — the engine over a block-paged KV pool holding the *same* KV
    memory as the dense engine but serving **2x the slots**: a request
    only pins ``ceil((prompt+budget)/page_size)`` pages, so concurrency is
    bounded by actual usage, not worst-case context. The reported peak
    concurrency is measured from the admit/finish intervals.
  prefix   — a shared-prefix trace through the paged engine with and
    without prefix caching: hits map resident pages into the block table
    and prefill only the suffix (fewer prefill tokens, same output).

All timed paths are run once to warm the jit caches and timed on a second
replay; results also land in ``BENCH_serving.json`` at the repo root.
``--full`` adds an offered-load sweep (arrival rate -> goodput).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, write_bench_json
from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.serving import Engine, Request, max_trace_len, synthetic_trace
from repro.training import build_decode_step, init_train_state


def _peak_concurrency(metrics) -> int:
    """Max simultaneously-admitted requests over the run (a finish at time
    t frees the slot before an admit at the same t takes it)."""
    events = []
    for m in metrics:
        events += [(m.t_admit, 1), (m.t_finish, -1)]
    peak = cur = 0
    for _, d in sorted(events):
        cur += d
        peak = max(peak, cur)
    return peak


def shared_prefix_trace(cfg, *, requests: int, prefix_len: int,
                        suffix_len: int, gen_len: int,
                        seed: int = 0) -> List[Request]:
    """Chat-style trace: one common system-prompt prefix, distinct tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).tolist()
    out = []
    for i in range(requests):
        suffix = rng.integers(0, cfg.vocab_size, (suffix_len,)).tolist()
        out.append(Request(rid=i, prompt=prefix + suffix,
                           max_new_tokens=gen_len))
    return out


def run_lockstep(cfg, qcfg, mcfg, params, trace: List[Request], *,
                 slots: int, max_len: int, decode=None):
    """Fixed-group serving; returns (useful_new_tokens, wall_seconds).
    Pass a pre-jitted ``decode`` to share compile caches across replays."""
    if decode is None:
        decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    useful = 0
    t0 = time.monotonic()
    for g0 in range(0, len(trace), slots):
        group = trace[g0:g0 + slots]
        pmax = max(r.prompt_len for r in group)
        gmax = max(r.max_new_tokens for r in group)
        tokens = np.zeros((slots, pmax), np.int32)
        for j, r in enumerate(group):
            # left-pad shorter prompts so every row's last prompt token
            # lands at pmax-1 (the lock-step script's fixed-shape premise)
            tokens[j, pmax - r.prompt_len:] = np.asarray(r.prompt)
        caches = init_caches(slots, max_len, cfg)
        logits, caches = decode(params, caches,
                                {"tokens": jnp.asarray(tokens)},
                                jnp.zeros((slots,), jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        for step in range(1, gmax):
            pos = jnp.full((slots,), pmax + step - 1, jnp.int32)
            logits, caches = decode(params, caches, {"tokens": tok}, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        jax.block_until_ready(tok)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.monotonic() - t0


def run(requests: int = 24, slots: int = 4, prompt_len: int = 16,
        gen_len: int = 24, sweep: bool = False) -> list[str]:
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    # bimodal lengths: the regime where lock-step groups stall on their
    # longest member while continuous batching keeps slots occupied
    trace = synthetic_trace(cfg, requests=requests, prompt_len=prompt_len,
                            gen_len=gen_len, lengths="bimodal")
    # distribution bound (covers the sweep's re-drawn traces too)
    max_len = max_trace_len(prompt_len, gen_len, "bimodal")

    rows = []
    decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                 max_len=max_len, decode=decode)  # warm-up: compiles
    useful, wall = run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                                max_len=max_len, decode=decode)
    tps_lock = useful / wall
    rows.append(csv_row("serving_lockstep", wall * 1e6,
                        f"tok_s={tps_lock:.1f} requests={requests} "
                        f"slots={slots}"))

    engine = Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                    max_len=max_len)
    engine.run(trace)      # warm-up: compiles every prefill bucket
    engine.reset()
    agg = engine.run(trace)
    tps_eng = agg["tokens_per_s"]
    dense_peak = _peak_concurrency(engine.completed)
    rows.append(csv_row(
        "serving_engine", agg["wall_s"] * 1e6,
        f"tok_s={tps_eng:.1f} speedup_vs_lockstep={tps_eng / tps_lock:.2f} "
        f"ttft_p95_s={agg['ttft_p95_s']:.3f}"))

    # ---- paged pool: same KV memory as the dense engine, 2x the slots
    page = 16
    num_pages = slots * max_len // page  # dense-equivalent KV positions
    paged = Engine(cfg, qcfg, mcfg, params, num_slots=2 * slots,
                   max_len=max_len, page_size=page, num_pages=num_pages,
                   prefix_cache=False)
    paged.run(trace)
    paged.reset()
    agg_p = paged.run(trace)
    paged_peak = _peak_concurrency(paged.completed)
    rows.append(csv_row(
        "serving_paged", agg_p["wall_s"] * 1e6,
        f"tok_s={agg_p['tokens_per_s']:.1f} slots={2 * slots} "
        f"kv_pages={num_pages} peak_concurrency={paged_peak} "
        f"(dense peak {dense_peak} at equal KV memory)"))

    # ---- prefix caching: shared system prompt, suffix-only prefill
    fine = (8, 16, 32, 64, 128, 256)
    ptrace = shared_prefix_trace(cfg, requests=max(8, requests // 3),
                                 prefix_len=3 * page, suffix_len=6,
                                 gen_len=gen_len // 2)
    stats = {}
    for label, pc in (("off", False), ("on", True)):
        e = Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                   max_len=max_len, page_size=page, buckets=fine,
                   prefix_cache=pc)
        e.run(ptrace)
        e.reset()
        agg_x = e.run(ptrace)
        stats[label] = (e.prefill_tokens, e.prefix_hits,
                        e.prefix_reused_tokens, agg_x)
    (pt_off, _, _, agg_off) = stats["off"]
    (pt_on, hits, reused, agg_on) = stats["on"]
    rows.append(csv_row(
        "serving_prefix_cache", agg_on["wall_s"] * 1e6,
        f"prefill_tokens={pt_on} (vs {pt_off} uncached) "
        f"hits={hits} reused_tokens={reused} "
        f"tok_s={agg_on['tokens_per_s']:.1f}"))

    write_bench_json("serving", {
        "lockstep_tok_s": tps_lock,
        "engine_tok_s": tps_eng,
        "engine_speedup_vs_lockstep": tps_eng / tps_lock,
        "engine_ttft_p95_s": agg["ttft_p95_s"],
        "dense_slots": slots,
        "dense_peak_concurrency": dense_peak,
        "paged_tok_s": agg_p["tokens_per_s"],
        "paged_slots": 2 * slots,
        "paged_kv_pages": num_pages,
        "paged_page_size": page,
        "paged_peak_concurrency": paged_peak,
        "prefix_prefill_tokens": pt_on,
        "prefix_prefill_tokens_uncached": pt_off,
        "prefix_hits": hits,
        "prefix_reused_tokens": reused,
        "prefix_tok_s": agg_on["tokens_per_s"],
        "noprefix_tok_s": agg_off["tokens_per_s"],
        "requests": requests,
    })

    if sweep:  # offered load -> goodput curve
        for rate in (2.0, 4.0, 8.0, 16.0):
            engine.reset()
            agg = engine.run(synthetic_trace(
                cfg, requests=requests, prompt_len=prompt_len,
                gen_len=gen_len, lengths="bimodal", rate=rate))
            rows.append(csv_row(
                f"serving_load_{rate:g}rps", agg["wall_s"] * 1e6,
                f"tok_s={agg['tokens_per_s']:.1f} "
                f"ttft_p95_s={agg['ttft_p95_s']:.3f} "
                f"latency_p95_s={agg['latency_p95_s']:.3f}"))
    return rows


if __name__ == "__main__":
    for row in run(sweep=True):
        print(row)
