"""Serving throughput: continuous batching vs lock-step batching, dense vs
block-paged KV, and prefix-cache reuse.

Replays one mixed-length request trace through the harnesses over the same
packed-LNS weights and decode step:

  lockstep — requests are processed in fixed groups of ``slots``; every
    group decodes until its *longest* request finishes (the old
    ``launch/serve.py`` shape: finished sequences squat on their slot).
  engine   — ``repro.serving.Engine``: a finished sequence frees its slot
    and cache rows immediately and the next request is admitted mid-decode.
  paged    — the engine over a block-paged KV pool holding the *same* KV
    memory as the dense engine but serving **2x the slots**, with the
    ``ondemand`` allocation policy: a request pins only its prompt's
    pages at admission and grows one page per ``page_size`` decoded
    tokens, preempting the youngest request by recompute when the pool
    runs dry — concurrency is bounded by tokens actually resident, not
    worst-case context. A ``reserve``-policy row (worst-case pages up
    front) is recorded alongside to keep the policy gap on the
    trajectory. Peak concurrency is measured from admit/finish intervals.
  prefix   — a shared-prefix trace through the paged engine with and
    without prefix caching: hits map resident pages into the block table
    and prefill only the suffix (fewer prefill tokens, same output).
  mesh     — the ondemand paged engine again, sharded over a
    ``(data=2, model=2)`` host mesh (recorded only when >= 4 devices are
    visible, i.e. the CI ``mesh-smoke`` leg under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``). On host CPU
    the mesh row measures GSPMD partitioning + collective overhead, not a
    speedup — ``mesh_vs_single_tok_ratio`` is trend-tracked so the
    overhead stays on the trajectory; no invariant gates it until a
    multi-chip baseline lands.
  spec     — the ondemand paged engine with self-speculative decoding at
    draft bitwidths 6/7/8 (k=4 draft tokens per fused draft+verify
    cycle): the draft view re-grids the packed LNS weights to a coarser
    exponent grid, verify scores all k tokens in one S=k pass, and the
    accept rate is measured per bitwidth. The headline ``spec_tok_s`` is
    the best bitwidth's throughput; its ratio to the same-group paged
    baseline is the acceptance gate (spec must beat non-speculative).

  obs      — the ondemand paged engine again with the observability
    layer attached (request span ring + step timeline). Its throughput
    against the obs-disabled ``paged`` row from the same interleave
    group is the overhead gate: ``obs_overhead_pct`` must stay near
    zero, proving tracing is close to free, and the observer's
    prefill/decode/spec time breakdown rides along as an attachment.

All timed paths are run once to warm the jit caches and then timed over
``REPLAYS`` replays, keeping each harness's best. The engine harnesses
replay **interleaved** (round-robin, one replay each per round): host
noise on shared CPU arrives in multi-second windows, so consecutive
replays of one harness can all land in the same slow window and skew a
cross-harness ratio — interleaving gives every harness a shot at every
window and the per-harness best tracks capability, not the host's mood.
Results also land in ``BENCH_serving.json`` at the repo root. ``--full``
adds an offered-load sweep (arrival rate -> goodput).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, emit_bench, kernel_roofline, record
from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.obs import EngineObserver
from repro.serving import Engine, Request, max_trace_len, synthetic_trace
from repro.training import build_decode_step, init_train_state


REPLAYS = 5  # timed replays per harness; the best one is recorded


def _interleaved_best(engines, trace):
    """Replay ``trace`` through every (already warm) engine, round-robin,
    ``REPLAYS`` rounds; return each harness's fastest replay as
    ``{name: (agg, peak_concurrency, preemptions, decode_page_allocs)}``
    (counters captured at that replay, since a later one overwrites the
    engine's own state)."""
    best = {}
    for _ in range(REPLAYS):
        for name, eng in engines.items():
            eng.reset()
            agg = eng.run(trace)
            cur = best.get(name)
            if cur is None or agg["tokens_per_s"] > cur[0]["tokens_per_s"]:
                best[name] = (agg, _peak_concurrency(eng.completed),
                              eng.preemptions, eng.decode_page_allocs)
    return best


def _peak_concurrency(metrics) -> int:
    """Max simultaneously-admitted requests over the run (a finish at time
    t frees the slot before an admit at the same t takes it)."""
    events = []
    for m in metrics:
        events += [(m.t_admit, 1), (m.t_finish, -1)]
    peak = cur = 0
    for _, d in sorted(events):
        cur += d
        peak = max(peak, cur)
    return peak


def shared_prefix_trace(cfg, *, requests: int, prefix_len: int,
                        suffix_len: int, gen_len: int,
                        seed: int = 0) -> List[Request]:
    """Chat-style trace: one common system-prompt prefix, distinct tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).tolist()
    out = []
    for i in range(requests):
        suffix = rng.integers(0, cfg.vocab_size, (suffix_len,)).tolist()
        out.append(Request(rid=i, prompt=prefix + suffix,
                           max_new_tokens=gen_len))
    return out


def run_lockstep(cfg, qcfg, mcfg, params, trace: List[Request], *,
                 slots: int, max_len: int, decode=None):
    """Fixed-group serving; returns (useful_new_tokens, wall_seconds).
    Pass a pre-jitted ``decode`` to share compile caches across replays."""
    if decode is None:
        decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    useful = 0
    t0 = time.monotonic()
    for g0 in range(0, len(trace), slots):
        group = trace[g0:g0 + slots]
        pmax = max(r.prompt_len for r in group)
        gmax = max(r.max_new_tokens for r in group)
        tokens = np.zeros((slots, pmax), np.int32)
        for j, r in enumerate(group):
            # left-pad shorter prompts so every row's last prompt token
            # lands at pmax-1 (the lock-step script's fixed-shape premise)
            tokens[j, pmax - r.prompt_len:] = np.asarray(r.prompt)
        caches = init_caches(slots, max_len, cfg)
        logits, caches = decode(params, caches,
                                {"tokens": jnp.asarray(tokens)},
                                jnp.zeros((slots,), jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        for step in range(1, gmax):
            pos = jnp.full((slots,), pmax + step - 1, jnp.int32)
            logits, caches = decode(params, caches, {"tokens": tok}, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(slots, 1)
        jax.block_until_ready(tok)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.monotonic() - t0


def run(requests: int = 24, slots: int = 4, prompt_len: int = 16,
        gen_len: int = 24, sweep: bool = False) -> list[str]:
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    # bimodal lengths: the regime where lock-step groups stall on their
    # longest member while continuous batching keeps slots occupied
    trace = synthetic_trace(cfg, requests=requests, prompt_len=prompt_len,
                            gen_len=gen_len, lengths="bimodal")
    # distribution bound (covers the sweep's re-drawn traces too)
    max_len = max_trace_len(prompt_len, gen_len, "bimodal")

    rows = []
    decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))
    run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                 max_len=max_len, decode=decode)  # warm-up: compiles
    wall = min(run_lockstep(cfg, qcfg, mcfg, params, trace, slots=slots,
                            max_len=max_len, decode=decode)[1]
               for _ in range(REPLAYS))
    useful = sum(r.max_new_tokens for r in trace)
    tps_lock = useful / wall
    rows.append(csv_row("serving_lockstep", wall * 1e6,
                        f"tok_s={tps_lock:.1f} requests={requests} "
                        f"slots={slots}"))

    # ---- dense engine + the two paged policies, timed interleaved.
    # The paged pool holds the same KV memory as the dense engine with 2x
    # the slots; ondemand allocation is the headline paged row (pages
    # track tokens actually resident), the reserve policy rides along so
    # the trajectory keeps the cost of worst-case reservation visible.
    page = 16
    num_pages = slots * max_len // page  # dense-equivalent KV positions
    engines = {
        "dense": Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                        max_len=max_len),
        "ondemand": Engine(cfg, qcfg, mcfg, params, num_slots=2 * slots,
                           max_len=max_len, page_size=page,
                           num_pages=num_pages, prefix_cache=False,
                           alloc_policy="ondemand"),
        "reserve": Engine(cfg, qcfg, mcfg, params, num_slots=2 * slots,
                          max_len=max_len, page_size=page,
                          num_pages=num_pages, prefix_cache=False,
                          alloc_policy="reserve"),
    }
    # speculative harnesses share the interleave group so spec_tok_s and
    # paged_tok_s are measured under the same host-noise windows; one
    # engine per draft bitwidth keeps the accept-rate-vs-grid trajectory
    # honest (B=8 is the identity draft — accept ~1.0 by construction)
    spec_k = 4
    spec_bits = (6, 7, 8)
    for b in spec_bits:
        engines[f"spec_b{b}"] = Engine(
            cfg, qcfg, mcfg, params, num_slots=2 * slots, max_len=max_len,
            page_size=page, num_pages=num_pages, prefix_cache=False,
            alloc_policy="ondemand", speculate_k=spec_k, draft_bitwidth=b)
    # observability overhead: an ondemand clone with the span ring +
    # step timeline attached, timed in the same interleave group so the
    # obs-vs-paged ratio sees identical host-noise windows
    observer = EngineObserver()
    engines["obs"] = Engine(cfg, qcfg, mcfg, params, num_slots=2 * slots,
                            max_len=max_len, page_size=page,
                            num_pages=num_pages, prefix_cache=False,
                            alloc_policy="ondemand", observer=observer)
    for eng in engines.values():
        eng.run(trace)     # warm-up: compiles every prefill bucket
    best = _interleaved_best(engines, trace)
    engine = engines["dense"]  # the --full sweep reuses this harness
    agg, dense_peak, _, _ = best["dense"]
    tps_eng = agg["tokens_per_s"]
    rows.append(csv_row(
        "serving_engine", agg["wall_s"] * 1e6,
        f"tok_s={tps_eng:.1f} speedup_vs_lockstep={tps_eng / tps_lock:.2f} "
        f"ttft_p95_s={agg['ttft_p95_s']:.3f}"))

    agg_p, paged_peak, preempts, page_allocs = best["ondemand"]
    agg_r = best["reserve"][0]
    rows.append(csv_row(
        "serving_paged", agg_p["wall_s"] * 1e6,
        f"tok_s={agg_p['tokens_per_s']:.1f} slots={2 * slots} "
        f"kv_pages={num_pages} peak_concurrency={paged_peak} "
        f"preemptions={preempts} "
        f"(dense peak {dense_peak} at equal KV memory; reserve policy "
        f"tok_s={agg_r['tokens_per_s']:.1f})"))

    # ---- self-speculative decoding: accept rate per draft bitwidth and
    # the best bitwidth's throughput. The trace is deterministic and the
    # engine resets between replays, so the counters left by the final
    # replay match every replay's — read them off the engines directly.
    spec_stats = {b: (best[f"spec_b{b}"][0], engines[f"spec_b{b}"])
                  for b in spec_bits}
    best_bits = max(spec_bits,
                    key=lambda b: spec_stats[b][0]["tokens_per_s"])
    agg_s, eng_s = spec_stats[best_bits]
    tps_spec = agg_s["tokens_per_s"]
    tps_paged = agg_p["tokens_per_s"]
    accept_by_bits = {b: spec_stats[b][1].spec_accept_rate
                      for b in spec_bits}
    rows.append(csv_row(
        "serving_speculative", agg_s["wall_s"] * 1e6,
        f"tok_s={tps_spec:.1f} vs_paged={tps_spec / tps_paged:.2f} "
        f"k={spec_k} draft_bits={best_bits} "
        f"accept=" + "/".join(f"b{b}={accept_by_bits[b]:.2f}"
                              for b in spec_bits)))

    # ---- observability overhead: tracing must be near-free. The pct is
    # measured against the obs-disabled ondemand row from the same
    # interleave group; negative values just mean host noise favored
    # the obs replica. A clean extra replay (observer cleared first)
    # yields the time breakdown attachment without replay accumulation.
    agg_o = best["obs"][0]
    tps_obs = agg_o["tokens_per_s"]
    obs_overhead_pct = (1.0 - tps_obs / tps_paged) * 100.0
    observer.clear()
    engines["obs"].reset()
    agg_bd = engines["obs"].run(trace)
    time_breakdown = observer.time_breakdown(agg_bd["wall_s"])
    rows.append(csv_row(
        "serving_obs", agg_o["wall_s"] * 1e6,
        f"tok_s={tps_obs:.1f} overhead_vs_paged={obs_overhead_pct:.2f}% "
        f"spans={len(observer.spans.snapshot())} "
        f"timeline_rows={len(observer.timeline.samples())}"))

    # ---- prefix caching: shared system prompt, suffix-only prefill
    fine = (8, 16, 32, 64, 128, 256)
    ptrace = shared_prefix_trace(cfg, requests=max(8, requests // 3),
                                 prefix_len=3 * page, suffix_len=6,
                                 gen_len=gen_len // 2)
    stats = {}
    for label, pc in (("off", False), ("on", True)):
        e = Engine(cfg, qcfg, mcfg, params, num_slots=slots,
                   max_len=max_len, page_size=page, buckets=fine,
                   prefix_cache=pc)
        e.run(ptrace)
        e.reset()
        agg_x = e.run(ptrace)
        stats[label] = (e.prefill_tokens, e.prefix_hits,
                        e.prefix_reused_tokens, agg_x)
    (pt_off, _, _, agg_off) = stats["off"]
    (pt_on, hits, reused, agg_on) = stats["on"]
    rows.append(csv_row(
        "serving_prefix_cache", agg_on["wall_s"] * 1e6,
        f"prefill_tokens={pt_on} (vs {pt_off} uncached) "
        f"hits={hits} reused_tokens={reused} "
        f"tok_s={agg_on['tokens_per_s']:.1f}"))

    # ---- mesh serving: the same ondemand paged harness over a (2,2)
    # host mesh, only when the platform exposes enough devices
    mesh_recs: list = []
    if jax.device_count() >= 4:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=2, model=2)
        mesh_eng = Engine(cfg, qcfg, mcfg, params, num_slots=2 * slots,
                          max_len=max_len, page_size=page,
                          num_pages=num_pages, prefix_cache=False,
                          alloc_policy="ondemand", mesh=mesh)
        mesh_eng.run(trace)  # warm-up
        agg_m = None
        for _ in range(REPLAYS):
            mesh_eng.reset()
            cand = mesh_eng.run(trace)
            if agg_m is None or cand["tokens_per_s"] > agg_m["tokens_per_s"]:
                agg_m = cand
        tps_mesh = agg_m["tokens_per_s"]
        mesh_recs = [
            record("mesh_tok_s", tps_mesh, unit="tok_s"),
            # host-CPU meshes pay GSPMD overhead with no extra compute:
            # the ratio tracks that overhead, it is not a speedup claim
            record("mesh_vs_single_tok_ratio", tps_mesh / tps_paged,
                   unit="ratio",
                   derived=f"mesh={tps_mesh:.1f} paged={tps_paged:.1f} "
                           f"shape=data2,model2"),
            record("mesh_devices", int(mesh.devices.size), unit="count"),
        ]
        rows.append(csv_row(
            "serving_mesh", agg_m["wall_s"] * 1e6,
            f"tok_s={tps_mesh:.1f} vs_single={tps_mesh / tps_paged:.2f} "
            f"mesh=data2,model2 slots={2 * slots}"))

    # per-decode-token roofline estimate (TPU-class constants): 2N FLOPs
    # against packed 1 B/param weight reads plus the slot's KV page reads
    n_params = cfg.active_params_count()
    kv_layers = cfg.num_layers
    kv_bytes = (kv_layers * (max_len // 2) * cfg.num_kv_heads
                * cfg.head_dim * 2)  # k+v, ~1 B/elem packed, half-full row
    tok_roofline = kernel_roofline(2.0 * n_params, n_params + kv_bytes)

    emit_bench("serving", [
        record("lockstep_tok_s", tps_lock, unit="tok_s"),
        record("engine_tok_s", tps_eng, unit="tok_s"),
        # dense_tok_s is the regression gate's canonical name for the
        # dense-cache engine on this same trace (== engine_tok_s)
        record("dense_tok_s", tps_eng, unit="tok_s", extra=tok_roofline),
        record("engine_speedup_vs_lockstep", tps_eng / tps_lock,
               unit="ratio"),
        record("engine_ttft_p95_s", agg["ttft_p95_s"], unit="s"),
        record("dense_slots", slots, unit="count"),
        record("dense_peak_concurrency", dense_peak, unit="count"),
        record("paged_tok_s", tps_paged, unit="tok_s", extra=tok_roofline),
        # the machine-independent acceptance metric: paged >= dense
        record("paged_vs_dense_tok_ratio", tps_paged / tps_eng,
               unit="ratio",
               derived=f"paged={tps_paged:.1f} dense={tps_eng:.1f}"),
        record("paged_reserve_tok_s", agg_r["tokens_per_s"], unit="tok_s"),
        record("paged_slots", 2 * slots, unit="count"),
        record("paged_kv_pages", num_pages, unit="count"),
        record("paged_page_size", page, unit="count"),
        record("paged_peak_concurrency", paged_peak, unit="count"),
        record("paged_preemptions", preempts, unit="count"),
        record("paged_decode_page_allocs", page_allocs, unit="count"),
        record("spec_tok_s", tps_spec, unit="tok_s", extra=tok_roofline),
        # the machine-independent acceptance metric: speculating must
        # beat the same paged engine decoding one token per launch
        record("spec_vs_paged_tok_ratio", tps_spec / tps_paged,
               unit="ratio",
               derived=f"spec={tps_spec:.1f} paged={tps_paged:.1f} "
                       f"k={spec_k} draft_bits={best_bits}"),
        record("spec_accept_rate_b6", accept_by_bits[6], unit="ratio"),
        record("spec_accept_rate_b7", accept_by_bits[7], unit="ratio"),
        record("spec_accept_rate_b8", accept_by_bits[8], unit="ratio"),
        record("spec_verify_steps", eng_s.spec_verify_steps, unit="count"),
        record("spec_cycles", eng_s.spec_cycles, unit="count"),
        record("spec_fallbacks", eng_s.spec_fallbacks, unit="count"),
        record("spec_k", spec_k, unit="count"),
        record("spec_draft_bits", best_bits, unit="count"),
        record("obs_tok_s", tps_obs, unit="tok_s"),
        # absolute percentage points vs the obs-disabled ondemand row;
        # tracked by check_regression as an absolute bound (the value
        # sits near zero, so relative change is meaningless)
        record("obs_overhead_pct", obs_overhead_pct, unit="pct",
               derived=f"obs={tps_obs:.1f} paged={tps_paged:.1f}",
               extra={"time_breakdown": time_breakdown}),
        record("prefix_prefill_tokens", pt_on, unit="count"),
        record("prefix_prefill_tokens_uncached", pt_off, unit="count"),
        record("prefix_hits", hits, unit="count"),
        record("prefix_reused_tokens", reused, unit="count"),
        record("prefix_tok_s", agg_on["tokens_per_s"], unit="tok_s"),
        record("noprefix_tok_s", agg_off["tokens_per_s"], unit="tok_s"),
        record("requests", requests, unit="count"),
    ] + mesh_recs)

    if sweep:  # offered load -> goodput curve
        for rate in (2.0, 4.0, 8.0, 16.0):
            engine.reset()
            agg = engine.run(synthetic_trace(
                cfg, requests=requests, prompt_len=prompt_len,
                gen_len=gen_len, lengths="bimodal", rate=rate))
            rows.append(csv_row(
                f"serving_load_{rate:g}rps", agg["wall_s"] * 1e6,
                f"tok_s={agg['tokens_per_s']:.1f} "
                f"ttft_p95_s={agg['ttft_p95_s']:.3f} "
                f"latency_p95_s={agg['latency_p95_s']:.3f}"))
    return rows


if __name__ == "__main__":
    for row in run(sweep=True):
        print(row)
