"""Fused-vs-unfused train step: the dispatch layer's regression guard.

Two implementations of one LNS-Madam train step on the smoke LM:

* ``unfused`` — the pre-dispatch pipeline: whole-tree ``materialize`` to
  dense bf16, fake-quant ``qeinsum`` on the dense copies, Madam as a
  per-leaf chain of jnp ops.
* ``dispatch`` — the production pipeline: packed ``LNSWeight`` leaves end
  to end, GEMMs routed through ``kernels/dispatch`` (tile-local decode),
  fused single-pass Madam update on the wire words.

Walltime on CPU is backend-dependent (the dispatch path auto-selects the
jnp reference backend here; on TPU it is the compiled Pallas kernel) — the
structural column is the parameter HBM traffic per step, which is what the
packed store actually buys: the unfused path reads/writes a dense
``2 B/elem`` copy of every weight each step on top of the packed words,
the dispatch path touches only the wire words (1 B/elem at B=8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, emit_bench, record, timed
from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat, is_lns_weight
from repro.core.quantizer import QuantConfig, quantize_grads
from repro.models.model import lm_loss
from repro.optim.madam import MadamConfig, madam_lns, materialize
from repro.training import TrainState, build_train_step, init_train_state
from repro.training.data import SyntheticLM


def _unfused_step(cfg, qcfg, mcfg):
    """The seed's materialize-then-train pipeline, kept as the baseline."""
    _, opt_update = madam_lns(mcfg)

    def step(state, batch):
        dense = materialize(state.params, mcfg, dtype=cfg.compute_dtype)
        loss, grads = jax.value_and_grad(
            lambda d: lm_loss(d, batch, cfg, qcfg, remat=True))(dense)
        grads = quantize_grads(grads, qcfg)
        new_p, new_opt = opt_update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt, state.step + 1), loss

    return step


def _param_bytes(params):
    packed = sum(l.packed.size * l.packed.dtype.itemsize
                 for l in jax.tree.leaves(
                     params, is_leaf=is_lns_weight) if is_lns_weight(l))
    elems = sum(l.packed.size for l in jax.tree.leaves(
        params, is_leaf=is_lns_weight) if is_lns_weight(l))
    return packed, elems


def run(steps: int = 3) -> list[str]:
    rows = []
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))

    state0 = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
    packed_bytes, elems = _param_bytes(state0.params)
    dense_bytes = elems * 2  # bf16 whole-tree copy the unfused path makes

    unfused = jax.jit(_unfused_step(cfg, qcfg, mcfg))
    fused = jax.jit(build_train_step(cfg, qcfg, mcfg))
    instrumented = jax.jit(build_train_step(cfg, qcfg, mcfg, numerics=True))

    us_a = timed(lambda: unfused(state0, batch), iters=steps)
    us_b = timed(lambda: fused(state0, batch), iters=steps)
    # numerics telemetry must ride along for ~free: the counters are
    # in-graph epilogue sums on tensors the step already touches (the
    # encode-site stats CSE with the quantizer's own scale/log2 pass), so
    # the instrumented step is gated in absolute percentage points — the
    # same TRACKED_ABS mechanism as serving's obs_overhead_pct. Both
    # sides of the subtraction use the same (larger) iter count: the
    # overhead is a small difference of two wall times.
    it = max(steps, 5)
    us_b2 = timed(lambda: fused(state0, batch), warmup=2, iters=it)
    us_c = timed(lambda: instrumented(state0, batch), warmup=2, iters=it)
    overhead_pct = (us_c - us_b2) / us_b2 * 100.0

    # one instrumented step's aggregate health, recorded so the gate can
    # trend the saturation fraction itself (a jump means a clip site is
    # suddenly railing codes, whatever the walltime says)
    _, metrics = instrumented(state0, batch)
    upd = jax.device_get(metrics["numerics"]["update"])
    n_layers = max(len(upd), 1)
    sat_hi = sum(float(s["sat_hi"]) for s in upd.values()) / n_layers
    sat_lo = sum(float(s["sat_lo"]) for s in upd.values()) / n_layers
    qerr = sum(float(s["qerr_rel"]) for s in upd.values()) / n_layers

    # per-step weight traffic on the forward side: the unfused path writes
    # + reads a dense copy of every packed leaf; dispatch reads the words
    unfused_fwd = packed_bytes + 2 * dense_bytes
    rows.append(csv_row(
        "train_step_unfused", us_a,
        f"fwd_weight_bytes={unfused_fwd} (packed+2x dense copy)"))
    rows.append(csv_row(
        "train_step_dispatch", us_b,
        f"fwd_weight_bytes={packed_bytes} "
        f"ratio={packed_bytes / unfused_fwd:.2f} speedup={us_a / us_b:.2f}x"))
    rows.append(csv_row(
        "train_step_numerics", us_c,
        f"overhead={overhead_pct:.1f}% sat_hi={sat_hi:.4f} "
        f"qerr_rel={qerr:.2e} ({n_layers} layers)"))
    emit_bench("train_step", [
        record("unfused_us_per_step", us_a),
        record("dispatch_us_per_step", us_b),
        record("speedup", us_a / us_b, unit="ratio"),
        record("unfused_fwd_weight_bytes", unfused_fwd, unit="bytes"),
        record("dispatch_fwd_weight_bytes", packed_bytes, unit="bytes"),
        # deterministic structural metric: the dispatch path must never
        # silently re-densify the weights (ratio would snap to ~1.0)
        record("fwd_weight_bytes_ratio", packed_bytes / unfused_fwd,
               unit="ratio"),
        record("numerics_us_per_step", us_c),
        record("numerics_overhead_pct", overhead_pct, unit="pct",
               derived="instrumented vs plain dispatch step"),
        record("numerics_sat_hi_frac", sat_hi, unit="ratio",
               derived="mean over update-site layers, first step"),
        record("numerics_sat_lo_frac", sat_lo, unit="ratio"),
        record("numerics_qerr_rel", qerr, unit="ratio",
               derived="mean per-layer Thm.-1 update quantization error"),
        record("steps", steps, unit="count"),
    ])
    return rows
