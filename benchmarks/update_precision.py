"""Tables 5 + Fig. 7 reproduction (trend): weight-update precision.

Table 5: LNS-Madam at 16-bit vs 32-bit Q_U — degradation should be small.
Fig. 7: Madam vs SGD/AdamW under the Eq.-4 logarithmic quantized weight
update as Q_U shrinks 16 -> 10 bits — Madam must degrade most gracefully.

The BENCH trajectory additionally carries per-layer update-site health
rows from instrumented runs at two Q_U widths, so a precision change
shows up layer-by-layer (which clip site railed) rather than only as a
final-loss delta.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row, record, train_tiny_lm, \
    train_tiny_lm_numerics
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig


def run(steps: int = 50) -> list[str]:
    rows = []
    base = QuantConfig.lns_madam()

    # ---- Table 5: Q_U bitwidth for LNS-Madam
    for bits in (32, 16):
        fmt = LNSFormat(bits=8, gamma=8).with_bits(bits)
        t0 = time.monotonic()
        losses = train_tiny_lm(base, steps=steps, update_fmt=fmt)
        us = (time.monotonic() - t0) * 1e6 / steps
        rows.append(csv_row(f"table5_lns_madam_u{bits}", us,
                            f"final_loss={sum(losses[-5:]) / 5:.4f}"))

    # ---- Fig. 7: optimizers under quantized weight update, 16 -> 10 bit
    for bits in (16, 12, 10):
        fmt = LNSFormat(bits=8, gamma=8).with_bits(bits)
        for opt in ("madam", "sgd_q", "adamw_q"):
            t0 = time.monotonic()
            losses = train_tiny_lm(base, optimizer=opt, steps=steps,
                                   update_fmt=fmt)
            us = (time.monotonic() - t0) * 1e6 / steps
            rows.append(csv_row(
                f"fig7_{opt}_u{bits}", us,
                f"final_loss={sum(losses[-5:]) / 5:.4f}"))

    # per-layer update-site health at a wide and a narrow Q_U: the narrow
    # grid's qerr_rel should rise roughly with the coarser gap while the
    # saturation fractions stay near zero (healthy clip sites)
    nsteps = max(4, min(steps, 10))
    for bits in (16, 10):
        fmt = LNSFormat(bits=8, gamma=8).with_bits(bits)
        _, per_layer = train_tiny_lm_numerics(base, steps=nsteps,
                                              update_fmt=fmt)
        for layer, stats in sorted(per_layer.items()):
            rows.append(record(
                f"u{bits}_layer_qerr_rel.{layer}", stats["qerr_rel"],
                unit="ratio",
                derived=f"sat_hi={stats['sat_hi']:.4f} "
                        f"dead={stats['dead_frac']:.4f} "
                        f"over {nsteps} steps"))
        if per_layer:
            n = len(per_layer)
            rows.append(record(
                f"u{bits}_layer_qerr_rel_mean",
                sum(s["qerr_rel"] for s in per_layer.values()) / n,
                unit="ratio", derived=f"{n} layers"))
            rows.append(record(
                f"u{bits}_layer_sat_hi_mean",
                sum(s["sat_hi"] for s in per_layer.values()) / n,
                unit="ratio"))
    return rows
