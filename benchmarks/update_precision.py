"""Tables 5 + Fig. 7 reproduction (trend): weight-update precision.

Table 5: LNS-Madam at 16-bit vs 32-bit Q_U — degradation should be small.
Fig. 7: Madam vs SGD/AdamW under the Eq.-4 logarithmic quantized weight
update as Q_U shrinks 16 -> 10 bits — Madam must degrade most gracefully.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row, train_tiny_lm
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig


def run(steps: int = 50) -> list[str]:
    rows = []
    base = QuantConfig.lns_madam()

    # ---- Table 5: Q_U bitwidth for LNS-Madam
    for bits in (32, 16):
        fmt = LNSFormat(bits=8, gamma=8).with_bits(bits)
        t0 = time.monotonic()
        losses = train_tiny_lm(base, steps=steps, update_fmt=fmt)
        us = (time.monotonic() - t0) * 1e6 / steps
        rows.append(csv_row(f"table5_lns_madam_u{bits}", us,
                            f"final_loss={sum(losses[-5:]) / 5:.4f}"))

    # ---- Fig. 7: optimizers under quantized weight update, 16 -> 10 bit
    for bits in (16, 12, 10):
        fmt = LNSFormat(bits=8, gamma=8).with_bits(bits)
        for opt in ("madam", "sgd_q", "adamw_q"):
            t0 = time.monotonic()
            losses = train_tiny_lm(base, optimizer=opt, steps=steps,
                                   update_fmt=fmt)
            us = (time.monotonic() - t0) * 1e6 / steps
            rows.append(csv_row(
                f"fig7_{opt}_u{bits}", us,
                f"final_loss={sum(losses[-5:]) / 5:.4f}"))
    return rows
