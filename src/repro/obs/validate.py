"""CLI: validate exported Chrome trace files against the span contract.

    python -m repro.obs.validate /tmp/trace/*.trace.json [--require-spec]
    python -m repro.obs.validate /tmp/trace/*.trace.json --train

Exit 0 when every file parses as a trace-event document and every
completed request carries its queue/prefill/decode (and, with
``--require-spec``, spec) spans; exit 1 otherwise. ``--train`` switches
to the training-trace contract instead: per-step ``train_step`` spans
plus the required ``numerics/*`` counter tracks (DESIGN.md §14). CI
round-trips both the serve and the train smoke exports through this.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.numerics import validate_train_trace
from repro.obs.spans import validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="exported *.trace.json files")
    ap.add_argument("--require-spec", action="store_true",
                    help="completed requests must also carry spec spans")
    ap.add_argument("--train", action="store_true",
                    help="validate against the training-trace contract "
                         "(train_step spans + numerics counter tracks)")
    args = ap.parse_args(argv)
    status = 0
    for path in args.paths:
        try:
            with open(path) as f:
                doc = json.load(f)
            if args.train:
                info = validate_train_trace(doc)
            else:
                per_request = validate_chrome_trace(
                    doc, require_spec=args.require_spec)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"[obs] FAIL {path}: {e}")
            status = 1
            continue
        if args.train:
            print(f"[obs] ok {path}: {info['steps']} train steps, "
                  f"{info['counter_events']} counter events over "
                  f"{len(info['tracks'])} tracks ({info['series']} series)")
        else:
            spans = sum(sum(v.values()) for v in per_request.values())
            print(f"[obs] ok {path}: {len(per_request)} completed requests, "
                  f"{spans} request spans, "
                  f"{len(doc['traceEvents'])} events")
    return status


if __name__ == "__main__":
    sys.exit(main())
