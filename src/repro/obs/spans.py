"""Request span tracing: a bounded ring of span events exportable as
Chrome trace-event JSON (loads in Perfetto / ``chrome://tracing``).

The engine records **complete** spans (a name, a start, a duration) and
**instant** events (preemption, requeue, abort, finish markers) into a
:class:`SpanRing`. Each request gets its own trace *thread* (tid = rid +
1; tid 0 is the engine itself), so Perfetto renders one swim-lane per
request with its queue -> prefill -> decode -> spec phases, and one lane
for the engine's step timeline.

Timestamps are engine-clock seconds (``Engine.now()``); the export
converts to the microsecond ``ts``/``dur`` fields the trace-event format
specifies. The ring is bounded (oldest events drop first) so a long-lived
server never grows without bound; ``dropped`` counts what fell out.

Appends happen on the engine/driver thread while exports may run on the
gateway's asyncio thread (``GET /obs/trace``), so the ring guards its
deque with a lock — the lock is only ever taken when tracing is enabled,
never on the disabled hot path.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["SpanRing", "ENGINE_TID", "request_tid", "validate_chrome_trace"]

ENGINE_TID = 0

# span/event categories — the validator keys off these
CAT_REQUEST = "request"
CAT_ENGINE = "engine"

# the request phases the acceptance bar requires for every completed
# request (spec spans additionally required when speculation ran)
REQUEST_PHASES = ("queue", "prefill", "decode")


def request_tid(rid: int) -> int:
    """Trace thread id for request ``rid`` (tid 0 is the engine)."""
    return rid + 1


class SpanRing:
    """Bounded ring of trace events; export via :meth:`to_chrome`."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events: "deque[tuple]" = deque(maxlen=capacity)
        self._tid_names: Dict[int, str] = {ENGINE_TID: "engine"}
        self._lock = threading.Lock()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    def _append(self, ev: tuple) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    def name_tid(self, tid: int, name: str) -> None:
        with self._lock:
            self._tid_names.setdefault(tid, name)

    def complete(self, name: str, cat: str, tid: int, t0: float,
                 t1: float, args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete span ``[t0, t1]`` (engine-clock seconds)."""
        self._append((name, cat, tid, t0, max(t1 - t0, 0.0), args))

    def instant(self, name: str, cat: str, tid: int, t: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        self._append((name, cat, tid, t, None, args))

    def snapshot(self) -> List[tuple]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def to_chrome(self, extra_events: Optional[List[Dict[str, Any]]] = None
                  ) -> Dict[str, Any]:
        """The trace-event JSON document (``{"traceEvents": [...]}``)."""
        with self._lock:
            events = list(self._events)
            tid_names = dict(self._tid_names)
        out: List[Dict[str, Any]] = []
        for tid, name in sorted(tid_names.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": 0,
                        "tid": tid, "args": {"name": name}})
        out.append({"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
                    "args": {"name": "repro serving engine"}})
        for name, cat, tid, t0, dur, args in sorted(
                events, key=lambda e: e[3]):
            ev: Dict[str, Any] = {"name": name, "cat": cat, "pid": 0,
                                  "tid": tid, "ts": t0 * 1e6}
            if dur is None:
                ev["ph"] = "i"
                ev["s"] = "t"  # thread-scoped instant
            else:
                ev["ph"] = "X"
                ev["dur"] = dur * 1e6
            if args:
                ev["args"] = args
            out.append(ev)
        if extra_events:
            out.extend(extra_events)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path: str,
               extra_events: Optional[List[Dict[str, Any]]] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(extra_events), f)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# schema validation (the CI round-trip check)


def _check_event(ev: Any, i: int) -> None:
    if not isinstance(ev, dict):
        raise ValueError(f"traceEvents[{i}] is not an object")
    for field in ("ph", "pid", "tid", "name"):
        if field not in ev:
            raise ValueError(f"traceEvents[{i}] missing {field!r}")
    ph = ev["ph"]
    if ph == "M":
        return  # metadata events carry no timestamp
    if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
        raise ValueError(f"traceEvents[{i}] ({ph!r}) has no numeric ts")
    if ph == "X":
        if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
            raise ValueError(f"traceEvents[{i}] complete span has bad dur")
    elif ph not in ("i", "I", "C", "B", "E"):
        raise ValueError(f"traceEvents[{i}] unknown phase {ph!r}")


def validate_chrome_trace(doc: Dict[str, Any], *,
                          require_spec: bool = False
                          ) -> Dict[str, Dict[str, int]]:
    """Validate an exported trace document against the schema Perfetto
    needs plus the repo's own span contract.

    Structural checks: ``traceEvents`` is a list of well-formed events
    (phase, pid/tid, microsecond ``ts``, non-negative ``dur`` on complete
    spans). Semantic check: every request tid that carries a ``finish``
    marker with a completed reason (stop/length/capacity) must also carry
    queue, prefill, and decode spans — and a spec span when
    ``require_spec`` is set. Returns ``{rid: {span_name: count}}`` for
    the finished requests; raises ``ValueError`` on any violation.
    """
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("trace document must hold a traceEvents list")
    events = doc["traceEvents"]
    spans: Dict[int, Dict[str, int]] = {}
    finished: Dict[int, str] = {}
    for i, ev in enumerate(events):
        _check_event(ev, i)
        if ev.get("cat") != CAT_REQUEST:
            continue
        tid = ev["tid"]
        if ev["ph"] == "X":
            per = spans.setdefault(tid, {})
            per[ev["name"]] = per.get(ev["name"], 0) + 1
        elif ev["ph"] == "i" and ev["name"] == "finish":
            reason = (ev.get("args") or {}).get("reason", "")
            if reason in ("stop", "length", "capacity"):
                finished[tid] = reason
    if not finished:
        raise ValueError("trace holds no completed request (finish marker "
                         "with reason stop/length/capacity)")
    required = REQUEST_PHASES + (("spec",) if require_spec else ())
    out: Dict[str, Dict[str, int]] = {}
    for tid, reason in sorted(finished.items()):
        per = spans.get(tid, {})
        missing = [name for name in required if not per.get(name)]
        if missing:
            raise ValueError(
                f"request tid {tid} finished ({reason}) but lacks "
                f"span(s) {missing}; has {sorted(per)}")
        out[tid - 1] = per  # keyed by rid
    return out
