"""Kernel-time attribution for the dispatch layer.

`kernels/dispatch.py` routes every op call through a module-level probe
in this file. The disabled path — the default — is a single global
``None`` check, so the serving hot loop pays nothing until someone calls
:func:`enable`.

When enabled, each call is classified:

* **trace-time** (the probe value is a ``jax.core.Tracer``): the op is
  being staged into a jit — bump the compile/trace counter for its
  (op, backend, bitwidth) key. Walltime here would measure tracing, not
  the kernel, so none is recorded.
* **eager**: time the call with ``perf_counter``. JAX dispatch is async,
  so by default this measures *launch* walltime; under the
  ``block_every`` sampling knob every Nth call additionally runs
  ``jax.block_until_ready`` on the result and records true device
  walltime in the ``blocked`` column.

:func:`profiler_trace` wraps ``jax.profiler`` start/stop for the cases
where attribution needs XLA's own view (``--jax-profile`` on the serve
CLI).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Dict, Optional, Tuple

__all__ = ["KernelStats", "enable", "disable", "get", "active",
           "profiler_trace"]

Key = Tuple[str, str, int]  # (op, backend, bitwidth; 0 = n/a)


class KernelStats:
    """Thread-safe per-(op, backend, bitwidth) accumulators."""

    def __init__(self, *, block_every: int = 0):
        # block_every=0 never blocks; N>0 blocks every Nth eager call
        self.block_every = block_every
        self._lock = threading.Lock()
        self._calls: Dict[Key, int] = {}
        self._traces: Dict[Key, int] = {}
        self._time_s: Dict[Key, float] = {}
        self._blocked_s: Dict[Key, float] = {}
        self._blocked_n: Dict[Key, int] = {}

    def record_trace(self, key: Key) -> None:
        with self._lock:
            self._traces[key] = self._traces.get(key, 0) + 1

    def record_call(self, key: Key, dur_s: float,
                    blocked_s: Optional[float] = None) -> None:
        with self._lock:
            self._calls[key] = self._calls.get(key, 0) + 1
            self._time_s[key] = self._time_s.get(key, 0.0) + dur_s
            if blocked_s is not None:
                self._blocked_s[key] = (
                    self._blocked_s.get(key, 0.0) + blocked_s)
                self._blocked_n[key] = self._blocked_n.get(key, 0) + 1

    def should_block(self, key: Key) -> bool:
        if self.block_every <= 0:
            return False
        # the pre-increment count: block on calls 0, N, 2N, ...
        return self._calls.get(key, 0) % self.block_every == 0

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """``{"op|backend|bits": {calls, traces, time_s, ...}}``."""
        with self._lock:
            keys = set(self._calls) | set(self._traces)
            out: Dict[str, Dict[str, Any]] = {}
            for key in sorted(keys):
                op, backend, bits = key
                row: Dict[str, Any] = {
                    "op": op, "backend": backend, "bits": bits,
                    "calls": self._calls.get(key, 0),
                    "traces": self._traces.get(key, 0),
                    "time_s": self._time_s.get(key, 0.0),
                }
                if key in self._blocked_n:
                    row["blocked_calls"] = self._blocked_n[key]
                    row["blocked_s"] = self._blocked_s[key]
                out[f"{op}|{backend}|b{bits}"] = row
            return out

    def clear(self) -> None:
        with self._lock:
            for d in (self._calls, self._traces, self._time_s,
                      self._blocked_s, self._blocked_n):
                d.clear()


# module-level singleton the dispatch hot path checks with one load
_stats: Optional[KernelStats] = None


def enable(*, block_every: int = 0) -> KernelStats:
    """Turn attribution on; returns the live collector."""
    global _stats
    _stats = KernelStats(block_every=block_every)
    return _stats


def disable() -> None:
    global _stats
    _stats = None


def active() -> Optional[KernelStats]:
    return _stats


def get() -> Dict[str, Dict[str, Any]]:
    """Snapshot of the live collector ({} when disabled)."""
    return _stats.snapshot() if _stats is not None else {}


def observe(op: str, backend: str, bits: int, probe: Any,
            fn, /, *args, **kwargs):
    """Run ``fn(*args, **kwargs)`` under attribution.

    ``probe`` is one of the op's array arguments; a ``jax.core.Tracer``
    there means we are inside jit tracing. Only called when a collector
    is enabled — dispatch inlines the ``None`` check. The leading
    parameters are positional-only so forwarded op kwargs (``backend=``,
    ``bits=``, ...) can never collide with them.
    """
    import jax

    stats = _stats
    if stats is None:  # raced a disable(); just run
        return fn(*args, **kwargs)
    key = (op, backend, bits)
    if isinstance(probe, jax.core.Tracer):
        stats.record_trace(key)
        return fn(*args, **kwargs)
    block = stats.should_block(key)
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    t1 = time.perf_counter()
    blocked_s = None
    if block:
        jax.block_until_ready(out)
        blocked_s = time.perf_counter() - t0
    stats.record_call(key, t1 - t0, blocked_s)
    return out


@contextlib.contextmanager
def profiler_trace(log_dir: str):
    """``jax.profiler`` trace over the with-block (TensorBoard format)."""
    import jax

    jax.profiler.start_trace(log_dir)
    try:
        yield log_dir
    finally:
        jax.profiler.stop_trace()
