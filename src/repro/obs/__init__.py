"""Observability layer: request span tracing, engine step timeline,
Prometheus exposition, and kernel-time attribution (DESIGN.md §13).

Everything here is off by default and near-free when disabled: the
engine's hot loop checks one attribute (``engine.observer is None``) and
the kernel dispatch path checks one module global
(``kernel_stats.active() is None``). The always-on pieces — the driver's
latency histograms and the ``/metrics`` text renderer — run off the hot
path entirely (per *finished request*, per scrape).
"""
from repro.obs import kernel_stats
from repro.obs.numerics import NumericsObserver, validate_train_trace
from repro.obs.observer import EngineObserver
from repro.obs.prom import (Histogram, parse_prometheus_text,
                            render_prometheus)
from repro.obs.spans import SpanRing, validate_chrome_trace
from repro.obs.timeline import StepTimeline

__all__ = [
    "EngineObserver", "NumericsObserver", "SpanRing", "StepTimeline",
    "Histogram", "render_prometheus", "parse_prometheus_text",
    "validate_chrome_trace", "validate_train_trace", "kernel_stats",
]
