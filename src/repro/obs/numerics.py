"""Per-layer LNS numerics health telemetry (DESIGN.md §14).

The paper's central claim is numerical — Thm. 1 bounds the weight-update
quantization error that LNS + Madam keeps small enough for stable 8-bit
training — and this module is the repo's visibility into that quantity.
Three clip sites can silently saturate: the gradient **encode**
(``lns_encode`` clamps the rounded exponent into ``[0, max_code]``), the
B_U -> B_W forward **requant** (``lns_requant_packed`` clamps the
re-gridded code), and the Madam **update** itself (Algorithm 1 clamps the
stepped exponent). Each is tracked per layer, per step, high and low rail
separately, as cheap *in-graph* reductions:

* the update-site stats ride the fused Madam kernel's epilogue
  (``kernels/madam_update.py``) while (code, target, code') are live in
  VMEM — no second HBM pass over the weights;
* the encode-site stats (:func:`encode_sat_stats`) re-derive the
  pre-clip exponent from the same gradient tensor the quantizer reads,
  so XLA fuses them into the existing encode pass;
* everything returns as one aux pytree of f32 scalars from the jitted
  train step — the host syncs once per step (on the loss it already
  blocks on), never per stat.

:class:`NumericsObserver` is the host-side sink: structured jsonl step
logs, Prometheus exposition through :func:`repro.obs.prom
.render_prometheus` (per-layer ``{layer=...}`` gauge families), Chrome
trace counter tracks next to the spans PR 9 introduced, and the
aggregate summary the train CLI prints. :func:`validate_train_trace` is
the CI round-trip contract for the exported training trace.
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, is_lns_weight, lns_unpack
from repro.obs.prom import render_prometheus
from repro.obs.spans import _check_event

__all__ = ["NumericsObserver", "path_name", "encode_sat_stats",
           "grad_encode_stats", "tree_code_stats", "validate_train_trace",
           "REQUIRED_TRAIN_COUNTERS"]

# counter tracks the exported training trace must carry (site/stat) —
# the per-layer series live in each counter event's args
REQUIRED_TRAIN_COUNTERS = ("update/sat_hi", "update/sat_lo",
                           "update/qerr_rel", "update/dead_frac")


def path_name(path) -> str:
    """A pytree key path as a stable dotted layer name.

    Handles the three jax key types (DictKey ``.key``, GetAttrKey
    ``.name``, SequenceKey ``.idx``) without importing their classes, so
    it tracks jax versions.
    """
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return ".".join(parts) or "root"


# ---------------------------------------------------------------------------
# in-graph stat helpers (traced inside the jitted train step)


def encode_sat_stats(x: jax.Array, fmt: LNSFormat, scale_axis=None
                     ) -> Dict[str, jax.Array]:
    """Rail-saturation fractions for encoding ``x`` into ``fmt``.

    Re-derives the *pre-clip* rounded exponent exactly as
    :func:`repro.core.lns.lns_encode` computes it (absmax pow2 scale,
    ``-log2`` with the tiny-floor, round-to-nearest ties-away), then
    counts what the clamp would cut: ``sat_lo`` is the overflow rail
    (rounded exponent below code 0 — impossible under a whole-tensor
    absmax scale, so nonzero means a per-channel scale undershot) and
    ``sat_hi`` is the underflow rail (values too small for the grid,
    including exact zeros). ``scale_log2`` tracks the pow2 scale drift.
    Reads the same tensor the encode itself reads — XLA fuses the two.
    """
    from repro.core.lns import compute_scale
    scale = compute_scale(x, axis=scale_axis)
    xf = x.astype(jnp.float32)
    mag = jnp.abs(xf) / scale
    e = -jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)) * fmt.gamma
    rounded = jnp.floor(e + 0.5)
    inv = 1.0 / float(max(x.size, 1))
    f32 = lambda m: m.astype(jnp.float32)
    return {
        "sat_lo": jnp.sum(f32(rounded < 0)) * inv,
        "sat_hi": jnp.sum(f32(rounded > fmt.max_code)) * inv,
        "scale_log2": jnp.mean(jnp.log2(scale)),
    }


def grad_encode_stats(grads, qcfg) -> Dict[str, Dict[str, jax.Array]]:
    """Per-layer encode-site stats for the gradient quantizer Q_G.

    Covers the >=2-D leaves ``quantize_grads`` actually pushes through
    the LNS grid; returns ``{}`` when the config doesn't quantize grads.
    """
    fmt = getattr(qcfg, "grad", None)
    if not isinstance(fmt, LNSFormat):
        return {}
    axis = getattr(qcfg, "grad_scale_axis", None)
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    out = {}
    for path, g in flat:
        if getattr(g, "ndim", 0) >= 2:
            out[path_name(path)] = encode_sat_stats(g, fmt, axis)
    return out


def tree_code_stats(params) -> Dict[str, Any]:
    """Host-side code-rail occupancy of every LNSWeight leaf.

    Serving-side health: a tree whose codes pile up at either rail has
    lost resolution (weights went out of the representable range, or
    collapsed to the flush-to-zero rail) — the live-weights readiness
    signal for the ROADMAP's train-while-serving item. One pass over the
    packed words on device, four scalars back to the host.
    """
    tot = 0
    lo = hi = code_sum = 0.0
    for leaf in jax.tree.leaves(params, is_leaf=is_lns_weight):
        if not is_lns_weight(leaf):
            continue
        fmt = leaf.fmt
        _, code = lns_unpack(leaf.packed, fmt)
        code = code.astype(jnp.int32)
        lo += float(jnp.sum(code == 0))
        hi += float(jnp.sum(code == fmt.max_code))
        code_sum += float(jnp.sum(code))
        tot += code.size
    if tot == 0:
        return {"elements": 0}
    return {"elements": tot, "code0_frac": lo / tot,
            "maxcode_frac": hi / tot, "code_mean": code_sum / tot}


# ---------------------------------------------------------------------------
# host-side observer


def _to_float_tree(tree) -> Any:
    """Device scalars -> plain floats (one batched transfer)."""
    host = jax.device_get(tree)
    return jax.tree.map(float, host)


class NumericsObserver:
    """Collects the per-step numerics pytree; renders jsonl / Prometheus
    / Chrome counter tracks / aggregate summaries.

    ``record_step`` is the only per-step call: one batched device->host
    transfer of the aux scalars (the loop already blocked on the loss,
    so this adds no extra sync), one jsonl line when ``log_path`` is
    set, one optional progress print when ``quiet`` is off. Rows retain
    in a bounded ring (``history``) for the trace export.
    """

    def __init__(self, *, log_path: Optional[str] = None,
                 history: int = 4096, quiet: bool = True,
                 progress_every: int = 10):
        self.log_path = log_path
        self.quiet = quiet
        self.progress_every = max(1, progress_every)
        self.started_at = time.time()
        self._t0 = time.perf_counter()
        self._rows: "deque[Dict[str, Any]]" = deque(maxlen=max(1, history))
        self._recorded = 0
        self._log_file = None
        if log_path:
            os.makedirs(os.path.dirname(os.path.abspath(log_path)),
                        exist_ok=True)
            self._log_file = open(log_path, "w")

    # -- per-step sink ------------------------------------------------------

    def record_step(self, step: int, metrics: Dict[str, Any],
                    walltime_s: Optional[float] = None) -> Dict[str, Any]:
        """Record one train step's metrics dict (with or without the
        ``numerics`` aux pytree). Returns the recorded row."""
        row: Dict[str, Any] = {
            "step": int(step),
            "t": time.perf_counter() - self._t0,
        }
        if walltime_s is not None:
            row["dt_s"] = float(walltime_s)
        for k in ("loss", "grad_norm"):
            if k in metrics:
                try:
                    row[k] = float(metrics[k])
                except (TypeError, ValueError):
                    pass
        num = metrics.get("numerics")
        row["numerics"] = _to_float_tree(num) if num else {}
        self._rows.append(row)
        self._recorded += 1
        if self._log_file is not None:
            self._log_file.write(json.dumps(row) + "\n")
            self._log_file.flush()
        if not self.quiet and (step % self.progress_every == 0 or step == 1):
            print(self._progress_line(row))
        return row

    def _progress_line(self, row: Dict[str, Any]) -> str:
        bits = [f"[train] step {row['step']}"]
        if "loss" in row:
            bits.append(f"loss {row['loss']:.4f}")
        if "dt_s" in row:
            bits.append(f"dt {row['dt_s'] * 1e3:.1f}ms")
        worst = self._worst_sat(row)
        if worst is not None:
            site, layer, frac = worst
            bits.append(f"sat {frac:.3f} ({site}:{layer})")
        return "  ".join(bits)

    @staticmethod
    def _worst_sat(row: Dict[str, Any]):
        worst = None
        for site, layers in (row.get("numerics") or {}).items():
            for layer, stats in layers.items():
                frac = stats.get("sat_lo", 0.0) + stats.get("sat_hi", 0.0)
                if worst is None or frac > worst[2]:
                    worst = (site, layer, frac)
        return worst

    @property
    def n_steps(self) -> int:
        return self._recorded

    def latest(self) -> Optional[Dict[str, Any]]:
        return self._rows[-1] if self._rows else None

    def close(self) -> None:
        if self._log_file is not None:
            self._log_file.close()
            self._log_file = None

    # -- Prometheus ---------------------------------------------------------

    def prom_stats(self):
        """``(flat_stats, labeled)`` for ``render_prometheus``.

        Flat stats are aggregates (worst rail saturation, mean update
        error); ``labeled`` holds the per-layer gauge families keyed
        ``numerics_<site>_<stat>`` with a ``{layer=...}`` label each.
        """
        row = self.latest()
        if row is None:
            return {"numerics_steps": 0}, {}
        stats: Dict[str, Any] = {
            "numerics_steps": self._recorded,
            "numerics_last_step": row["step"],
        }
        for k in ("loss", "grad_norm", "dt_s"):
            if k in row:
                stats[f"numerics_{k}"] = row[k]
        labeled: Dict[str, List] = {}
        for site, layers in (row.get("numerics") or {}).items():
            for layer, per in layers.items():
                for stat, v in per.items():
                    name = f"numerics_{site}_{stat}"
                    labeled.setdefault(name, []).append(
                        ({"layer": layer}, v))
        for name, samples in labeled.items():
            vals = [v for _, v in samples if not math.isnan(v)]
            if vals:
                stats[name + "_max"] = max(vals)
        return stats, labeled

    def prom_text(self, prefix: str = "repro_") -> str:
        stats, labeled = self.prom_stats()
        return render_prometheus(stats, info={"kind": "train"},
                                 prefix=prefix, labeled=labeled)

    # -- Chrome trace -------------------------------------------------------

    def to_chrome_counters(self, stride: int = 1) -> List[Dict[str, Any]]:
        """Counter tracks (``ph: "C"``): one event per recorded step per
        (site, stat), with the per-layer series in ``args``."""
        events: List[Dict[str, Any]] = []
        for row in list(self._rows)[::max(1, stride)]:
            ts = row["t"] * 1e6
            per_track: Dict[str, Dict[str, float]] = {}
            for site, layers in (row.get("numerics") or {}).items():
                for layer, per in layers.items():
                    for stat, v in per.items():
                        if math.isnan(v):
                            continue
                        per_track.setdefault(f"{site}/{stat}", {})[layer] = \
                            round(v, 6)
            if "loss" in row:
                per_track["loss"] = {"loss": row["loss"]}
            for track, args in sorted(per_track.items()):
                events.append({"ph": "C", "name": f"numerics/{track}",
                               "cat": "numerics", "pid": 0, "tid": 0,
                               "ts": ts, "args": args})
        return events

    def to_chrome(self) -> Dict[str, Any]:
        """Full trace document: step spans + numerics counter tracks."""
        events: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "repro training"}},
            {"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
             "args": {"name": "train"}},
        ]
        for row in self._rows:
            if "dt_s" not in row:
                continue
            dur = max(row["dt_s"], 0.0) * 1e6
            args = {"step": row["step"]}
            if "loss" in row:
                args["loss"] = row["loss"]
            events.append({"ph": "X", "name": "train_step", "cat": "train",
                           "pid": 0, "tid": 0,
                           "ts": max(row["t"] * 1e6 - dur, 0.0),
                           "dur": dur, "args": args})
        events.extend(self.to_chrome_counters())
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"steps_recorded": self._recorded}}

    def export(self, trace_dir: str, tag: str = "train") -> Dict[str, str]:
        """Write ``{tag}-{stamp}.trace.json`` + ``.summary.json``."""
        os.makedirs(trace_dir, exist_ok=True)
        stamp = time.strftime("%Y%m%d-%H%M%S")
        trace_path = os.path.join(trace_dir, f"{tag}-{stamp}.trace.json")
        with open(trace_path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
        summary_path = os.path.join(trace_dir, f"{tag}-{stamp}.summary.json")
        with open(summary_path, "w") as f:
            json.dump(self.summary(), f, indent=2)
            f.write("\n")
        return {"trace": trace_path, "summary": summary_path}

    # -- aggregates ---------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        """Worst-case / mean health over the retained window."""
        out: Dict[str, Any] = {"steps": self._recorded,
                               "retained": len(self._rows)}
        agg: Dict[str, List[float]] = {}
        for row in self._rows:
            for site, layers in (row.get("numerics") or {}).items():
                for per in layers.values():
                    for stat, v in per.items():
                        if not math.isnan(v):
                            agg.setdefault(f"{site}.{stat}", []).append(v)
        for key, vals in sorted(agg.items()):
            out[key + "_max"] = max(vals)
            out[key + "_mean"] = sum(vals) / len(vals)
        worst = self._worst_sat(self.latest() or {})
        if worst is not None:
            out["worst_sat_site"] = f"{worst[0]}:{worst[1]}"
            out["worst_sat_frac"] = worst[2]
        return out


# ---------------------------------------------------------------------------
# trace validation (the CI round-trip contract for training traces)


def validate_train_trace(doc: Dict[str, Any],
                         require: tuple = REQUIRED_TRAIN_COUNTERS
                         ) -> Dict[str, Any]:
    """Validate an exported *training* trace document.

    Structural checks reuse the span-event schema; semantic checks
    require at least one ``train_step`` complete span and a
    ``numerics/<site>/<stat>`` counter track (with at least one layer
    series) for every required (site, stat). Returns summary counts;
    raises ``ValueError`` on violations.
    """
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("trace document must hold a traceEvents list")
    steps = 0
    counters: Dict[str, int] = {}
    series: set = set()
    for i, ev in enumerate(doc["traceEvents"]):
        _check_event(ev, i)
        if ev["ph"] == "X" and ev["name"] == "train_step":
            steps += 1
        elif ev["ph"] == "C" and str(ev["name"]).startswith("numerics/"):
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                raise ValueError(
                    f"traceEvents[{i}] counter {ev['name']!r} has no series")
            for v in args.values():
                if not isinstance(v, (int, float)):
                    raise ValueError(
                        f"traceEvents[{i}] counter {ev['name']!r} holds a "
                        f"non-numeric series value")
            track = ev["name"][len("numerics/"):]
            counters[track] = counters.get(track, 0) + 1
            series.update(f"{track}:{k}" for k in args)
    if steps == 0:
        raise ValueError("trace holds no train_step span")
    missing = [t for t in require if not counters.get(t)]
    if missing:
        raise ValueError(f"trace lacks numerics counter track(s) {missing}; "
                         f"has {sorted(counters)}")
    return {"steps": steps, "counter_events": sum(counters.values()),
            "tracks": sorted(counters), "series": len(series)}
