"""Prometheus text exposition (format 0.0.4) for the gateway.

Two halves:

* :class:`Histogram` — a tiny fixed-bucket cumulative histogram the
  driver feeds as requests finish (TTFT / TPOT / queue-wait). Updates
  are O(#buckets) integer bumps, cheap enough to stay always-on.
* :func:`render_prometheus` — flattens the driver's existing JSON stats
  snapshot plus histogram state into the standard text format, so a
  stock Prometheus server can scrape ``GET /metrics`` with no adapter.

:func:`parse_prometheus_text` is the inverse used by CI: a strict-enough
parser that asserts ``# TYPE`` lines precede their samples, every sample
value parses as a float, and each histogram carries a ``+Inf`` bucket
with consistent ``_sum``/``_count`` series.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["Histogram", "render_prometheus", "parse_prometheus_text",
           "LATENCY_BUCKETS"]

# seconds; spans sub-ms sampler ticks through multi-second TTFT tails
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


class Histogram:
    """Cumulative-bucket histogram in the Prometheus model."""

    def __init__(self, name: str, help_text: str,
                 buckets: Iterable[float] = LATENCY_BUCKETS):
        self.name = name
        self.help = help_text
        self.bounds = tuple(sorted(buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        if v is None or math.isnan(v):
            return
        for i, bound in enumerate(self.bounds):
            if v <= bound:
                self._counts[i] += 1
                break
        else:
            self._counts[-1] += 1
        self._sum += v
        self._count += 1

    @property
    def count(self) -> int:
        return self._count

    def snapshot(self) -> Dict[str, Any]:
        """Cumulative bucket counts keyed by upper bound, plus sum/count."""
        cum = 0
        buckets: List[Tuple[float, int]] = []
        for bound, c in zip(self.bounds, self._counts):
            cum += c
            buckets.append((bound, cum))
        return {"name": self.name, "help": self.help,
                "buckets": buckets, "sum": self._sum,
                "count": self._count + 0}


def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels(labels: Optional[Dict[str, Any]]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in labels.items())
    return "{%s}" % inner


# flat stats keys -> (metric name, type, help). Counters are cumulative
# totals; everything else from the snapshot is exported as a gauge.
_COUNTERS = {
    "completed_total", "aborted_total", "rejected_total", "decode_steps",
    "prefills", "preemptions", "prefix_hits", "spec_cycles",
    "spec_drafted", "spec_accepted",
}


def render_prometheus(stats: Dict[str, Any],
                      histograms: Iterable[Histogram] = (),
                      info: Optional[Dict[str, Any]] = None,
                      prefix: str = "repro_",
                      labeled: Optional[Dict[str, List[Tuple[
                          Dict[str, Any], float]]]] = None) -> str:
    """The driver stats snapshot + histograms as exposition text.

    ``labeled`` carries multi-sample gauge families —
    ``{metric_name: [(labels, value), ...]}`` — used by the numerics
    observer for per-layer series (one sample per ``{layer=...}``).
    """
    lines: List[str] = []

    def emit(name: str, mtype: str, help_text: str,
             samples: List[Tuple[str, Optional[Dict[str, Any]], float]]):
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")
        for suffix, labels, value in samples:
            lines.append(f"{name}{suffix}{_labels(labels)} {_fmt(value)}")

    if info:
        emit(prefix + "build_info", "gauge",
             "Engine build/runtime identity (value is always 1).",
             [("", {k: v for k, v in info.items() if v is not None}, 1.0)])

    for key in sorted(stats):
        value = stats[key]
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            continue
        if isinstance(value, float) and math.isnan(value):
            continue  # rates are NaN before the first completion
        mtype = "counter" if key in _COUNTERS else "gauge"
        emit(prefix + key, mtype, f"Engine stat {key!r}.",
             [("", None, float(value))])

    for key in sorted(labeled or ()):
        samples = [("", labels, float(v)) for labels, v in labeled[key]
                   if isinstance(v, (int, float)) and not isinstance(v, bool)
                   and not math.isnan(float(v))]
        if samples:
            emit(prefix + key, "gauge", f"Per-label series {key!r}.", samples)

    for hist in histograms:
        snap = hist.snapshot()
        name = prefix + snap["name"]
        samples: List[Tuple[str, Optional[Dict[str, Any]], float]] = []
        for bound, cum in snap["buckets"]:
            samples.append(("_bucket", {"le": _fmt(bound)}, float(cum)))
        samples.append(("_bucket", {"le": "+Inf"}, float(snap["count"])))
        samples.append(("_sum", None, snap["sum"]))
        samples.append(("_count", None, float(snap["count"])))
        emit(name, "histogram", snap["help"], samples)

    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> Dict[str, Dict[str, Any]]:
    """Parse exposition text; raise ``ValueError`` on format violations.

    Returns ``{metric_name: {"type": ..., "samples": [(labels, value)]}}``
    where histogram child series (``_bucket``/``_sum``/``_count``) fold
    into their parent metric.
    """
    metrics: Dict[str, Dict[str, Any]] = {}
    typed: Dict[str, str] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE line {line!r}")
            typed[parts[2]] = parts[3]
            metrics.setdefault(parts[2], {"type": parts[3], "samples": []})
            continue
        if line.startswith("#"):
            continue
        # sample: name{labels} value [timestamp]
        if "{" in line:
            name, rest = line.split("{", 1)
            if "}" not in rest:
                raise ValueError(f"line {lineno}: unterminated labels")
            label_str, tail = rest.split("}", 1)
            labels = {}
            for part in filter(None, label_str.split(",")):
                if "=" not in part:
                    raise ValueError(f"line {lineno}: bad label {part!r}")
                k, v = part.split("=", 1)
                labels[k.strip()] = v.strip().strip('"')
            value_str = tail.split()[0] if tail.split() else ""
        else:
            fields = line.split()
            if len(fields) < 2:
                raise ValueError(f"line {lineno}: sample missing value")
            name, value_str = fields[0], fields[1]
            labels = {}
        name = name.strip()
        try:
            value = float(value_str)
        except ValueError:
            raise ValueError(
                f"line {lineno}: non-numeric value {value_str!r}") from None
        parent = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and typed.get(name[:-len(suffix)]) == \
                    "histogram":
                parent = name[:-len(suffix)]
                break
        if parent not in typed:
            raise ValueError(
                f"line {lineno}: sample {name!r} precedes its TYPE line")
        metrics[parent]["samples"].append(
            ({**labels, "__name__": name}, value))

    # histogram completeness: +Inf bucket, _sum, _count, monotone buckets
    for name, meta in metrics.items():
        if meta["type"] != "histogram":
            continue
        series = {s["__name__"] for s, _ in meta["samples"]}
        for want in (name + "_sum", name + "_count"):
            if want not in series:
                raise ValueError(f"histogram {name} missing {want}")
        buckets = [(s.get("le"), v) for s, v in meta["samples"]
                   if s["__name__"] == name + "_bucket"]
        if not any(le == "+Inf" for le, _ in buckets):
            raise ValueError(f"histogram {name} missing +Inf bucket")
        counts = [v for _, v in buckets]
        if counts != sorted(counts):
            raise ValueError(f"histogram {name} buckets not cumulative")
    return metrics
