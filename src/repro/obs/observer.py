"""The engine-facing bundle: span ring + step timeline behind one handle.

``Engine`` holds ``self.observer`` (default ``None``); every hook site in
the hot loop is a single ``if obs is not None`` — the disabled path costs
one attribute load and a branch, no allocation. When an
:class:`EngineObserver` is attached, the engine calls these methods at
its lifecycle edges and the observer turns them into spans (per-request
swim-lanes) and timeline rows (per-step gauges).

All timestamps are engine-clock seconds (``Engine.now()``). Caveat:
simulated-time replay (``step(now=...)``) stamps request events with the
*caller's* clock while step walltimes come from the real engine clock —
span durations from such runs are degenerate, so attach observers to
real-time runs (``Engine.run()``, the online driver).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.spans import (CAT_ENGINE, CAT_REQUEST, SpanRing, request_tid)
from repro.obs.timeline import StepTimeline

__all__ = ["EngineObserver"]


class EngineObserver:
    """Collects request spans + step timeline for one engine."""

    def __init__(self, *, span_capacity: int = 65536,
                 timeline_capacity: int = 16384):
        self.spans = SpanRing(span_capacity)
        self.timeline = StepTimeline(timeline_capacity)
        self.started_at = time.time()  # wall time, for export filenames

    # -- request lifecycle hooks (engine thread) -----------------------

    def admitted(self, rs, *, resumed: bool = False) -> None:
        req = rs.request
        tid = request_tid(req.rid)
        self.spans.name_tid(tid, f"req {req.rid}")
        if resumed:
            self.spans.instant("resume", CAT_REQUEST, tid, rs.t_admit,
                               {"generated": len(rs.generated)})
        else:
            self.spans.complete("queue", CAT_REQUEST, tid,
                                req.arrival, rs.t_admit)

    def prefill(self, rs, t0: float, t1: float, *,
                gauges: Optional[Dict[str, int]] = None) -> None:
        req = rs.request
        tid = request_tid(req.rid)
        self.spans.complete("prefill", CAT_REQUEST, tid, t0, t1,
                            {"prompt_len": req.prompt_len})
        self.timeline.record("prefill", t0, t1, emitted=1,
                             **(gauges or {}))

    def preempted(self, rs, t: float) -> None:
        self.spans.instant("preempt", CAT_REQUEST,
                           request_tid(rs.request.rid), t,
                           {"generated": len(rs.generated)})

    def aborted_queued(self, rid: int, t: float) -> None:
        tid = request_tid(rid)
        self.spans.name_tid(tid, f"req {rid}")
        self.spans.instant("finish", CAT_REQUEST, tid, t,
                           {"reason": "aborted", "queued": True})

    def finished(self, rs, reason: str) -> None:
        tid = request_tid(rs.request.rid)
        if rs.t_first_token is not None and rs.t_finish is not None:
            self.spans.complete("decode", CAT_REQUEST, tid,
                                rs.t_first_token, rs.t_finish)
        self.spans.instant("finish", CAT_REQUEST, tid,
                           rs.t_finish if rs.t_finish is not None else 0.0,
                           {"reason": reason, "tokens": len(rs.generated)})

    # -- step hooks ----------------------------------------------------

    def decode_step(self, t0: float, t1: float, *, emitted: int,
                    gauges: Optional[Dict[str, int]] = None) -> None:
        self.spans.complete("decode_step", CAT_ENGINE, 0, t0, t1,
                            {"emitted": emitted})
        self.timeline.record("decode", t0, t1, emitted=emitted,
                             **(gauges or {}))

    def spec_cycle(self, t0: float, t1: float, *, k: int,
                   rows: List[Tuple[int, int, int]], emitted: int,
                   gauges: Optional[Dict[str, int]] = None) -> None:
        """``rows`` is ``[(rid, accepted, emitted_for_request), ...]`` for
        the live slots the cycle covered."""
        accepted = 0
        for rid, acc, emit in rows:
            accepted += acc
            self.spans.complete("spec", CAT_REQUEST, request_tid(rid),
                                t0, t1, {"k": k, "accepted": acc,
                                         "emitted": emit})
        self.spans.complete("spec_cycle", CAT_ENGINE, 0, t0, t1,
                            {"k": k, "slots": len(rows)})
        self.timeline.record("spec", t0, t1, emitted=emitted,
                             drafted=k * len(rows), accepted=accepted,
                             **(gauges or {}))

    # -- consumption ---------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        out = self.timeline.summary()
        out["span_events"] = len(self.spans)
        out["span_dropped"] = self.spans.dropped
        return out

    def time_breakdown(self, wall_s: Optional[float] = None
                       ) -> Dict[str, float]:
        """Walltime shares by phase. With ``wall_s`` (the run's total
        wall), the uninstrumented remainder is attributed to host-side
        scheduling/bookkeeping — the gap jit launches can't explain."""
        s = self.timeline.summary()
        out = {
            "prefill_s": s.get("prefill_time_s", 0.0),
            "decode_s": s.get("decode_time_s", 0.0),
            "spec_s": s.get("spec_time_s", 0.0),
        }
        device = sum(out.values())
        if wall_s is not None:
            out["wall_s"] = wall_s
            out["host_s"] = max(wall_s - device, 0.0)
        total = wall_s if wall_s else device
        if total > 0:
            for key in ("prefill", "decode", "spec", "host"):
                if f"{key}_s" in out:
                    out[f"{key}_share"] = round(out[f"{key}_s"] / total, 4)
        return out

    def to_chrome(self) -> Dict[str, Any]:
        return self.spans.to_chrome(
            extra_events=self.timeline.to_chrome_counters())

    def export(self, trace_dir: str, *, tag: str = "trace") -> str:
        """Write the Chrome trace plus the timeline summary next to it;
        returns the trace path."""
        os.makedirs(trace_dir, exist_ok=True)
        stamp = time.strftime("%Y%m%d-%H%M%S",
                              time.localtime(self.started_at))
        path = os.path.join(trace_dir, f"{tag}-{stamp}.trace.json")
        self.spans.export(path,
                          extra_events=self.timeline.to_chrome_counters())
        with open(os.path.join(
                trace_dir, f"{tag}-{stamp}.timeline.json"), "w") as f:
            json.dump(self.summary(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    def clear(self) -> None:
        self.spans.clear()
        self.timeline.clear()
