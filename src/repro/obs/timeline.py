"""Engine step timeline: a fixed-size numpy ring of per-step samples.

Each ``Engine.step()`` that does work appends one row — step kind
(prefill / decode / spec), walltime, slot occupancy, queue depth, page
pool free/cached, cumulative preemptions, and the spec cycle's
drafted/accepted/emitted counts. The ring is a preallocated structured
array with a monotonically increasing write head, so a steady-state
server does zero Python allocation per step; ``samples()`` and
``summary()`` materialize copies on demand (live queries, trace export).

The same rows become Chrome trace counter events (``ph:"C"``) on the
engine lane so Perfetto renders occupancy/pool gauges under the spans.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

__all__ = ["StepTimeline", "STEP_KINDS"]

STEP_KINDS = ("prefill", "decode", "spec")
_KIND_ID = {k: i for i, k in enumerate(STEP_KINDS)}

_DTYPE = np.dtype([
    ("t0", np.float64),        # engine-clock step start (s)
    ("dur", np.float64),       # step walltime (s)
    ("kind", np.int8),         # index into STEP_KINDS
    ("running", np.int32),     # occupied decode slots after the step
    ("queued", np.int32),      # queue depth after the step
    ("pages_free", np.int32),  # allocator free pages (-1 when dense)
    ("pages_cached", np.int32),  # prefix-cache (LRU) pages (-1 when dense)
    ("preempts", np.int64),    # cumulative preemption count
    ("drafted", np.int32),     # spec: draft tokens proposed this step
    ("accepted", np.int32),    # spec: draft tokens accepted this step
    ("emitted", np.int32),     # tokens emitted to streams this step
])


class StepTimeline:
    """Preallocated ring of per-step samples (single-writer)."""

    def __init__(self, capacity: int = 16384):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf = np.zeros(capacity, dtype=_DTYPE)
        self._head = 0  # total rows ever written

    def __len__(self) -> int:
        return min(self._head, self.capacity)

    @property
    def total(self) -> int:
        return self._head

    @property
    def dropped(self) -> int:
        return max(self._head - self.capacity, 0)

    def record(self, kind: str, t0: float, t1: float, *, running: int = 0,
               queued: int = 0, pages_free: int = -1, pages_cached: int = -1,
               preempts: int = 0, drafted: int = 0, accepted: int = 0,
               emitted: int = 0) -> None:
        row = self._buf[self._head % self.capacity]
        row["t0"] = t0
        row["dur"] = max(t1 - t0, 0.0)
        row["kind"] = _KIND_ID[kind]
        row["running"] = running
        row["queued"] = queued
        row["pages_free"] = pages_free
        row["pages_cached"] = pages_cached
        row["preempts"] = preempts
        row["drafted"] = drafted
        row["accepted"] = accepted
        row["emitted"] = emitted
        self._head += 1

    def clear(self) -> None:
        self._head = 0

    def samples(self) -> np.ndarray:
        """Retained rows in chronological order (a copy)."""
        n = len(self)
        if self._head <= self.capacity:
            return self._buf[:n].copy()
        cut = self._head % self.capacity
        return np.concatenate([self._buf[cut:], self._buf[:cut]])

    def summary(self) -> Dict[str, Any]:
        """Per-kind step counts and total walltime over retained rows."""
        rows = self.samples()
        out: Dict[str, Any] = {
            "steps": int(self.total),
            "retained": int(len(rows)),
            "dropped": int(self.dropped),
        }
        for kind, kid in _KIND_ID.items():
            mask = rows["kind"] == kid
            out[f"{kind}_steps"] = int(mask.sum())
            out[f"{kind}_time_s"] = float(rows["dur"][mask].sum())
        if len(rows):
            out["emitted_tokens"] = int(rows["emitted"].sum())
            out["drafted_tokens"] = int(rows["drafted"].sum())
            out["accepted_tokens"] = int(rows["accepted"].sum())
            out["preempts"] = int(rows["preempts"].max())
            out["span_s"] = float(rows["t0"][-1] + rows["dur"][-1]
                                  - rows["t0"][0])
        return out

    def to_chrome_counters(self, *, stride: int = 1) -> List[Dict[str, Any]]:
        """Counter events (``ph:"C"``) for the engine lane of a trace."""
        rows = self.samples()[::max(stride, 1)]
        events: List[Dict[str, Any]] = []
        for row in rows:
            ts = float(row["t0"]) * 1e6
            events.append({"ph": "C", "name": "slots", "pid": 0, "tid": 0,
                           "ts": ts, "cat": "engine",
                           "args": {"running": int(row["running"]),
                                    "queued": int(row["queued"])}})
            if row["pages_free"] >= 0:
                events.append({"ph": "C", "name": "pages", "pid": 0,
                               "tid": 0, "ts": ts, "cat": "engine",
                               "args": {"free": int(row["pages_free"]),
                                        "cached": int(row["pages_cached"])}})
        return events
