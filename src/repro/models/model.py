"""The decoder-only LM covering all ten assigned architectures.

``ArchConfig.layer_pattern()`` describes the trunk as ``prefix`` unrolled
layers + ``n_periods`` scanned repetitions of a block period; this module
initializes parameters in exactly that structure (period params stacked on a
leading axis) and applies them with ``jax.lax.scan`` so 48-81-layer models
compile as one rolled loop. Weight leaves may be LNS codes — they are
decoded per layer *inside* the scan body, so at most one layer's dense
weights exist at a time (the no-fp-master-copy property, paper §4).

Families:
  dense/local/global — GQA attention + gated MLP (gemma3/qwen/granite/
    smollm/phi3v/musicgen backbones)
  moe   — attention (GQA or MLA) + routed experts (+ optional MTP head)
  mamba — Mamba2 SSD block (zamba2 trunk)
  shared_attn — zamba2's single shared transformer block, re-applied with a
    per-occurrence LoRA on the fused QKV projection
  rwkv  — RWKV6 time-mix + channel-mix
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum
from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ArchConfig, dense_init
from repro.models.layers import (decoded_of, dense_of, embedding_init,
                                 mlp_apply, mlp_init, rms_norm)

__all__ = ["ForwardOut", "init_params", "forward", "lm_loss", "init_caches",
           "decode_step"]


class ForwardOut(NamedTuple):
    logits: jax.Array
    caches: Optional[Dict[str, Any]]
    aux: jax.Array
    hidden: jax.Array


# ---------------------------------------------------------------------------
# init


def _block_init(key, cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    ln = lambda: jnp.zeros((d,), jnp.float32)
    if kind in ("dense", "local", "global"):
        a = (attn_mod.mla_init if cfg.use_mla else attn_mod.attn_init)(ks[0], cfg)
        return {"ln1": ln(), "attn": a, "ln2": ln(), "mlp": mlp_init(ks[1], cfg)}
    if kind == "moe":
        a = (attn_mod.mla_init if cfg.use_mla else attn_mod.attn_init)(ks[0], cfg)
        return {"ln1": ln(), "attn": a, "ln2": ln(),
                "moe": moe_mod.moe_init(ks[1], cfg)}
    if kind == "mamba":
        return {"ln": ln(), "mamba": ssm_mod.mamba_init(ks[0], cfg)}
    if kind == "rwkv":
        return {"ln1": ln(), "ln2": ln(), "rwkv": rwkv_mod.rwkv_init(ks[0], cfg)}
    if kind == "shared_attn":
        # per-occurrence LoRA only; the shared weights live at the top level
        r = cfg.shared_block_lora_rank
        out_dim = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        p = {"ln1": ln(), "ln2": ln()}
        if r:
            p["lora_a"] = dense_init(ks[0], d, r, cfg.compute_dtype)
            p["lora_b"] = jnp.zeros((r, out_dim), cfg.compute_dtype)
        return p
    raise ValueError(kind)


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    prefix, n_periods, period = cfg.layer_pattern()
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {"embed": embedding_init(ks[0], cfg)}
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)

    if prefix:
        pk = jax.random.split(ks[1], len(prefix))
        params["prefix"] = [
            _block_init(pk[i], cfg, kind) for i, kind in enumerate(prefix)]

    if n_periods:
        period_params = {}
        for pos, kind in enumerate(period):
            pk = jax.random.split(jax.random.fold_in(ks[2], pos), n_periods)
            period_params[f"pos{pos}"] = jax.vmap(
                lambda k: _block_init(k, cfg, kind))(pk)
        params["period"] = period_params

    if "shared_attn" in period or "shared_attn" in prefix:
        params["shared"] = {
            "attn": attn_mod.attn_init(ks[3], cfg),
            "mlp": mlp_init(ks[4], cfg),
        }

    if cfg.mtp_depth:  # deepseek multi-token prediction module
        params["mtp"] = {
            "block": _block_init(ks[5], cfg, "dense"),
            "norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "proj": dense_init(jax.random.fold_in(ks[5], 1),
                               2 * cfg.d_model, cfg.d_model, cfg.compute_dtype),
        }
    return params


# ---------------------------------------------------------------------------
# embedding / head


def _embed(params, tokens, cfg: ArchConfig, qcfg) -> jax.Array:
    # lookup semantics: the table must be dense (decoded per call, not a
    # persistent master copy)
    tok_table = decoded_of(params["embed"]["tok"], cfg, qcfg)
    if cfg.num_codebooks:
        # musicgen: sum the per-codebook embeddings (tokens: (B,S,Books))
        offsets = jnp.arange(cfg.num_codebooks) * cfg.vocab_size
        x = jnp.sum(jnp.take(tok_table, tokens + offsets, axis=0), axis=2)
    else:
        x = jnp.take(tok_table, tokens, axis=0)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return shard(x.astype(cfg.compute_dtype), "batch", "seq", "embed")


def _logits(params, x, cfg: ArchConfig, qcfg) -> jax.Array:
    if cfg.tie_embeddings:
        w = decoded_of(params["embed"]["tok"], cfg, qcfg).T
    else:
        w = dense_of(params["embed"]["head"], cfg, qcfg)
    logits = qeinsum("bsd,dv->bsv", x, w, qcfg)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# blocks


def _attn_kind_args(cfg: ArchConfig, kind: str):
    window = cfg.sliding_window if kind == "local" else None
    theta = (cfg.rope_theta_global or cfg.rope_theta) if kind == "global" \
        else cfg.rope_theta
    return window, theta


def _block_apply(kind: str, bp, x, cfg: ArchConfig, qcfg, *, positions,
                 shared=None, cache=None, block_tables=None):
    """Apply one block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "local", "global", "moe"):
        window, theta = _attn_kind_args(cfg, kind)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if cfg.use_mla:
            a, cache = attn_mod.mla_apply(bp["attn"], h, cfg, qcfg,
                                          positions=positions, cache=cache)
        else:
            a, cache = attn_mod.attn_apply(bp["attn"], h, cfg, qcfg,
                                           positions=positions, window=window,
                                           theta=theta, cache=cache,
                                           block_table=block_tables)
        x = x + a
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if kind == "moe":
            m, aux = moe_mod.moe_apply(bp["moe"], h, cfg, qcfg)
        else:
            m = mlp_apply(bp["mlp"], h, cfg, qcfg)
        return x + m, cache, aux

    if kind == "mamba":
        h = rms_norm(x, bp["ln"], cfg.norm_eps)
        m, cache = ssm_mod.mamba_apply(bp["mamba"], h, cfg, qcfg, state=cache)
        return x + m, cache, aux

    if kind == "rwkv":
        x, cache = _rwkv_block(bp, x, cfg, qcfg, cache)
        return x, cache, aux

    if kind == "shared_attn":
        sp = shared
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        attn_p = dict(sp["attn"])
        if "lora_a" in bp:
            attn_p = _lora_qkv(attn_p, bp, h, cfg, qcfg)
        a, cache = attn_mod.attn_apply(attn_p, h, cfg, qcfg,
                                       positions=positions, cache=cache,
                                       block_table=block_tables)
        x = x + a
        m = mlp_apply(sp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps), cfg, qcfg)
        return x + m, cache, aux

    raise ValueError(kind)


def _rwkv_block(bp, x, cfg, qcfg, cache):
    """RWKV residual wiring (parallel-block form): both halves read the
    pre-block residual (x += TM(ln1(x)) + CM(ln2(x))). The reference impl
    feeds CM the post-TM residual; the parallel form lets one rwkv_apply
    share the state dict — deviation noted in DESIGN.md §Deviations."""
    (tm, cm), new_cache = rwkv_mod.rwkv_apply(
        bp["rwkv"],
        rms_norm(x, bp["ln1"], cfg.norm_eps),
        rms_norm(x, bp["ln2"], cfg.norm_eps),
        cfg, qcfg, state=cache)
    return x + tm + cm, new_cache


def _lora_qkv(attn_p, bp, h, cfg: ArchConfig, qcfg):
    """zamba2: add a per-occurrence LoRA delta to the fused QKV weights."""
    # weight arithmetic: the shared QKV must be dense to take the delta
    a = decoded_of(bp["lora_a"], cfg, qcfg)
    b = decoded_of(bp["lora_b"], cfg, qcfg)
    delta = jnp.einsum("dr,re->de", a, b)  # (d, (h+2kv)*hd)
    hd = cfg.head_dim
    q_dim = cfg.num_heads * hd
    kv_dim = cfg.num_kv_heads * hd
    attn_p = dict(attn_p)
    attn_p["wq"] = decoded_of(attn_p["wq"], cfg, qcfg) + delta[:, :q_dim]
    attn_p["wk"] = decoded_of(attn_p["wk"], cfg, qcfg) + delta[:, q_dim:q_dim + kv_dim]
    attn_p["wv"] = decoded_of(attn_p["wv"], cfg, qcfg) + delta[:, q_dim + kv_dim:]
    return attn_p


# ---------------------------------------------------------------------------
# forward


def forward(
    params,
    tokens: jax.Array,
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig] = None,
    *,
    patches: Optional[jax.Array] = None,   # phi3v precomputed patch embeds
    caches: Optional[Dict[str, Any]] = None,
    pos_offset: jax.Array | int = 0,
    block_tables: Optional[jax.Array] = None,
    remat: bool = False,
    scan_unroll: int | bool = 1,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Run the trunk. Returns (logits, new_caches, aux_loss).

    ``scan_unroll`` is forwarded to ``lax.scan`` over the layer periods;
    the dry-run passes ``True`` (full unroll) because XLA's cost analysis
    counts a while-loop body once — rolled scans stay the production path.

    ``tokens``: (B, S) int32 — or (B, S, Books) for multi-codebook audio.
    With ``caches`` the call is incremental (decode/chunked prefill).
    ``pos_offset`` may be a scalar or a (B,) vector of per-slot offsets —
    the serving engine decodes a batch whose rows sit at different
    sequence positions.

    ``block_tables`` (B, max_pages) maps each slot's local pages into the
    per-layer paged KV pools (see ``attention.init_paged_kv_cache``); one
    table serves every paged layer — each indexes its own pool with the
    same page ids. Required iff ``caches`` contains paged layers.
    """
    prefix, n_periods, period = cfg.layer_pattern()
    x = _embed(params, tokens, cfg, qcfg)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    off = jnp.asarray(pos_offset)
    positions = (off[..., None] + jnp.arange(S)).astype(jnp.int32)
    if positions.ndim > 1 and positions.shape[0] == 1:
        positions = positions[0]

    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared")
    new_caches: Dict[str, Any] = {}

    def body_fn(kind, bp, h, pos, sh, c):
        return _block_apply(kind, bp, h, cfg, qcfg, positions=pos,
                            shared=sh, cache=c, block_tables=block_tables)

    if remat:
        body_fn = jax.checkpoint(
            body_fn, static_argnums=(0,),
            policy=jax.checkpoint_policies.nothing_saveable)

    # ---- unrolled prefix
    if prefix:
        new_caches["prefix"] = []
        for i, kind in enumerate(prefix):
            c = caches["prefix"][i] if caches is not None else None
            x, c, aux = body_fn(kind, params["prefix"][i], x, positions,
                                shared, c)
            aux_total = aux_total + aux
            new_caches["prefix"].append(c)

    # ---- scanned periods
    if n_periods:
        pp = params["period"]
        pc = caches["period"] if caches is not None else None

        def scan_body(carry, xs):
            h, aux_acc = carry
            layer_params, layer_caches = xs
            out_caches = {}
            for pos, kind in enumerate(period):
                c = layer_caches[f"pos{pos}"] if layer_caches is not None else None
                h, c, aux = body_fn(kind, layer_params[f"pos{pos}"], h,
                                    positions, shared, c)
                aux_acc = aux_acc + aux
                out_caches[f"pos{pos}"] = c
            return (h, aux_acc), (out_caches if layer_caches is not None else 0)

        (x, aux_total), ys = jax.lax.scan(scan_body, (x, aux_total),
                                          (pp, pc), unroll=scan_unroll)
        if caches is not None:
            new_caches["period"] = ys

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, qcfg)
    return ForwardOut(logits, (new_caches if caches is not None else None),
                      aux_total, x)


def lm_loss(params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            qcfg: Optional[QuantConfig] = None, *, remat: bool = True,
            scan_unroll: int | bool = 1):
    """Next-token cross entropy (+ MoE aux + optional MTP loss)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    out = forward(params, tokens, cfg, qcfg, patches=batch.get("patches"),
                  remat=remat, scan_unroll=scan_unroll)
    logits, hidden = out.logits, out.hidden
    if batch.get("patches") is not None:
        n_patch = batch["patches"].shape[1]
        logits = logits[:, n_patch:]   # text positions only
        hidden = hidden[:, n_patch:]

    if cfg.num_codebooks:
        B, S, K = labels.shape
        logits = logits.reshape(B, S, K, cfg.vocab_size)
    ce = _xent(logits, labels)
    loss = ce + 0.01 * out.aux

    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(params, hidden, tokens, labels, cfg, qcfg)
    return loss


def _xent(logits, labels):
    lf = cot_boundary(logits).astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _mtp_loss(params, hidden, tokens, labels, cfg: ArchConfig, qcfg):
    """Depth-1 multi-token prediction (deepseek-v3 MTP), sharing the head.

    Combines the trunk's hidden state at t with the embedding of token t+1
    through a projection + one extra block; the shared head predicts t+2.
    """
    emb = _embed(params, tokens, cfg, qcfg)
    emb_next = jnp.concatenate([emb[:, 1:], emb[:, -1:]], axis=1)
    h = rms_norm(hidden, params["mtp"]["norm"], cfg.norm_eps)
    x = qeinsum("bsd,dc->bsc",
                jnp.concatenate([h, emb_next], axis=-1),
                dense_of(params["mtp"]["proj"], cfg, qcfg), qcfg)
    x, _, _ = _block_apply("dense", params["mtp"]["block"], x, cfg, qcfg,
                           positions=jnp.arange(x.shape[1]))
    mtp_logits = _logits(params, x, cfg, qcfg)
    shifted = jnp.concatenate(
        [labels[:, 1:], -jnp.ones_like(labels[:, :1])], axis=1)
    return _xent(mtp_logits, shifted)


# ---------------------------------------------------------------------------
# serving


def init_caches(batch: int, max_len: int, cfg: ArchConfig, *,
                page_size: Optional[int] = None,
                num_pages: Optional[int] = None,
                window_slack: int = 0) -> Dict[str, Any]:
    """Allocate decode caches matching the trunk structure.

    With ``page_size`` the full-context attention layers allocate one
    block-paged pool of ``num_pages`` pages each (default: dense-equivalent
    capacity, ``batch * ceil(max_len / page_size)``) instead of a dense
    ``(batch, max_len)`` buffer; ``forward`` then needs ``block_tables``.
    Sliding-window rings, recurrent state, and MLA caches keep their dense
    per-slot layout (DESIGN.md §7.1).

    ``window_slack`` over-allocates sliding-window rings by that many
    positions beyond ``cfg.sliding_window`` (the attention *mask* still
    uses the config window). Speculative decoding needs it: a cursor
    rewind after a rejected draft must not have let the ring's write head
    lap a position that is still inside the mask window — with ``slack >=
    k`` draft writes land only on slots that are already outside the mask
    for every attendable query, so stale words are overwritten before they
    can ever be read (DESIGN.md §11).
    """
    prefix, n_periods, period = cfg.layer_pattern()
    if page_size is not None and num_pages is None:
        num_pages = batch * (-(-max_len // page_size))
    paged = page_size is not None and not cfg.use_mla

    def one(kind):
        if kind in ("dense", "global", "moe"):
            if cfg.use_mla:
                return attn_mod.init_mla_cache(batch, max_len, cfg)
            if paged:
                return attn_mod.init_paged_kv_cache(batch, num_pages,
                                                    page_size, cfg)
            return attn_mod.init_kv_cache(batch, max_len, cfg)
        if kind == "local":
            return attn_mod.init_kv_cache(
                batch, max_len, cfg,
                window=cfg.sliding_window + window_slack)
        if kind == "shared_attn":
            if paged:
                return attn_mod.init_paged_kv_cache(batch, num_pages,
                                                    page_size, cfg)
            return attn_mod.init_kv_cache(batch, max_len, cfg)
        if kind == "mamba":
            return ssm_mod.init_mamba_state(batch, cfg)
        if kind == "rwkv":
            return rwkv_mod.init_rwkv_state(batch, cfg)
        raise ValueError(kind)

    caches: Dict[str, Any] = {}
    if prefix:
        caches["prefix"] = [one(k) for k in prefix]
    if n_periods:
        stack = lambda tree: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), tree)
        caches["period"] = {f"pos{i}": stack(one(k))
                            for i, k in enumerate(period)}
    return caches


def decode_step(params, caches, tokens, cfg: ArchConfig,
                qcfg: Optional[QuantConfig] = None, *,
                pos_offset, block_tables: Optional[jax.Array] = None,
                scan_unroll: int | bool = 1
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One incremental step (S small, typically 1). Returns (logits, caches)."""
    out = forward(params, tokens, cfg, qcfg, caches=caches,
                  pos_offset=pos_offset, block_tables=block_tables,
                  scan_unroll=scan_unroll)
    return out.logits[:, -1], out.caches
