"""Shared model machinery: the architecture config and init helpers."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "dense_init", "embed_init", "trunc_normal"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (exact shapes from the brief).

    Every assigned arch is expressible as a pattern of blocks over a shared
    decoder trunk; family selects the block wiring.
    """

    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    qkv_bias: bool = False            # qwen2.5
    qk_norm: bool = False             # gemma3
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None  # gemma3 global layers
    sliding_window: Optional[int] = None
    local_global_ratio: int = 0       # gemma3: N local per 1 global
    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    num_dense_layers: int = 0
    moe_dispatch: str = "dense_ref"   # dense_ref | a2a
    capacity_factor: float = 1.25
    mtp_depth: int = 0                # deepseek multi-token prediction heads
    # --- SSM / RWKV ---
    ssm_state_dim: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64            # mamba2 / rwkv6 head width
    shared_attn_every: int = 0        # zamba2: shared block period
    shared_block_lora_rank: int = 0   # zamba2 per-occurrence LoRA
    rwkv_chunk: int = 16
    ssm_chunk: int = 64
    # --- modality stubs ---
    num_patches: int = 0              # phi-3-vision prefix
    num_codebooks: int = 0            # musicgen
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act_fn: str = "silu"
    mlp_gated: bool = True
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    embed_scale: bool = False         # gemma: scale embeddings by sqrt(d)
    # beyond-paper: store the KV cache as packed 8-bit LNS codes (+ one
    # per-position-per-head scale) — the paper's format applied to the
    # serving bandwidth bottleneck. None = bf16 cache.
    kv_cache_bits: Optional[int] = None
    quantize_attention: bool = True   # paper: "quantize all GEMMs"
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear-attn / mostly-local)."""
        return self.family in ("ssm", "hybrid") or self.local_global_ratio > 0

    def layer_pattern(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        """(prefix_kinds, n_periods, period_kinds) — the decoder structure.

        The trunk is ``prefix`` unrolled layers followed by ``n_periods``
        scanned repetitions of ``period_kinds``.
        """
        if self.family == "ssm":  # rwkv6: uniform
            return (), self.num_layers, ("rwkv",)
        if self.family == "hybrid":  # zamba2: [mamba×(k-1), shared_attn] periods
            k = self.shared_attn_every
            n_periods = self.num_layers // k
            prefix = ("mamba",) * (self.num_layers - n_periods * k)
            return prefix, n_periods, ("mamba",) * (k - 1) + ("shared_attn",)
        if self.family == "moe":
            prefix = ("dense",) * self.num_dense_layers
            return prefix, self.num_layers - self.num_dense_layers, ("moe",)
        if self.local_global_ratio > 0:  # gemma3
            period = ("local",) * self.local_global_ratio + ("global",)
            n_periods = self.num_layers // len(period)
            prefix = ("local",) * (self.num_layers - n_periods * len(period))
            return prefix, n_periods, period
        return (), self.num_layers, ("dense",)

    def params_count(self) -> int:
        """Total trainable parameters (used for 6·N·D roofline bookkeeping)."""
        return _count_params(self)

    def active_params_count(self) -> int:
        """Per-token active parameters (MoE: routed top-k + shared only)."""
        return _count_params(self, active_only=True)


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    if cfg.use_mla:
        n = d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
        n += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        n += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
        n += cfg.num_heads * cfg.v_head_dim * d
        n += cfg.q_lora_rank + cfg.kv_lora_rank  # q_norm, kv_norm gains
        return n
    hd = cfg.head_dim
    n = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd + cfg.num_heads * hd * d
    if cfg.qkv_bias:
        n += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    if cfg.qk_norm:
        n += 2 * hd
    return n


def _mlp_params(d: int, f: int, gated: bool) -> int:
    return d * f * (3 if gated else 2)


def _mamba_params(cfg: ArchConfig) -> int:
    """Mirrors ``models.ssm.mamba_init`` exactly."""
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    h = d_in // cfg.ssm_head_dim
    n_st = cfg.ssm_state_dim
    conv_dim = d_in + 2 * n_st
    n = d * (2 * d_in + 2 * n_st + h)                   # in_proj
    n += cfg.ssm_conv_width * conv_dim + conv_dim       # conv w + b
    n += 3 * h                                          # A_log, D, dt_bias
    n += d_in                                           # norm
    n += d_in * d                                       # out_proj
    return n


def _rwkv_params(cfg: ArchConfig) -> int:
    """Mirrors ``models.rwkv.rwkv_init`` exactly."""
    d = cfg.d_model
    lora = 64
    n = 5 * d + d                                       # mix (5,d) + w0
    n += d * lora + lora * d                            # decay lora
    n += d                                              # u
    n += 5 * d * d                                      # wr wk wv wg wo
    n += d                                              # ln_x
    n += 2 * d                                          # mix_cm
    n += d * cfg.d_ff + cfg.d_ff * d + d * d            # ck cv cr
    return n


def _count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    emb = cfg.vocab_size * d * (cfg.num_codebooks or 1)
    head = 0 if cfg.tie_embeddings else d * cfg.vocab_size * (cfg.num_codebooks or 1)
    prefix, n_periods, period = cfg.layer_pattern()
    kinds = list(prefix) + list(period) * n_periods

    total = emb + head + d  # final norm
    shared_counted = False
    for kind in kinds:
        if kind == "rwkv":
            total += _rwkv_params(cfg) + 2 * d          # two norms
        elif kind == "mamba":
            total += _mamba_params(cfg) + d             # one norm
        elif kind == "shared_attn":
            if not shared_counted:
                total += (_attn_params(cfg)
                          + _mlp_params(d, cfg.d_ff, cfg.mlp_gated))
                shared_counted = True
            total += 2 * d  # per-occurrence norms
            r = cfg.shared_block_lora_rank
            if r:  # per-occurrence LoRA on the fused qkv projection
                hd = cfg.head_dim
                total += d * r + r * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
        elif kind == "moe":
            total += _attn_params(cfg) + 2 * d
            e_all = cfg.num_experts
            e_act = cfg.experts_per_token
            n_exp = e_act if active_only else e_all
            total += n_exp * _mlp_params(d, cfg.moe_d_ff, cfg.mlp_gated)
            total += cfg.num_shared_experts * _mlp_params(
                d, cfg.moe_d_ff, cfg.mlp_gated)
            total += d * cfg.num_experts  # router
        else:  # dense / local / global
            total += (_attn_params(cfg)
                      + _mlp_params(d, cfg.d_ff, cfg.mlp_gated) + 2 * d)
    if cfg.mtp_depth:  # MTP module: one dense block + norm + 2d->d proj
        total += (_attn_params(cfg) + _mlp_params(d, cfg.d_ff, cfg.mlp_gated)
                  + 2 * d + d + 2 * d * d)
    return int(total)


# ---------------------------------------------------------------------------
def trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, std: Optional[float] = None):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    return trunc_normal(key, (d_in, d_out), std, dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return trunc_normal(key, (vocab, d), 0.02, dtype)
