"""Attention: GQA (± bias / qk-norm / sliding window / softcap) and MLA.

Training/prefill use a flash-style attention (lax.scan over KV blocks with
an online softmax — logits never materialize beyond one (B,H,Sq,blk) tile),
sharded either by heads (when num_heads divides the model axis) or by query
sequence (sequence-parallel fallback for head counts like 40/24/9).

Decode attends one query against the full cache with plain softmax; the
cache's sequence axis is sharded over the model axis (split-KV
flash-decode: GSPMD turns the softmax/PV reductions into tiny all-reduces),
which also serves the batch-1 ``long_500k`` shape by spreading 512k of KV
over the whole mesh.

MLA (deepseek-v3) keeps the paper-faithful low-rank projections; decode uses
the absorbed form so the cache stores only (c_kv, k_rope).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum, ste_quantize
from repro.distributed.sharding import current_mesh, model_axis_size, shard
from repro.models.common import ArchConfig, dense_init
from repro.models.layers import apply_rope, decoded_of, dense_of, rope

__all__ = ["attn_init", "attn_apply", "mla_init", "mla_apply",
           "init_kv_cache", "init_paged_kv_cache", "is_paged_cache",
           "flash_attention", "model_axis_size"]


def _full_mesh_size() -> int:
    mesh = current_mesh()
    return 1 if mesh is None else mesh.devices.size


def _qa(x, cfg: ArchConfig, qcfg: Optional[QuantConfig]):
    """Q_A on attention-internal GEMM operands (paper: all GEMMs quantized)."""
    if qcfg is not None and cfg.quantize_attention and qcfg.act is not None:
        return ste_quantize(x, qcfg.act, None)
    return x


def _mask(q_pos, k_pos, window: Optional[int]):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def flash_attention(
    q: jax.Array,              # (B, Sq, H, D)
    k: jax.Array,              # (B, Skv, H, D)  (kv heads pre-repeated)
    v: jax.Array,              # (B, Skv, H, D)
    *,
    q_offset: int = 0,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 512,
) -> jax.Array:
    """Causal online-softmax attention, scanning KV in blocks.

    ``v`` may have a different head width than q/k (MLA's v_head_dim).
    """
    B, Sq, H, D = q.shape
    Skv, Dv = k.shape[1], v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0, (Skv, block_k)
    nblk = Skv // block_k

    qf = cot_boundary(q).astype(jnp.float32) * scale
    kb = k.reshape(B, nblk, block_k, H, D).swapaxes(0, 1)
    vb = v.reshape(B, nblk, block_k, H, Dv).swapaxes(0, 1)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = xs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf,
                            cot_boundary(k_blk).astype(jnp.float32))
        logits = _softcap(logits, softcap)
        mask = _mask(q_pos, k_pos, window)  # (Sq, blk)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1)
        acc = corr[..., None] * acc + jnp.einsum(
            "bhqk,bkhd->bhqd", p, cot_boundary(v_blk).astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((B, H, Sq), -1e30, jnp.float32),
            jnp.zeros((B, H, Sq), jnp.float32),
            jnp.zeros((B, H, Sq, Dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, Sq, H, D)


# ---------------------------------------------------------------------------
# GQA


def attn_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _qk_norm(x, gain, eps):
    x = cot_boundary(x)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps) * (1.0 + gain)
    return x * scale.astype(x.dtype)


def _shard_qkv(q, k, v, heads_divisible: bool):
    if heads_divisible:
        q = shard(q, "batch", "seq", "act_heads", None)
        k = shard(k, "batch", "seq", "act_heads", None)
        v = shard(v, "batch", "seq", "act_heads", None)
    else:
        # head count doesn't divide the model axis: sequence-parallel
        # attention. (A batch-over-full-mesh reshard variant was measured
        # in §Perf and REFUTED — the attention-section all-to-alls cost
        # 3.4x the redundancy they remove; see EXPERIMENTS.md.)
        q = shard(q, "batch", "seq_shard", None, None)
        k = shard(k, "batch", "seq", None, None)
        v = shard(v, "batch", "seq", None, None)
    return q, k, v


def attn_apply(
    p: Dict[str, Any],
    x: jax.Array,                       # (B, S, D)
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig],
    *,
    positions: jax.Array,               # (S,) absolute positions
    window: Optional[int] = None,
    theta: Optional[float] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    block_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One attention block. With ``cache``, decode/append mode (S small);
    a paged cache additionally needs the engine's ``block_table``."""
    B, S, D = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    theta = theta if theta is not None else cfg.rope_theta

    q = qeinsum("bsd,de->bse", x, dense_of(p["wq"], cfg, qcfg), qcfg)
    k = qeinsum("bsd,de->bse", x, dense_of(p["wk"], cfg, qcfg), qcfg)
    v = qeinsum("bsd,de->bse", x, dense_of(p["wv"], cfg, qcfg), qcfg)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    # positions may be (S,) or per-slot (B, S) — rope broadcasts either way
    rot = rope(positions, hd, theta)
    if rot.ndim == 3:
        rot = rot[None]  # (1, S, hd/2, 2)
    q = apply_rope(q, rot)
    k = apply_rope(k, rot)
    q, k, v = _qa(q, cfg, qcfg), _qa(k, cfg, qcfg), _qa(v, cfg, qcfg)

    if cache is None:
        # training / prefill: repeat KV to full heads and flash
        heads_div = h % model_axis_size() == 0
        rep = h // kv
        kf = jnp.repeat(k, rep, axis=2)
        vf = jnp.repeat(v, rep, axis=2)
        q, kf, vf = _shard_qkv(q, kf, vf, heads_div)
        out = flash_attention(q, kf, vf, window=window,
                              softcap=cfg.attn_logit_softcap)
        new_cache = None
    elif is_paged_cache(cache):
        assert window is None, "paged KV pools do not serve ring buffers"
        assert block_table is not None, "paged cache requires a block table"
        out, new_cache = _paged_attend(q, k, v, cache, cfg,
                                       block_table=block_table, qcfg=qcfg)
    else:
        out, cache = _decode_attend(q, k, v, cache, cfg, window=window)
        new_cache = cache

    out = out.reshape(B, S, h * hd)
    # row-parallel wo in training (attn_out -> model); serving rules resolve
    # attn_out to None, making this constraint the all-gather epilogue that
    # keeps the replicated wo contraction bitwise equal to single-device
    out = shard(out, "batch", "seq", "attn_out")
    out = qeinsum("bse,ed->bsd", out, dense_of(p["wo"], cfg, qcfg), qcfg)
    return shard(out, "batch", "seq", "embed"), new_cache


def init_kv_cache(batch: int, max_len: int, cfg: ArchConfig,
                  window: Optional[int] = None) -> Dict[str, jax.Array]:
    """Fixed-capacity KV cache; window layers allocate a ring buffer.

    With ``cfg.kv_cache_bits`` the cache stores packed LNS words (1 byte per
    element at 8 bits — half the HBM reads of bf16) plus a per-position
    per-head power-of-two scale; decode dequantizes on read.
    """
    cap = min(window, max_len) if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.kv_cache_bits:
        return {
            "k": jnp.zeros((batch, cap, kv, hd), jnp.uint8),
            "v": jnp.zeros((batch, cap, kv, hd), jnp.uint8),
            "k_scale": jnp.ones((batch, cap, kv, 1), jnp.bfloat16),
            "v_scale": jnp.ones((batch, cap, kv, 1), jnp.bfloat16),
            "idx": jnp.zeros((batch,), jnp.int32),
        }
    dt = cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, cap, kv, hd), dt),
        "v": jnp.zeros((batch, cap, kv, hd), dt),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _kv_fmt(cfg: ArchConfig) -> LNSFormat:
    from repro.core.lns import LNSFormat
    return LNSFormat(bits=cfg.kv_cache_bits, gamma=8)


def _kv_encode(x: jax.Array, cfg: ArchConfig):
    """(B,S,KV,hd) -> packed codes + per-(pos,head) scale."""
    from repro.core.lns import compute_scale, lns_encode, lns_pack
    fmt = _kv_fmt(cfg)
    scale = compute_scale(x, axis=(0, 1, 2))  # keep all but head_dim
    sign, code = lns_encode(x, fmt, scale)
    bscale = jnp.broadcast_to(scale, x.shape[:-1] + (1,)).astype(jnp.bfloat16)
    return lns_pack(sign, code, fmt), bscale


def _kv_decode(packed: jax.Array, scale: jax.Array, cfg: ArchConfig):
    from repro.core.lns import lns_unpack, lns_decode
    fmt = _kv_fmt(cfg)
    sign, code = lns_unpack(packed, fmt)
    return lns_decode(sign, code, fmt, scale.astype(jnp.float32),
                      dtype=cfg.compute_dtype)


def init_paged_kv_cache(batch: int, num_pages: int, page_size: int,
                        cfg: ArchConfig) -> Dict[str, jax.Array]:
    """Block-paged KV pool shared by all slots of one attention layer.

    ``num_pages + 1`` pages of ``page_size`` tokens each (the extra page is
    the *null* page: unused block-table entries point at it, so gathers of a
    slot's unallocated tail and writes from freed slots land in one
    sacrificial page instead of corrupting live KV). Per-slot state is just
    the write cursor ``idx``; the page mapping lives in the engine-owned
    block table threaded through ``forward`` (same page ids for every
    layer — each layer indexes its own pool with them).

    Wire format matches the dense cache: with ``cfg.kv_cache_bits`` pages
    store packed LNS words + per-(pos, head) power-of-two scales
    (``_kv_encode``), decoded on read.
    """
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    P = num_pages + 1
    if cfg.kv_cache_bits:
        return {
            "kp": jnp.zeros((P, page_size, kv, hd), jnp.uint8),
            "vp": jnp.zeros((P, page_size, kv, hd), jnp.uint8),
            "kp_scale": jnp.ones((P, page_size, kv, 1), jnp.bfloat16),
            "vp_scale": jnp.ones((P, page_size, kv, 1), jnp.bfloat16),
            "idx": jnp.zeros((batch,), jnp.int32),
        }
    dt = cfg.compute_dtype
    return {
        "kp": jnp.zeros((P, page_size, kv, hd), dt),
        "vp": jnp.zeros((P, page_size, kv, hd), dt),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def is_paged_cache(cache) -> bool:
    return isinstance(cache, dict) and "kp" in cache


def _paged_attend(q, k_new, v_new, cache, cfg: ArchConfig, *,
                  block_table: jax.Array,
                  qcfg: Optional[QuantConfig] = None):
    """Paged-pool decode/append: scatter the new KV into this slot's pages,
    then attend over the pages named by the block table.

    ``block_table`` is (B, max_pages) int32 — slot-local page index ``j``
    covers absolute positions ``[j*page_size, (j+1)*page_size)``. Unused
    entries point at the null page (see :func:`init_paged_kv_cache`), so
    out-of-range writes from recycled rows and the gathered-but-invalid
    tail are harmless (the tail is masked out before the softmax anyway).
    """
    from repro.kernels import dispatch
    B, S, h, hd = q.shape
    pool_k = cache["kp"]
    page = pool_k.shape[1]
    mp = block_table.shape[1]
    idx = cache["idx"]  # (B,) tokens already cached, per slot
    pos = idx[:, None] + jnp.arange(S)  # (B, S) absolute write positions
    pg = jnp.take_along_axis(block_table, jnp.clip(pos // page, 0, mp - 1),
                             axis=1)
    # positions past the slot's page span (right-padded prefill tails,
    # stale cursors of recycled rows) must not clamp onto a live page:
    # point them out of bounds and let the scatter drop them
    pg = jnp.where(pos < mp * page, pg, pool_k.shape[0])
    off = pos % page

    quant = bool(cfg.kv_cache_bits)
    if quant:
        pk_new, sk_new = _kv_encode(k_new, cfg)
        pv_new, sv_new = _kv_encode(v_new, cfg)
        store = (("kp", pk_new), ("vp", pv_new),
                 ("kp_scale", sk_new), ("vp_scale", sv_new))
    else:
        store = (("kp", k_new), ("vp", v_new))

    new_cache = dict(cache)
    fpg, foff = pg.reshape(-1), off.reshape(-1)
    for key, new in store:
        flat = new.reshape((B * S,) + new.shape[2:])
        new_cache[key] = cache[key].at[fpg, foff].set(
            flat.astype(cache[key].dtype), mode="drop")
        # pool pages stay head-sharded across the mesh model axis (pages and
        # page offsets are shard-local views of one logical block table)
        new_cache[key] = shard(new_cache[key], None, None, "kv_heads", None)
    new_cache["idx"] = idx + S

    out = dispatch.paged_attend(
        q, new_cache["kp"], new_cache["vp"],
        new_cache.get("kp_scale"), new_cache.get("vp_scale"),
        block_table, idx + S,
        fmt=_kv_fmt(cfg) if quant else None,
        softcap=cfg.attn_logit_softcap,
        sm_scale=1.0 / math.sqrt(hd))
    return out.astype(q.dtype), new_cache


def _row_insert(buf, new, idx):
    """Per-row append: row b of ``new`` lands at ``buf[b, idx[b]:...]``.

    Each batch row is an independent serving slot with its own write
    cursor — the continuous-batching engine relies on this to hold
    sequences of different lengths in one cache."""
    def one(b, n, i):
        return jax.lax.dynamic_update_slice(b, n, (i,) + (0,) * (b.ndim - 1))
    return jax.vmap(one)(buf, new, idx)


def _decode_attend(q, k_new, v_new, cache, cfg: ArchConfig, *,
                   window: Optional[int]):
    """Append S new positions to the cache and attend over it (plain
    softmax; cache seq is sharded over the mesh => split-KV decode).

    ``cache["idx"]`` is (B,): every batch row (= serving slot) has its own
    sequence length, so a freed slot can restart from position 0 while its
    neighbours keep decoding."""
    B, S, h, hd = q.shape
    kv = cfg.num_kv_heads
    idx = cache["idx"]  # (B,) int32: tokens already cached, per slot
    cap = cache["k"].shape[1]
    slot = jnp.arange(cap)
    q_abs = idx[:, None] + jnp.arange(S)  # (B, S) absolute query positions

    quant = bool(cfg.kv_cache_bits)
    if quant:  # packed-LNS cache: encode the new keys once (beyond-paper)
        pk_new, sk_new = _kv_encode(k_new, cfg)
        pv_new, sv_new = _kv_encode(v_new, cfg)
        k_old = _kv_decode(cache["k"], cache["k_scale"], cfg)
        v_old = _kv_decode(cache["v"], cache["v_scale"], cfg)
        store_k, store_v = pk_new, pv_new
    else:
        k_old, v_old = cache["k"], cache["v"]
        store_k, store_v = k_new, v_new

    new_cache = dict(cache)
    if window:
        # Attend over [old ring contents ∪ new keys]: inserting first would
        # evict keys that earlier in-call queries still need. Ring slot s
        # holds absolute position p ≡ s (mod cap), p <= idx-1.
        last_prev = idx[:, None] - 1                       # (B, 1)
        abs_prev = last_prev - ((last_prev - slot[None, :]) % cap)  # (B, cap)
        k_att = jnp.concatenate([k_old, k_new], axis=1)
        v_att = jnp.concatenate([v_old, v_new], axis=1)
        abs_pos = jnp.concatenate([abs_prev, q_abs], axis=1)  # (B, cap+S)
        valid = jnp.concatenate(
            [abs_prev >= 0, jnp.ones((B, S), bool)], axis=1)

        def ring_update(buf, new):
            if S >= cap:
                start = (idx + S - cap) % cap
                return jax.vmap(
                    lambda n, s: jnp.roll(n, s, axis=0))(new[:, -cap:], start)
            slots = (idx[:, None] + jnp.arange(S)) % cap  # (B, S), may wrap
            return jax.vmap(lambda b, sl, n: b.at[sl].set(n))(buf, slots, new)

        new_cache["k"] = ring_update(cache["k"], store_k)
        new_cache["v"] = ring_update(cache["v"], store_v)
        if quant:
            new_cache["k_scale"] = ring_update(cache["k_scale"], sk_new)
            new_cache["v_scale"] = ring_update(cache["v_scale"], sv_new)
    else:
        new_cache["k"] = _row_insert(cache["k"], store_k, idx)
        new_cache["v"] = _row_insert(cache["v"], store_v, idx)
        if quant:
            new_cache["k_scale"] = _row_insert(cache["k_scale"], sk_new, idx)
            new_cache["v_scale"] = _row_insert(cache["v_scale"], sv_new, idx)
            k_att = _kv_decode(new_cache["k"], new_cache["k_scale"], cfg)
            v_att = _kv_decode(new_cache["v"], new_cache["v_scale"], cfg)
        else:
            k_att, v_att = new_cache["k"], new_cache["v"]
        abs_pos = jnp.broadcast_to(slot[None, :], (B, cap))
        valid = slot[None, :] < (idx[:, None] + S)
    # kv_seq wins the model axis under training rules (split-KV decode);
    # serving rules map kv_seq -> None so the same annotation head-shards
    for key in (("k", "v", "k_scale", "v_scale") if quant else ("k", "v")):
        new_cache[key] = shard(new_cache[key],
                               "batch", "kv_seq", "kv_heads", None)

    rep = h // kv
    kf = jnp.repeat(k_att, rep, axis=2)
    vf = jnp.repeat(v_att, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) / math.sqrt(hd)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    mask = valid[:, None, :] & (abs_pos[:, None, :] <= q_abs[:, :, None])
    if window:
        mask &= abs_pos[:, None, :] > (q_abs[:, :, None] - window)
    logits = jnp.where(mask[:, None], logits, -1e30)  # (B,1,S,K) vs (B,h,S,K)
    p_attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p_attn, vf.astype(jnp.float32))
    new_cache["idx"] = idx + S
    return out.astype(q.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)


def mla_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rpe, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 6)
    return {
        "q_down": dense_init(ks[0], d, qr, dt),
        "q_norm": jnp.zeros((qr,), jnp.float32),
        "q_up": dense_init(ks[1], qr, h * (nope + rpe), dt),
        "kv_down": dense_init(ks[2], d, kvr + rpe, dt),
        "kv_norm": jnp.zeros((kvr,), jnp.float32),
        "kv_up": dense_init(ks[3], kvr, h * (nope + vd), dt),
        "wo": dense_init(ks[4], h * vd, d, dt),
    }


def mla_apply(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig],
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = x.shape
    h = cfg.num_heads
    nope, rpe, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    from repro.models.layers import rms_norm  # local import to avoid cycle

    ql = qeinsum("bsd,dr->bsr", x, dense_of(p["q_down"], cfg, qcfg), qcfg)
    ql = rms_norm(ql, p["q_norm"], cfg.norm_eps)
    q = qeinsum("bsr,re->bse", ql, dense_of(p["q_up"], cfg, qcfg), qcfg)
    q = q.reshape(B, S, h, nope + rpe)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kvd = qeinsum("bsd,dr->bsr", x, dense_of(p["kv_down"], cfg, qcfg), qcfg)
    c_kv, k_rope = kvd[..., :kvr], kvd[..., kvr:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)

    rot = rope(positions, rpe, cfg.rope_theta)
    if rot.ndim == 3:
        rot = rot[None]
    q_rope = apply_rope(q_rope, rot)
    k_rope = apply_rope(k_rope[:, :, None, :], rot)[:, :, 0, :]  # (B,S,rpe)

    if cache is None:
        kv_up = dense_of(p["kv_up"], cfg, qcfg)
        kv = qeinsum("bsr,re->bse", c_kv, kv_up, qcfg).reshape(B, S, h, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, rpe))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq, k, v = _qa(qq, cfg, qcfg), _qa(k, cfg, qcfg), _qa(v, cfg, qcfg)
        heads_div = h % model_axis_size() == 0
        qq, k, v = _shard_qkv(qq, k, v, heads_div)
        out = flash_attention(qq, k, v, scale=1.0 / math.sqrt(nope + rpe))
        new_cache = None
    else:
        # absorbed decode folds kv_up into q/ctx einsums: dense view needed
        out, new_cache = _mla_decode(q_nope, q_rope, c_kv, k_rope,
                                     decoded_of(p["kv_up"], cfg, qcfg),
                                     cache, cfg)
    out = out.reshape(B, S, h * vd)
    out = qeinsum("bse,ed->bsd", out, dense_of(p["wo"], cfg, qcfg), qcfg)
    return shard(out, "batch", "seq", "embed"), new_cache


def init_mla_cache(batch: int, max_len: int, cfg: ArchConfig):
    dt = cfg.compute_dtype
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _mla_decode(q_nope, q_rope, c_kv_new, k_rope_new, kv_up, cache,
                cfg: ArchConfig):
    """Absorbed-form MLA decode: cache holds (c_kv, k_rope) only.
    ``cache["idx"]`` is (B,) — per-slot lengths, as in ``_decode_attend``."""
    B, S, h, nope = q_nope.shape
    kvr, vd = cfg.kv_lora_rank, cfg.v_head_dim
    idx = cache["idx"]  # (B,)
    ck = _row_insert(cache["c_kv"], c_kv_new, idx)
    kr = _row_insert(cache["k_rope"], k_rope_new, idx)
    ck = shard(ck, "batch", "kv_seq", None)
    kr = shard(kr, "batch", "kv_seq", None)
    cap = ck.shape[1]

    # absorb: q_nope (B,S,h,nope) x kv_up_k (kvr, h, nope) -> (B,S,h,kvr)
    kv_up_r = kv_up.reshape(kvr, h, nope + vd)
    w_k, w_v = kv_up_r[..., :nope], kv_up_r[..., nope:]
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))
    logits = (jnp.einsum("bshr,bkr->bhsk", q_abs, ck.astype(jnp.float32))
              + jnp.einsum("bshr,bkr->bhsk", q_rope.astype(jnp.float32),
                           kr.astype(jnp.float32)))
    logits = logits / math.sqrt(nope + cfg.qk_rope_dim)
    slot = jnp.arange(cap)
    q_pos = idx[:, None] + jnp.arange(S)  # (B, S)
    mask = slot[None, None, :] <= q_pos[:, :, None]  # (B, S, cap)
    logits = jnp.where(mask[:, None], logits, -1e30)
    p_attn = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhsk,bkr->bshr", p_attn, ck.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", ctx, w_v.astype(jnp.float32))
    return out.astype(q_nope.dtype), {"c_kv": ck, "k_rope": kr, "idx": idx + S}
