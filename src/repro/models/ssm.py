"""Mamba2 (SSD) block — chunked scan for training, O(1)-state decode.

The selective-state recurrence  h_t = exp(dt_t·A)·h_{t-1} + dt_t·x_t·B_t^T,
y_t = C_t·h_t + D·x_t  is computed chunk-parallel: quadratic masked-decay
attention within chunks of ``cfg.ssm_chunk`` tokens plus a cross-chunk state
scan. All projections are quantized GEMMs; the recurrence itself is the
paper's full-precision-accumulator analogue and stays fp32 (DESIGN.md §5).

Single group (B/C shared across heads), depthwise causal conv of width
``ssm_conv_width`` implemented as a sum of shifts.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum
from repro.distributed.sharding import shard
from repro.models.common import ArchConfig, dense_init
from repro.models.layers import decoded_of, dense_of, rms_norm

__all__ = ["mamba_init", "mamba_apply", "init_mamba_state"]


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_in // p
    n = cfg.ssm_state_dim
    return d_in, h, p, n


def mamba_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    w = cfg.ssm_conv_width
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 7)
    conv_dim = d_in + 2 * n
    return {
        # separate projections (z gate, x, B, C, dt) so the wide ones shard
        # over the model axis without resharding a fused output split
        "z_proj": dense_init(ks[0], d, d_in, dt),
        "x_proj": dense_init(ks[1], d, d_in, dt),
        "b_proj": dense_init(ks[2], d, n, dt),
        "c_proj": dense_init(ks[3], d, n, dt),
        "dt_proj": dense_init(ks[4], d, h, dt),
        # depthwise causal conv, one weight block per stream (x, B, C) so
        # the sharded x stream never concatenates with the replicated B/C
        "conv_wx": jax.random.normal(ks[5], (w, d_in), jnp.float32) * (w ** -0.5),
        "conv_wb": jax.random.normal(jax.random.fold_in(ks[5], 1), (w, n), jnp.float32) * (w ** -0.5),
        "conv_wc": jax.random.normal(jax.random.fold_in(ks[5], 2), (w, n), jnp.float32) * (w ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[6], d_in, d, dt),
    }


def init_mamba_state(batch: int, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d_in, h, p, n = _dims(cfg)
    w = cfg.ssm_conv_width - 1
    return {
        "conv_x": jnp.zeros((batch, w, d_in), jnp.float32),
        "conv_b": jnp.zeros((batch, w, n), jnp.float32),
        "conv_c": jnp.zeros((batch, w, n), jnp.float32),
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 prefix: Optional[jax.Array]):
    """Depthwise causal conv as a sum of shifted slices. xbc: (B,S,C)."""
    width = w.shape[0]
    if prefix is None:
        pad = jnp.zeros_like(xbc[:, : width - 1])
    else:
        pad = prefix.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, S+w-1, C)
    S = xbc.shape[1]
    out = sum(full[:, i:i + S] * w[i] for i in range(width)) + b
    new_prefix = full[:, -(width - 1):] if width > 1 else None
    return jax.nn.silu(out), new_prefix


def mamba_apply(
    p: Dict[str, Any],
    x: jax.Array,                 # (B, S, D)
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig],
    state: Optional[Dict[str, jax.Array]] = None,
):
    """Returns (out (B,S,D), new_state or None)."""
    B, S, D = x.shape
    d_in, H, P, N = _dims(cfg)

    z = qeinsum("bsd,de->bse", x, dense_of(p["z_proj"], cfg, qcfg), qcfg)
    xin = qeinsum("bsd,de->bse", x, dense_of(p["x_proj"], cfg, qcfg), qcfg)
    bin_ = qeinsum("bsd,dn->bsn", x, dense_of(p["b_proj"], cfg, qcfg), qcfg)
    cin = qeinsum("bsd,dn->bsn", x, dense_of(p["c_proj"], cfg, qcfg), qcfg)
    dt_raw = qeinsum("bsd,dh->bsh", x, dense_of(p["dt_proj"], cfg, qcfg), qcfg)
    z = shard(z, "batch", "seq", "ssm_inner")
    xin = shard(xin, "batch", "seq", "ssm_inner")
    bias_x, bias_b, bias_c = jnp.split(p["conv_b"], [d_in, d_in + N])
    pre = state if state is not None else {}
    # depthwise conv weights are consumed as shifted slices, not GEMMs:
    # dense view per layer (2-D packed leaves otherwise stay packed)
    xs, new_cx = _causal_conv(cot_boundary(xin).astype(jnp.float32),
                              decoded_of(p["conv_wx"], cfg, qcfg), bias_x,
                              pre.get("conv_x"))
    Bv, new_cb = _causal_conv(cot_boundary(bin_).astype(jnp.float32),
                              decoded_of(p["conv_wb"], cfg, qcfg), bias_b,
                              pre.get("conv_b"))
    Cv, new_cc = _causal_conv(cot_boundary(cin).astype(jnp.float32),
                              decoded_of(p["conv_wc"], cfg, qcfg), bias_c,
                              pre.get("conv_c"))
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(cot_boundary(dt_raw).astype(jnp.float32)
                         + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    dA = dt * A                                                       # log-decay

    if state is None:
        y, last_state = _ssd_chunked(xs, dt, dA, Bv, Cv, cfg.ssm_chunk)
        new_state = None
    else:
        h0 = state["ssm"]
        # sequential step(s) — decode path, S is small (typically 1)
        def step(h, inp):
            xt, dtt, dat, bt, ct = inp
            h = jnp.exp(dat)[:, :, None, None] * h + jnp.einsum(
                "bhp,bn,bh->bhpn", xt, bt, dtt)
            y = jnp.einsum("bhpn,bn->bhp", h, ct)
            return h, y
        inps = (xs.swapaxes(0, 1), dt.swapaxes(0, 1), dA.swapaxes(0, 1),
                Bv.swapaxes(0, 1), Cv.swapaxes(0, 1))
        h_last, ys = jax.lax.scan(step, h0, inps)
        y = ys.swapaxes(0, 1)  # (B,S,H,P)
        new_state = {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc,
                     "ssm": h_last}

    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = qeinsum("bse,ed->bsd", y, dense_of(p["out_proj"], cfg, qcfg), qcfg)
    return shard(out, "batch", "seq", "embed"), new_state


def _ssd_chunked(xs, dt, dA, Bv, Cv, Q: int):
    """Chunk-parallel SSD. xs:(B,S,H,P) dt,dA:(B,S,H) Bv,Cv:(B,S,N)."""
    B, S, H, P = xs.shape
    N = Bv.shape[-1]
    Q = min(Q, S)
    pad = (-S) % Q
    if pad:  # zero padding is inert: dA=0 (decay 1), dt·x=0 (no state add)
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, h = _ssd_chunked(z(xs), z(dt), z(dA), z(Bv), z(Cv), Q)
        return y[:, :S], h
    nc = S // Q

    def chunkify(a):
        return a.reshape((B, nc, Q) + a.shape[2:]).swapaxes(0, 1)

    xc, dtc, dac = chunkify(xs), chunkify(dt), chunkify(dA)
    bc, cc = chunkify(Bv), chunkify(Cv)

    def chunk_step(h, inp):
        xq, dtq, daq, bq, cq = inp  # (B,Q,...)
        l = jnp.cumsum(daq, axis=1)                     # (B,Q,H) inclusive
        dtx = xq * dtq[..., None]                       # (B,Q,H,P)
        # intra-chunk: masked decay attention
        g = jnp.einsum("bqn,bkn->bqk", cq, bq)          # (B,Q,Q)
        ldiff = l[:, :, None, :] - l[:, None, :, :]     # (B,Q,K,H) l_q - l_k
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        m = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", g, m, dtx)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cq, h, jnp.exp(l))
        # chunk-end state
        ltot = l[:, -1]                                  # (B,H)
        decay_rest = jnp.exp(ltot[:, None] - l)          # (B,Q,H)
        s_chunk = jnp.einsum("bkhp,bkn,bkh->bhpn", dtx, bq, decay_rest)
        h_new = jnp.exp(ltot)[:, :, None, None] * h + s_chunk
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, dac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y, h_last
