"""Mixture-of-Experts: top-k router + shared experts + two dispatch paths.

* ``sort``      — sort-based scatter dispatch (MaxText-style): tokens are
  ranked within their expert via an argsort over expert ids, scattered into
  a per-expert capacity buffer (E, C, D), processed, and gathered back.
  O(T·K·D) data movement — no O(T·E·C) one-hot einsums, which at E=384
  (kimi-k2) would dwarf the expert FLOPs themselves. Under pjit the
  token-order -> expert-order scatter lowers to the EP all-to-all.
* ``dense_ref`` — every token through every expert, masked combine. O(E)
  FLOPs: only for CPU-scale smoke tests and as the correctness oracle for
  the sort path.

The router stays fp32 (accuracy-critical, tiny — the same carve-out the
paper makes for batch-norm); expert GEMMs quantize like dense MLPs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum
from repro.distributed.sharding import current_mesh, shard
from repro.models.common import ArchConfig, dense_init
from repro.models.layers import ACT_FNS, decoded_of, dense_of

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_up": jax.random.normal(ks[1], (e, d, f), dt) * (d ** -0.5),
        "w_gate": jax.random.normal(ks[2], (e, d, f), dt) * (d ** -0.5),
        "w_down": jax.random.normal(ks[3], (e, f, d), dt) * (f ** -0.5),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = {
            "up": dense_init(ks[4], d, fs, dt),
            "gate": dense_init(ks[5], d, fs, dt),
            "down": dense_init(ks[6], fs, d, dt),
        }
    return p


def _router(p, x, cfg: ArchConfig, qcfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing: (gates (T,K), expert ids (T,K), aux loss scalar)."""
    T = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", cot_boundary(x).astype(jnp.float32),
                        decoded_of(p["router"], cfg, qcfg))
    probs = jax.nn.softmax(logits, axis=-1).reshape(T, cfg.num_experts)
    top_p, top_i = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance loss from bincounts (no (T,K,E) one-hot)
    me = jnp.mean(probs, axis=0)
    ce = jnp.bincount(top_i.reshape(-1), length=cfg.num_experts
                      ).astype(jnp.float32) / (T * cfg.experts_per_token)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return top_p, top_i, aux


def _expert_ffn(p, xe, cfg: ArchConfig, qcfg):
    """xe: (..., E, C, D) tokens grouped per expert -> same shape."""
    act = ACT_FNS[cfg.act_fn]
    w_up = dense_of(p["w_up"], cfg, qcfg)
    w_gate = dense_of(p["w_gate"], cfg, qcfg)
    w_down = dense_of(p["w_down"], cfg, qcfg)
    if xe.ndim == 4:  # grouped (G, E, C, D)
        up = qeinsum("gecd,edf->gecf", xe, w_up, qcfg)
        gate = qeinsum("gecd,edf->gecf", xe, w_gate, qcfg)
        # note: "moe_ff" is the *weight* FSDP axis; the activation groups
        # already occupy the data axis, so the hidden dim stays unsharded
        up = shard(act(gate) * up, "batch", "experts", None, None)
        return qeinsum("gecf,efd->gecd", up, w_down, qcfg)
    up = qeinsum("ecd,edf->ecf", xe, w_up, qcfg)
    gate = qeinsum("ecd,edf->ecf", xe, w_gate, qcfg)
    up = shard(act(gate) * up, "experts", None, "moe_ff")
    return qeinsum("ecf,efd->ecd", up, w_down, qcfg)


def moe_apply(p, x, cfg: ArchConfig, qcfg: Optional[QuantConfig]):
    """Returns (out (B,S,D), aux_loss scalar)."""
    top_p, top_i, aux = _router(p, x, cfg, qcfg)

    if cfg.moe_dispatch == "dense_ref":
        out = _dense_ref(p, x, top_p, top_i, cfg, qcfg)
    else:
        out = _sorted_dispatch(p, x, top_p, top_i, cfg, qcfg)

    if cfg.num_shared_experts:
        sp = p["shared"]
        act = ACT_FNS[cfg.act_fn]
        up = qeinsum("bsd,df->bsf", x, dense_of(sp["up"], cfg, qcfg), qcfg)
        gate = qeinsum("bsd,df->bsf", x, dense_of(sp["gate"], cfg, qcfg), qcfg)
        out = out + qeinsum("bsf,fd->bsd", act(gate) * up,
                            dense_of(sp["down"], cfg, qcfg), qcfg)
    return shard(out, "batch", "seq", "embed"), aux


def _dense_ref(p, x, top_p, top_i, cfg, qcfg):
    """O(E) oracle: all tokens through all experts, weighted combine."""
    B, S, D = x.shape
    T, E = B * S, cfg.num_experts
    xe = jnp.broadcast_to(x.reshape(1, T, D), (E, T, D))
    ye = _expert_ffn(p, xe, cfg, qcfg)  # (E, T, D)
    w = jnp.zeros((T, E), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], top_i].add(top_p)
    return jnp.einsum("etd,te->td", ye.astype(jnp.float32), w
                      ).reshape(B, S, D).astype(x.dtype)


def _dp_groups() -> int:
    """Number of data-parallel shards (the dispatch groups)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _group_routing_maps(flat_e, gates, E: int, C: int, K: int):
    """Per-group routing index maps (runs under vmap over groups).

    All heavy data movement downstream is GATHERS driven by these maps;
    the only scatters are over (T_g·K,)-sized int32 index vectors, which
    GSPMD replicates cheaply (D-wide scatter-adds would otherwise lower to
    giant cross-shard reductions).

    Returns:
      slot_src  (E*C,)   source token for each expert-capacity slot
      slot_fill (E*C,)   whether the slot is occupied
      inv       (T_g, K) capacity slot assigned to each (token, k)
      gate_inv  (T_g, K) gate, zeroed for dropped assignments
      slot_gate (E*C,)   gate of the slot's occupant (0 if unfilled)
    """
    TK = flat_e.shape[0]
    tok = jnp.arange(TK, dtype=jnp.int32) // K
    counts = jax.ops.segment_sum(jnp.ones((TK,), jnp.int32), flat_e,
                                 num_segments=E)
    starts = jnp.cumsum(counts) - counts
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    rank = jnp.arange(TK, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
    keep = rank < C
    dst = jnp.where(keep, e_sorted * C + jnp.clip(rank, 0, C - 1), E * C)
    slot_src = jnp.zeros((E * C + 1,), jnp.int32).at[dst].set(tok[order])
    slot_fill = jnp.zeros((E * C + 1,), bool).at[dst].set(keep)
    slot_gate = jnp.zeros((E * C + 1,), gates.dtype).at[dst].set(
        gates[order] * keep)
    inv = jnp.zeros((TK,), jnp.int32).at[order].set(dst)
    gate_inv = (jnp.zeros((TK,), gates.dtype).at[order]
                .set(gates[order] * keep))
    return (slot_src[:E * C], slot_fill[:E * C],
            inv.reshape(TK // K, K), gate_inv.reshape(TK // K, K),
            slot_gate[:E * C])


def _take_rows(a, idx):
    return jnp.take_along_axis(a, idx[..., None], axis=1, mode="clip")


@jax.custom_vjp
def _dispatch_gather(xg, slot_src, slot_fill, inv, keep):
    """xe[g,s] = xg[g, slot_src[g,s]] (0 if unfilled).

    The automatic transpose of this gather is a cross-shard scatter-add that
    XLA lowers to giant all-gathers; the hand-written vjp uses the *dual*
    map instead: dxg[g,t] = Σ_k dxe[g, inv[g,t,k]] — another gather.
    """
    xe = _take_rows(xg, slot_src)
    return jnp.where(slot_fill[..., None], xe, 0)


def _dispatch_fwd(xg, slot_src, slot_fill, inv, keep):
    return _dispatch_gather(xg, slot_src, slot_fill, inv, keep), \
        (slot_src, slot_fill, inv, keep)


def _dispatch_bwd(res, dxe):
    slot_src, slot_fill, inv, keep = res
    G = inv.shape[0]
    d = _take_rows(dxe, inv.reshape(G, -1))          # (G, Tg*K, D)
    d = d.reshape(inv.shape + (dxe.shape[-1],))      # (G, Tg, K, D)
    dxg = jnp.sum(d * keep[..., None].astype(d.dtype), axis=2)
    return dxg, None, None, None, None


_dispatch_gather.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine_gather(ye, inv, gate, slot_src, slot_gate):
    """out[g,t] = Σ_k ye[g, inv[g,t,k]] · gate[g,t,k].

    vjp w.r.t. ye via the dual map: dye[g,s] = dout[g, slot_src[g,s]] ·
    slot_gate[g,s] — a gather, not a scatter-add.
    """
    taken = _take_rows(ye, inv.reshape(inv.shape[0], -1))
    taken = taken.reshape(inv.shape + (ye.shape[-1],))
    return jnp.sum(taken * gate[..., None].astype(taken.dtype), axis=2)


def _combine_fwd(ye, inv, gate, slot_src, slot_gate):
    return _combine_gather(ye, inv, gate, slot_src, slot_gate), \
        (ye, inv, gate, slot_src, slot_gate)


def _combine_bwd(res, dout):
    ye, inv, gate, slot_src, slot_gate = res
    dye = _take_rows(dout, slot_src) * slot_gate[..., None].astype(dout.dtype)
    taken = _take_rows(ye, inv.reshape(inv.shape[0], -1))
    taken = taken.reshape(inv.shape + (ye.shape[-1],))
    dgate = jnp.sum(taken * dout[:, :, None, :].astype(taken.dtype), axis=-1)
    return dye, None, dgate.astype(gate.dtype), None, None


_combine_gather.defvjp(_combine_fwd, _combine_bwd)


def _sorted_dispatch(p, x, top_p, top_i, cfg, qcfg):
    """Grouped sort dispatch, capacity C_g = cf·T_g·K/E per group."""
    B, S, D = x.shape
    T, E, K = B * S, cfg.num_experts, cfg.experts_per_token
    G = _dp_groups()
    if T % G:
        G = 1
    Tg = T // G
    C = max(int(cfg.capacity_factor * Tg * K / E), 1)

    xg = shard(x.reshape(G, Tg, D), "batch", None, None)
    eg = top_i.reshape(G, Tg * K)
    gg = top_p.reshape(G, Tg * K).astype(x.dtype)

    slot_src, slot_fill, inv, gate_inv, slot_gate = jax.vmap(
        lambda e, g: _group_routing_maps(e, g, E, C, K))(eg, gg)
    keep = gate_inv != 0

    xe = _dispatch_gather(xg, slot_src, slot_fill, inv, keep)
    xe = shard(xe.reshape(G, E, C, D), "batch", "experts", None, None)
    ye = _expert_ffn(p, xe, cfg, qcfg)  # (G, E, C, D)
    ye = shard(ye, "batch", "experts", None, None).reshape(G, E * C, D)

    out = _combine_gather(ye, inv.reshape(G, Tg, K), gate_inv.reshape(G, Tg, K),
                          slot_src, slot_gate)
    return shard(out.reshape(B, S, D), "batch", None, None).astype(x.dtype)
