"""RWKV6 (Finch) block — data-dependent decay linear attention.

Time-mix: per-channel decays ``w_t = exp(-exp(w0 + lora(x)))`` (the Finch
contribution: decay depends on the token), bonus ``u``, receptance/key/value
/gate projections; the WKV recurrence

    out_t = r_t · (S_{t-1} + diag(u)·k_t^T v_t)
    S_t   = diag(w_t)·S_{t-1} + k_t^T v_t

runs chunk-parallel for training (within-chunk masked decay products,
cross-chunk state scan, fp32 state — the paper's high-precision-accumulator
analogue) and as an O(1)-state step for decode. Channel-mix is the squared-
relu RWKV FFN. Token-shift mixing uses static learned coefficients
(deviation from the 5 dynamic LoRAs of the reference impl, noted in
DESIGN.md; the *decay* LoRA — the headline Finch feature — is kept).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum
from repro.distributed.sharding import shard
from repro.models.common import ArchConfig, dense_init
from repro.models.layers import decoded_of, dense_of

__all__ = ["rwkv_init", "rwkv_apply", "init_rwkv_state"]

_DECAY_LORA = 64


def rwkv_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    h = d // cfg.ssm_head_dim
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mix": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,g,w shift mixes
        "w0": jnp.zeros((d,), jnp.float32),
        "w_lora_a": dense_init(ks[0], d, _DECAY_LORA, jnp.float32),
        "w_lora_b": jnp.zeros((_DECAY_LORA, d), jnp.float32),
        "u": jnp.zeros((d,), jnp.float32),
        "wr": dense_init(ks[1], d, d, dt),
        "wk": dense_init(ks[2], d, d, dt),
        "wv": dense_init(ks[3], d, d, dt),
        "wg": dense_init(ks[4], d, d, dt),
        "wo": dense_init(ks[5], d, d, dt),
        "ln_x": jnp.zeros((h, cfg.ssm_head_dim), jnp.float32),  # per-head norm
        # channel-mix
        "mix_cm": 0.5 * jnp.ones((2, d), jnp.float32),
        "ck": dense_init(ks[6], d, f, dt),
        "cv": dense_init(ks[7], f, d, dt),
        "cr": dense_init(ks[8], d, d, dt),
    }


def init_rwkv_state(batch: int, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h, p = d // cfg.ssm_head_dim, cfg.ssm_head_dim
    return {
        "shift_tm": jnp.zeros((batch, d), jnp.float32),
        "shift_cm": jnp.zeros((batch, d), jnp.float32),
        "S": jnp.zeros((batch, h, p, p), jnp.float32),
    }


def _shifted(x, prev):
    """Token shift: x_{t-1} (prev carries across decode steps)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv_apply(
    p: Dict[str, Any],
    x: jax.Array,                  # (B, S, D) — time-mix input (pre-normed)
    x_cm: jax.Array,               # (B, S, D) — channel-mix input
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig],
    state: Optional[Dict[str, jax.Array]] = None,
):
    """Returns ((tm_out, cm_out), new_state or None).

    The decoder stack calls time-mix and channel-mix around separate norms;
    both are computed here to share the state dict.
    """
    B, S, D = x.shape
    hn, hd = D // cfg.ssm_head_dim, cfg.ssm_head_dim

    prev_tm = state["shift_tm"] if state is not None else None
    xs = _shifted(x, prev_tm)
    # elementwise mixing/LoRA/norm params: dense views (2-D packed leaves)
    mix = decoded_of(p["mix"], cfg, qcfg)[:, None, None, :]  # (5,1,1,D)
    xr, xk, xv, xg, xw = [x + (xs - x) * mix[i] for i in range(5)]

    r = qeinsum("bsd,de->bse", xr, dense_of(p["wr"], cfg, qcfg), qcfg)
    k = qeinsum("bsd,de->bse", xk, dense_of(p["wk"], cfg, qcfg), qcfg)
    v = qeinsum("bsd,de->bse", xv, dense_of(p["wv"], cfg, qcfg), qcfg)
    g = jax.nn.silu(qeinsum("bsd,de->bse", xg, dense_of(p["wg"], cfg, qcfg), qcfg))
    # Finch data-dependent decay (fp32 lora — tiny, accuracy-critical).
    # log-decay clamped to >= -3.5/step so the chunked form's exp(-lcum)
    # stays finite in fp32 (chunk 16 ⇒ |lcum| <= 56); faster decays are
    # numerically indistinguishable from 0 after two steps anyway.
    lora = jnp.tanh(cot_boundary(xw).astype(jnp.float32)
                    @ decoded_of(p["w_lora_a"], cfg, qcfg)
                    ) @ decoded_of(p["w_lora_b"], cfg, qcfg)
    logw = -jnp.exp(jnp.clip(p["w0"] + lora, -8.0, 1.25))  # log decay < 0

    rh = cot_boundary(r).astype(jnp.float32).reshape(B, S, hn, hd)
    kh = cot_boundary(k).astype(jnp.float32).reshape(B, S, hn, hd)
    vh = cot_boundary(v).astype(jnp.float32).reshape(B, S, hn, hd)
    wh = logw.reshape(B, S, hn, hd)
    uh = p["u"].reshape(hn, hd)

    if state is None:
        y, s_last = _wkv_chunked(rh, kh, vh, wh, uh, cfg.rwkv_chunk)
        new_state = None
    else:
        def step(s, inp):
            rt, kt, vt, wt = inp  # (B,H,P)
            att = s + uh[None, :, :, None] * kt[..., None] * vt[..., None, :]
            y = jnp.einsum("bhp,bhpq->bhq", rt, att)
            s = jnp.exp(wt)[..., None] * s + kt[..., None] * vt[..., None, :]
            return s, y
        inps = tuple(a.swapaxes(0, 1) for a in (rh, kh, vh, wh))
        s_last, ys = jax.lax.scan(step, state["S"], inps)
        y = ys.swapaxes(0, 1)
        new_state = dict(state, S=s_last, shift_tm=x[:, -1].astype(jnp.float32))

    # per-head group norm, gate, output projection
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5) * (
        1.0 + decoded_of(p["ln_x"], cfg, qcfg))
    y = (y.reshape(B, S, D) * g.astype(jnp.float32)).astype(x.dtype)
    tm_out = qeinsum("bsd,de->bse", y, dense_of(p["wo"], cfg, qcfg), qcfg)
    tm_out = shard(tm_out, "batch", "seq", "embed")

    # channel mix
    prev_cm = state["shift_cm"] if state is not None else None
    xcs = _shifted(x_cm, prev_cm)
    mixc = decoded_of(p["mix_cm"], cfg, qcfg)[:, None, None, :]
    xck = x_cm + (xcs - x_cm) * mixc[0]
    xcr = x_cm + (xcs - x_cm) * mixc[1]
    kk = qeinsum("bsd,df->bsf", xck, dense_of(p["ck"], cfg, qcfg), qcfg)
    kk = shard(jnp.square(jax.nn.relu(kk)), "batch", "seq", "act_ff")
    vv = qeinsum("bsf,fd->bsd", kk, dense_of(p["cv"], cfg, qcfg), qcfg)
    rr = jax.nn.sigmoid(
        qeinsum("bsd,de->bse", xcr, dense_of(p["cr"], cfg, qcfg), qcfg))
    cm_out = shard(rr * vv, "batch", "seq", "embed")
    if new_state is not None:
        new_state["shift_cm"] = x_cm[:, -1].astype(jnp.float32)
    return (tm_out, cm_out), new_state


def _wkv_chunked(r, k, v, logw, u, Q: int):
    """Chunked WKV. r,k,v,logw: (B,S,H,P); u: (H,P). fp32 state."""
    B, S, H, P = r.shape
    Q = min(Q, S)
    pad = (-S) % Q
    if pad:  # zero padding is inert: logw=0 (decay 1), k=v=0 (no state add)
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad), (0, 0), (0, 0)])
        y, s = _wkv_chunked(z(r), z(k), z(v), z(logw), u, Q)
        return y[:, :S], s
    nc = S // Q

    def chunkify(a):
        return a.reshape(B, nc, Q, H, P).swapaxes(0, 1)

    rc, kc, vc, wc = chunkify(r), chunkify(k), chunkify(v), chunkify(logw)

    def chunk_step(s, inp):
        rq, kq, vq, wq = inp                       # (B,Q,H,P)
        lcum = jnp.cumsum(wq, axis=1)              # inclusive cumulative log-decay
        # decay from k-step s (exclusive) to query step t-1: exp(lcum_{t-1}-lcum_s)
        lq_prev = lcum - wq                        # cumulative up to t-1
        # intra-chunk attention A[t,s] = Σ_p r_t,p k_s,p exp(lq_prev_t - lcum_s)
        rd = rq * jnp.exp(lq_prev)
        kd = kq * jnp.exp(-lcum)
        att = jnp.einsum("bthp,bshp->bhts", rd, kd)
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)  # strict lower: s < t
        att = jnp.where(mask[None, None], att, 0.0)
        # bonus diagonal
        bonus = jnp.einsum("bthp,hp,bthp->bth", rq, u, kq)
        y = jnp.einsum("bhts,bshp->bthp", att, vq)
        y = y + bonus[..., None] * vq
        # inter-chunk: state contribution
        y = y + jnp.einsum("bthp,bhpq->bthq", rd, s)
        # state update
        ltot = lcum[:, -1]                          # (B,H,P)
        kdec = kq * jnp.exp(ltot[:, None] - lcum)
        s_new = jnp.exp(ltot)[..., None] * s + jnp.einsum(
            "bshp,bshq->bhpq", kdec, vq)
        return s_new, y

    s0 = jnp.zeros((B, H, P, P), jnp.float32)
    s_last, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, wc))
    return ys.swapaxes(0, 1).reshape(B, S, H, P), s_last
