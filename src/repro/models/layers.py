"""Shared building blocks: norms, rope, MLPs, embeddings.

All GEMMs route through :func:`repro.core.quantizer.qeinsum`, so one
``QuantConfig`` switches every architecture between fp, LNS, and FP8
training. Weight leaves may be dense arrays *or* packed
:class:`repro.core.lns.LNSWeight` words (deployed mode — no fp master
copy): ``dense_of`` hands 2-D packed weights to ``qeinsum`` still packed
(kernel-routed through ``repro.kernels.dispatch``), and decodes
higher-rank leaves per use site — under scan-over-layers at most one
layer's bf16 weights are alive at a time. ``decoded_of`` forces the dense
view for non-GEMM uses (lookups, transposes, weight arithmetic).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lns import is_lns_weight
from repro.core.quantizer import QuantConfig, cot_boundary, qeinsum
from repro.distributed.sharding import shard
from repro.models.common import ArchConfig, dense_init, embed_init

__all__ = ["dense_of", "decoded_of", "rms_norm", "rope", "apply_rope",
           "mlp_init", "mlp_apply", "embedding_init", "ACT_FNS"]

ACT_FNS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def dense_of(w, cfg: ArchConfig, qcfg: Optional[QuantConfig]):
    """Resolve a (possibly LNS-stored) weight for a GEMM.

    Packed 2-D weights pass through *still packed* — ``qeinsum`` routes
    them to the kernel dispatch layer (or decodes at the use site when the
    GEMM cannot route). Higher-rank packed leaves (MoE expert stacks)
    decode here, per leaf, inside whatever scan body is running — never a
    whole-tree materialize.
    """
    if is_lns_weight(w) and w.ndim != 2:
        return w.decode(cfg.compute_dtype)
    return w


def decoded_of(w, cfg: ArchConfig, qcfg: Optional[QuantConfig]):
    """Force a dense view — for non-GEMM uses (embedding lookups,
    transposes, weight arithmetic like LoRA deltas)."""
    if is_lns_weight(w):
        return w.decode(cfg.compute_dtype)
    return w


def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics but compute-dtype tensors.

    Only the variance reduction runs in f32; the (B,S,D)-sized values stay
    in the network dtype so GSPMD resharding (and the backward) never moves
    a full-width f32 copy of the residual stream. The norms are still the
    paper's full-precision carve-out — the *statistics* are exact."""
    x = cot_boundary(x)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps) * (1.0 + gain.astype(jnp.float32))
    return x * scale.astype(x.dtype)


def rope(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """Rotary embedding table for integer positions: (..., head_dim/2, 2)."""
    freqs = jnp.exp2(
        -jnp.log2(theta) * jnp.arange(0, head_dim // 2, dtype=jnp.float32)
        / (head_dim // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def apply_rope(x: jax.Array, rot: jax.Array) -> jax.Array:
    """Rotate pairs. x: (..., S, H, D); rot: (..., S, D/2, 2) broadcasting."""
    xf = cot_boundary(x).astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    cos = jnp.expand_dims(rot[..., 0], axis=-2)  # (..., S, 1, D/2)
    sin = jnp.expand_dims(rot[..., 1], axis=-2)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP


def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, f, dt)}
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[1], d, f, dt)
    p["down"] = dense_init(ks[2], f, d, dt)
    return p


def mlp_apply(p, x, cfg: ArchConfig, qcfg: Optional[QuantConfig]):
    act = ACT_FNS[cfg.act_fn]
    up = qeinsum("bsd,df->bsf", x, dense_of(p["up"], cfg, qcfg), qcfg)
    up = shard(up, "batch", "seq", "act_ff")
    if cfg.mlp_gated:
        gate = qeinsum("bsd,df->bsf", x, dense_of(p["gate"], cfg, qcfg), qcfg)
        up = act(gate) * up
    else:
        up = act(up)
    out = qeinsum("bsf,fd->bsd", up, dense_of(p["down"], cfg, qcfg), qcfg)
    return shard(out, "batch", "seq", "embed")


def embedding_init(key, cfg: ArchConfig):
    n_books = cfg.num_codebooks or 1
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 2)
    p = {"tok": embed_init(ks[0], cfg.vocab_size * n_books, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size * n_books, dt, std=0.02)
    return p
