"""Modality frontend stubs (per the assignment: backbones only).

``phi-3-vision`` and ``musicgen`` specify the transformer backbone; the CLIP
vision tower and EnCodec audio codec are STUBS that produce the tensors the
backbone consumes. ``input_specs()`` in the configs package hands the dry-run
these shapes directly; the functions here generate concrete values for the
smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

__all__ = ["vision_patches_stub", "encodec_tokens_stub", "apply_delay_pattern"]


def vision_patches_stub(key, batch: int, cfg: ArchConfig) -> jax.Array:
    """Precomputed CLIP-tile patch embeddings, already projected to d_model.

    Real phi-3-vision: 336x336 tiles -> CLIP ViT-L/14 -> 2-layer MLP
    projector -> 576 patch embeddings. The stub draws unit-scale gaussians
    with the correct (B, num_patches, d_model) shape/dtype.
    """
    return jax.random.normal(
        key, (batch, cfg.num_patches, cfg.d_model)).astype(cfg.compute_dtype)


def encodec_tokens_stub(key, batch: int, seq: int, cfg: ArchConfig) -> jax.Array:
    """EnCodec RVQ codes: (B, S, num_codebooks) ints in [0, vocab)."""
    return jax.random.randint(
        key, (batch, seq, cfg.num_codebooks), 0, cfg.vocab_size, jnp.int32)


def apply_delay_pattern(tokens: jax.Array, pad_id: int = 0) -> jax.Array:
    """MusicGen delay pattern: codebook k is shifted right by k steps so the
    model predicts all books of step t from strictly-past codes."""
    B, S, K = tokens.shape
    out = []
    for k in range(K):
        shifted = jnp.concatenate(
            [jnp.full((B, k), pad_id, tokens.dtype), tokens[:, : S - k, k]],
            axis=1)
        out.append(shifted)
    return jnp.stack(out, axis=-1)
