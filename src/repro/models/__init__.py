from repro.models.common import ArchConfig
from repro.models.model import (ForwardOut, decode_step, forward, init_caches,
                                init_params, lm_loss)

__all__ = ["ArchConfig", "ForwardOut", "decode_step", "forward",
           "init_caches", "init_params", "lm_loss"]
