"""Public jit'd wrappers around the Pallas kernels.

Handle padding to tile multiples, scale plumbing (per-channel scales applied
in the f32 epilogue), and backend selection (``interpret=True`` on CPU —
this container's validation mode; compiled Mosaic on real TPUs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels.dispatch import resolve_interpret
from repro.kernels.lns_matmul import lns_matmul_pallas
from repro.kernels.lns_qmatmul import lns_qmatmul_pallas
from repro.kernels.lns_quantize import lns_quantize_pallas, lns_requant_pallas
from repro.kernels.madam_update import (madam_update_packed_pallas,
                                        madam_update_pallas)
from repro.kernels.paged_attend import paged_attend_pallas

__all__ = [
    "default_interpret",
    "quantize_pack",
    "requant_pack",
    "lns_matmul",
    "lns_qmatmul",
    "madam_step",
    "madam_step_packed",
    "paged_attend_blocktable",
    "paged_attend_decode",
    "fused_sample",
]


def default_interpret() -> bool:
    """Interpret-mode wherever Pallas cannot compile (i.e. not TPU/GPU);
    env-overridable — see :func:`repro.kernels.dispatch.resolve_interpret`."""
    return resolve_interpret(None)


def _pad2(x: jax.Array, mult_r: int, mult_c: int, fill=0):
    R, C = x.shape
    pr = (-R) % mult_r
    pc = (-C) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=fill)
    return x, R, C


def quantize_pack(
    x: jax.Array,
    fmt: LNSFormat,
    scale_axis: Optional[int] = None,
    *,
    block: int = 256,
    interpret: Optional[bool] = None,
):
    """Encode a 2-D tensor into packed LNS words + its scale (kernel path).

    Returns ``(packed uint8 (R,C), scale (R,1))``. ``scale_axis=0`` keeps
    per-row scales; ``None`` is per-tensor. Pad codes encode magnitude 0
    (max exponent), so padded GEMM tails contribute ~nothing and are sliced
    off anyway.
    """
    interpret = resolve_interpret(interpret)
    R, C = x.shape
    scale = compute_scale(x, axis=scale_axis)  # (R,1) or scalar
    srow = jnp.broadcast_to(scale.reshape(-1, 1) if scale.ndim else scale, (R, 1)).astype(jnp.float32)
    xp, R0, C0 = _pad2(x, block, block)
    sp, _, _ = _pad2(srow, block, 1, fill=1.0)
    packed = lns_quantize_pallas(xp, sp, fmt, block_r=block, block_c=block,
                                 interpret=interpret)
    return packed[:R0, :C0], srow


def requant_pack(
    packed: jax.Array,
    src: LNSFormat,
    dst: LNSFormat,
    *,
    block: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Re-grid a packed LNS tensor of any rank on the kernel path.

    Flattens to 2-D, pads to tile multiples (pad words are ``src.max_code``
    — smallest magnitude, positive sign — and are sliced off anyway), runs
    :func:`lns_requant_pallas`, and restores the original shape. Bit-exact
    against :func:`repro.core.lns.lns_requant_packed` by construction (the
    kernel body traces the same definition).
    """
    interpret = resolve_interpret(interpret)
    shape = packed.shape
    flat = packed.reshape(-1, shape[-1]) if packed.ndim != 2 else packed
    fp, R0, C0 = _pad2(flat, block, block, fill=src.max_code)
    out = lns_requant_pallas(fp, src, dst, block_r=block, block_c=block,
                             interpret=interpret)
    return out[:R0, :C0].reshape(shape)


def lns_matmul(
    a: jax.Array,
    b: jax.Array,
    fmt: LNSFormat,
    *,
    frac_bits: int = 16,
    lut_entries: Optional[int] = None,
    block_k: int = 16,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """End-to-end bit-exact-datapath matmul on real inputs.

    Quantizes both operands (per-tensor scale — one PE pass), runs the Fig.-6
    integer datapath, and rescales: ``out·s_a·s_b/2^frac_bits``. Returns f32.
    """
    interpret = resolve_interpret(interpret)
    sa = compute_scale(a)
    sb = compute_scale(b)
    siga, ca = lns_encode(a, fmt, sa)
    sigb, cb = lns_encode(b, fmt, sb)
    pa = lns_pack(siga, ca, fmt)
    pb = lns_pack(sigb, cb, fmt)
    # pad: code max_code = smallest magnitude; sign + => tiny positive dust,
    # but exact zero requires the magnitude to underflow — pad K with
    # complementary signs so pairs cancel? Simpler: pad with max_code and
    # rely on underflow (frac_bits=16, pad product exponent >= max_code
    # ⇒ quotient >= 15 ... only exact for gamma*frac_bits >= max_code; for
    # B=8, γ=8: q = 254>>3 = 31 > 16 ⇒ shifts to 0. Guaranteed zero.
    pad_word = fmt.max_code  # positive sign, smallest magnitude
    M, K = a.shape
    _, N = b.shape
    pa, _, _ = _pad2(pa, 128, block_k, fill=pad_word)
    pb, _, _ = _pad2(pb, block_k, 128, fill=pad_word)
    out = lns_matmul_pallas(pa, pb, fmt, frac_bits=frac_bits,
                            lut_entries=lut_entries, block_k=block_k,
                            interpret=interpret)[:M, :N]
    return out.astype(jnp.float32) * (sa * sb) / (1 << frac_bits)


def lns_qmatmul(
    pa: jax.Array,
    pb: jax.Array,
    fmt: LNSFormat,
    scale_a: Optional[jax.Array] = None,
    scale_b: Optional[jax.Array] = None,
    *,
    compute_dtype=jnp.bfloat16,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Production packed-LNS matmul: dequant-in-VMEM -> MXU -> f32 epilogue.

    ``scale_a`` is per-row of A ((M,1) or scalar), ``scale_b`` per-column of
    B ((1,N) or scalar); both factor out of the GEMM and multiply the output.
    """
    interpret = resolve_interpret(interpret)
    M, K = pa.shape
    _, N = pb.shape
    pad_word = fmt.max_code
    pa_p, _, _ = _pad2(pa, 128, 128, fill=pad_word)
    pb_p, _, _ = _pad2(pb, 128, 128, fill=pad_word)
    out = lns_qmatmul_pallas(pa_p, pb_p, fmt, compute_dtype=compute_dtype,
                             interpret=interpret)[:M, :N]
    if scale_a is not None:
        out = out * scale_a
    if scale_b is not None:
        out = out * scale_b
    return out


def paged_attend_blocktable(
    q: jax.Array,
    kp: jax.Array,
    vp: jax.Array,
    k_scale: Optional[jax.Array],
    v_scale: Optional[jax.Array],
    block_table: jax.Array,
    lengths: jax.Array,
    *,
    fmt: Optional[LNSFormat] = None,
    softcap: Optional[float] = None,
    sm_scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paged attention through the fused Pallas kernel — decode (S == 1)
    and prefill-over-block-table (S > 1) shapes alike.

    Thin pass-through today — serving head/page shapes are small and the
    CPU CI leg runs in interpret mode; real-TPU tile padding would live
    here (pad heads/head_dim to tile multiples, slice the output).
    """
    return paged_attend_pallas(q, kp, vp, k_scale, v_scale, block_table,
                               lengths, fmt=fmt, softcap=softcap,
                               sm_scale=sm_scale,
                               interpret=resolve_interpret(interpret))


# historical name, from when the kernel served only the decode shape
paged_attend_decode = paged_attend_blocktable


def fused_sample(
    logits: jax.Array,
    gumbel: Optional[jax.Array],
    temp: Optional[jax.Array],
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused sampler epilogue (kernel path): ``(B, V)`` -> ``(B,) int32``.

    Pads V to a 128-lane multiple — pad logits are ``-1e30`` with zero
    gumbel, so a padded column can never win the argmax (nor survive the
    ``/ max(temp, 1e-6)`` scale within f32 range).
    """
    from repro.kernels.sampler import fused_sample_pallas
    interpret = resolve_interpret(interpret)
    V = logits.shape[-1]
    pc = (-V) % 128
    if pc:
        logits = jnp.pad(logits, ((0, 0), (0, pc)), constant_values=-1e30)
        if gumbel is not None:
            gumbel = jnp.pad(gumbel, ((0, 0), (0, pc)))
    return fused_sample_pallas(logits, gumbel, temp, interpret=interpret)


def madam_step(
    code: jax.Array,
    sign: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    interpret: Optional[bool] = None,
):
    """Fused Madam update for one 2-D LNS weight (pads to tile multiples)."""
    interpret = resolve_interpret(interpret)
    R, C = code.shape
    block = 256
    cp, _, _ = _pad2(code, block, block)
    sp, _, _ = _pad2(sign, block, block, fill=1)
    gp, _, _ = _pad2(g, block, block)
    vp, _, _ = _pad2(v, block, block, fill=1.0)
    nc, nv = madam_update_pallas(cp, sp, gp, vp, count, fmt, lr=lr, beta=beta,
                                 eps=eps, block_r=block, block_c=block,
                                 interpret=interpret)
    return nc[:R, :C], nv[:R, :C]


def madam_step_packed(
    packed: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    interpret: Optional[bool] = None,
):
    """Fused Madam update on a 2-D *packed-word* weight (pads to tiles).

    Pad words are 0 (sign +, code 0) with g=0, v=1: gstar is 0 there, so
    the padded tail is a fixed point and is sliced off anyway.
    """
    interpret = resolve_interpret(interpret)
    R, C = packed.shape
    block = 256
    pp, _, _ = _pad2(packed, block, block)
    gp, _, _ = _pad2(g, block, block)
    vp, _, _ = _pad2(v, block, block, fill=1.0)
    npk, nv = madam_update_packed_pallas(pp, gp, vp, count, fmt, lr=lr,
                                         beta=beta, eps=eps, block_r=block,
                                         block_c=block, interpret=interpret)
    return npk[:R, :C], nv[:R, :C]


def madam_step_packed_stats(
    packed: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    requant=None,
    interpret: Optional[bool] = None,
):
    """:func:`madam_step_packed` plus the fused numerics-stat epilogue.

    Returns ``(new_packed, new_v, stats_vec)``. Padded elements are a
    fixed point of the update (target == code == 0) and contribute zero
    to every stat partial sum, so callers normalize the vector by the
    *true* element count ``R*C``, never the padded one.
    """
    from repro.kernels.madam_update import madam_update_packed_stats_pallas
    interpret = resolve_interpret(interpret)
    R, C = packed.shape
    block = 256
    pp, _, _ = _pad2(packed, block, block)
    gp, _, _ = _pad2(g, block, block)
    vp, _, _ = _pad2(v, block, block, fill=1.0)
    npk, nv, stats = madam_update_packed_stats_pallas(
        pp, gp, vp, count, fmt, lr=lr, beta=beta, eps=eps, requant=requant,
        block_r=block, block_c=block, interpret=interpret)
    return npk[:R, :C], nv[:R, :C], stats
