"""Bit-exact LNS dot-product datapath (paper Fig. 6) as a Pallas kernel.

Emulates the Vector MAC Unit: per product, add the integer exponents and XOR
the signs; split the product exponent into quotient (MSB) / remainder (LSB);
convert to linear fixed point by a right shift (quotient) and a small-LUT
multiply (remainder — exact γ-entry LUT, or the App.-B Mitchell hybrid);
reduce through adder trees; saturate the 24-bit accumulation collector.

Since our storage keeps *negated* exponents (value = s·2**(-e/γ)), the RTL's
left-shift-by-quotient becomes a right shift — offset-binary equivalent, and
products below the fixed point's LSB underflow to 0 exactly like hardware.
The output is an int32 partial-sum tile in Qx.``frac_bits`` fixed point
(frac_bits=16 ⇒ Q7.16, a 24-bit collector: paper Table 1).

This kernel is the *validation + energy-model* artifact: it proves the
datapath semantics on TPU-shaped tiles and backs the Table-10 benchmark. The
production matmul is ``lns_qmatmul`` (dequantize -> MXU). LUT lookups are
compile-time select-sums (γ ≤ 32 entries), not gathers — MXU/VPU friendly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import conversion
from repro.core.lns import LNSFormat
from repro.kernels.dispatch import resolve_interpret

__all__ = ["lns_matmul_pallas"]

_SAT24 = (1 << 23) - 1


def _select_lut(idx: jax.Array, lut: np.ndarray) -> jax.Array:
    """LUT lookup as a select-sum over the (small) static constant table."""
    out = jnp.zeros(idx.shape, jnp.int32)
    for j, val in enumerate(lut):
        out = jnp.where(idx == j, jnp.int32(int(val)), out)
    return out


def _datapath_terms(m, gamma: int, frac_bits: int, lut_entries: int | None):
    """Linear fixed-point magnitude of 2**(-m/γ) — shift + LUT (+ Mitchell)."""
    b = int(gamma).bit_length() - 1
    q = jnp.minimum(m >> b, 31)
    r = m & (gamma - 1)
    if lut_entries is None:
        lut = conversion.remainder_lut_neg_int(gamma, frac_bits)
        v = _select_lut(r, lut)
    else:
        # complement-Mitchell on the LSBs (see conversion.exp2_neg_hybrid_fixed)
        b_l = b - (int(lut_entries).bit_length() - 1)
        r_m = r >> b_l
        r_l = r & ((1 << b_l) - 1)
        lut = conversion.remainder_lut_neg_shifted_int(gamma, frac_bits,
                                                       lut_entries)
        v = _select_lut(r_m, lut) * (gamma + (1 << b_l) - r_l)
        v = jax.lax.shift_right_logical(v, b)
    return jax.lax.shift_right_logical(v, q)


def _kernel(pa_ref, pb_ref, out_ref, *, bits: int, gamma: int,
            frac_bits: int, lut_entries: int | None):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    max_code = (1 << (bits - 1)) - 1
    wa = pa_ref[...].astype(jnp.int32)  # (bm, bk) packed words
    wb = pb_ref[...].astype(jnp.int32)  # (bk, bn)
    ca, sa = wa & max_code, 1 - 2 * (wa >> (bits - 1))
    cb, sb = wb & max_code, 1 - 2 * (wb >> (bits - 1))

    # product exponents / signs over the (bm, bk, bn) outer-product space
    m = ca[:, :, None] + cb[None, :, :]
    sgn = sa[:, :, None] * sb[None, :, :]
    mag = _datapath_terms(m, gamma, frac_bits, lut_entries)
    block = jnp.sum(sgn * mag, axis=1)  # adder tree over the vector lanes

    # accumulation collector: saturating 24-bit add per K block
    out_ref[...] = jnp.clip(out_ref[...] + block, -_SAT24, _SAT24)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "frac_bits", "lut_entries", "block_m", "block_n",
                     "block_k", "interpret"),
)
def lns_matmul_pallas(
    pa: jax.Array,
    pb: jax.Array,
    fmt: LNSFormat,
    *,
    frac_bits: int = 16,
    lut_entries: int | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 16,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Packed-LNS matmul through the bit-exact integer datapath.

    ``pa (M,K)`` x ``pb (K,N)`` packed words -> int32 (M,N) partial sums in
    Q·``frac_bits`` fixed point. Real value = out · s_a·s_b / 2**frac_bits.
    Shapes must tile evenly (callers pad); K saturation order == ``block_k``.
    """
    M, K = pa.shape
    K2, N = pb.shape
    assert K == K2, (pa.shape, pb.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"shapes ({M},{K})x({K},{N}) must tile by ({block_m},{block_n},{block_k})")

    interpret = resolve_interpret(interpret)
    grid = (M // block_m, N // block_n, K // block_k)
    kernel = functools.partial(
        _kernel, bits=fmt.bits, gamma=fmt.gamma, frac_bits=frac_bits,
        lut_entries=lut_entries)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(pa, pb)
