"""Fused on-device sampler epilogue: scale -> gumbel add -> argmax in one
launch, one logits row per grid step.

This is the kernel half of ``dispatch.fused_sample`` — the sort-free fast
path of ``server.sampling`` (pure greedy and temperature-only batches; the
rare top-k/top-p rows keep the jnp sort path). The gumbel noise comes in
as an *input*: it is drawn host-side with ``jax.random`` keys that fold in
the request seed and step, so a seeded request replays token-for-token
whether this kernel or the jnp reference serves it. (In-kernel
``pltpu.prng_*`` would also not be cross-backend reproducible, and is not
available in interpret mode — the CPU CI leg.)

Argmax is spelled manually as ``min(where(x == max(x), iota, V))`` —
first-maximum-wins, bit-identical to ``jnp.argmax`` / the host-side
``np.argmax`` the engine used before sampling moved on device, and it
lowers on Mosaic where a fused ``argmax`` reduction may not.

The caller (``ops.fused_sample``) pads V to a lane multiple with ``-1e30``
logits and zero gumbel, so padded columns can never win either reduction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import resolve_interpret

__all__ = ["fused_sample_pallas"]


def _argmax_first(x: jax.Array) -> jax.Array:
    """First-max-wins argmax over a (1, V) row -> int32 scalar."""
    v = x.shape[-1]
    m = jnp.max(x, axis=-1, keepdims=True)
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    return jnp.min(jnp.where(x == m, idx, v)).astype(jnp.int32)


def _kernel(temp_ref, lg_ref, *rest, with_gumbel):
    if with_gumbel:
        gum_ref, o_ref = rest
    else:
        o_ref = rest[0]
    b = pl.program_id(0)
    lg = lg_ref[...].astype(jnp.float32)            # (1, V)
    greedy = _argmax_first(lg)
    if with_gumbel:
        t = temp_ref[b]
        scaled = lg / jnp.maximum(t, 1e-6) + gum_ref[...].astype(jnp.float32)
        tok = jnp.where(t > 0.0, _argmax_first(scaled), greedy)
    else:
        tok = greedy
    o_ref[0, 0] = tok


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample_pallas(
    logits: jax.Array,                 # (B, V) — V already lane-padded
    gumbel: Optional[jax.Array],       # (B, V) or None for pure greedy
    temp: Optional[jax.Array],         # (B,) f32, required with gumbel
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One launch per batch: returns sampled token ids ``(B,) int32``."""
    interpret = resolve_interpret(interpret)
    B, V = logits.shape
    if gumbel is None:
        temp = jnp.zeros((B,), jnp.float32)  # prefetched but unread
    row = pl.BlockSpec((1, V), lambda b, t: (b, 0))
    in_specs = [row]
    args = [logits]
    if gumbel is not None:
        in_specs.append(pl.BlockSpec((1, V), lambda b, t: (b, 0)))
        args.append(gumbel)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, with_gumbel=gumbel is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(temp.astype(jnp.float32), *args)
    return out[:, 0]
