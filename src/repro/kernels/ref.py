"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors its kernel's semantics exactly (including block-wise
accumulator saturation order for the bit-exact datapath) so tests can assert
bit-for-bit equality in interpret mode across shape/dtype sweeps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import conversion
from repro.core.lns import LNSFormat, lns_decode_packed, lns_unpack

__all__ = [
    "SAT24",
    "lns_matmul_ref",
    "lns_qmatmul_ref",
    "lns_quantize_ref",
    "madam_update_ref",
]

SAT24 = (1 << 23) - 1  # 24-bit accumulation collector bound (paper Table 1)


def _saturate(x: jax.Array, bound: int = SAT24) -> jax.Array:
    return jnp.clip(x, -bound, bound)


def lns_matmul_ref(
    pa: jax.Array,
    pb: jax.Array,
    fmt: LNSFormat,
    *,
    frac_bits: int = 16,
    lut_entries: int | None = None,
    block_k: int = 128,
) -> jax.Array:
    """Oracle for the bit-exact Fig.-6 datapath kernel.

    ``pa (M,K)``, ``pb (K,N)``: packed LNS words. Output int32 partial sums
    in Q(23-frac_bits).frac_bits fixed point. The accumulator saturates to
    24 bits after every ``block_k`` slice, replicating the kernel's
    accumulation-collector order — tests must use the same ``block_k``.
    """
    sa, ca = lns_unpack(pa, fmt)
    sb, cb = lns_unpack(pb, fmt)
    m = ca.astype(jnp.int32)[:, :, None] + cb.astype(jnp.int32)[None, :, :]
    sign = sa.astype(jnp.int32)[:, :, None] * sb.astype(jnp.int32)[None, :, :]
    if lut_entries is None:
        mag = conversion.exp2_neg_exact_fixed(m, fmt.gamma, frac_bits)
    else:
        mag = conversion.exp2_neg_hybrid_fixed(m, fmt.gamma, lut_entries, frac_bits)
    terms = sign * mag  # (M, K, N) int32

    K = pa.shape[1]
    acc = jnp.zeros((pa.shape[0], pb.shape[1]), jnp.int32)
    for k0 in range(0, K, block_k):
        acc = _saturate(acc + jnp.sum(terms[:, k0:k0 + block_k, :], axis=1))
    return acc


def lns_qmatmul_ref(
    pa: jax.Array,
    pb: jax.Array,
    fmt: LNSFormat,
    *,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for the fused dequantize->MXU matmul kernel.

    Decodes packed words to ``compute_dtype`` (unscaled: magnitude
    2**(-code/γ)) and matmuls with f32 accumulation. Per-channel scales are
    applied by the ops wrapper outside the kernel in both paths. The decode
    is the same :func:`repro.core.lns.lns_decode_packed` the kernel
    prologue runs — oracle and kernel share one definition.
    """
    a = lns_decode_packed(pa, fmt, compute_dtype)
    b = lns_decode_packed(pb, fmt, compute_dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def lns_quantize_ref(x: jax.Array, scale: jax.Array, fmt: LNSFormat) -> jax.Array:
    """Oracle for the fused encode+pack kernel.

    ``scale`` broadcasts against ``x`` (per-row (R,1) or scalar (1,1)).
    Deterministic round-to-nearest (ties away from zero).
    """
    xf = x.astype(jnp.float32)
    neg = (xf < 0).astype(jnp.uint8)
    mag = jnp.abs(xf) / scale
    e = -jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)) * fmt.gamma
    e = jnp.clip(jnp.floor(e + 0.5), 0, fmt.max_code)
    return ((neg << (fmt.bits - 1)) | e.astype(jnp.uint8)).astype(jnp.uint8)


def madam_update_ref(
    code: jax.Array,
    sign: jax.Array,
    g: jax.Array,
    v: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float,
    count: int,
    eps: float = 1e-30,
):
    """Oracle for the fused LNS-Madam update kernel (Algorithm 1).

    Returns (new_code, new_v). Matches ``optim.madam.madam_lns`` leaf math.
    """
    gf = g.astype(jnp.float32)
    v = (1.0 - beta) * gf * gf + beta * v
    bc = 1.0 - beta ** jnp.asarray(count, jnp.float32)
    gstar = gf * jax.lax.rsqrt(v / bc + eps)
    step = lr * fmt.gamma * gstar * sign.astype(jnp.float32)
    target = code.astype(jnp.float32) + step
    new_code = jnp.clip(jnp.floor(target + 0.5), 0, fmt.max_code).astype(fmt.code_dtype)
    return new_code, v
