"""Fused LNS encode+pack kernel — one pass from f32/bf16 to packed words.

Implements the paper's Q_log (Eq. 3) as the write-side of the TPU datapath:
``code = clamp(round(-log2(|x|/s)·γ), 0, 2^(B-1)-1)`` packed with the sign
bit into a single byte. Scales arrive per row tile (per-channel) or
broadcast (per-tensor) — the absmax reduction runs in a prior pass (the
hardware's PPU also scales as a post-processing step, §5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lns import LNSFormat, lns_requant_packed
from repro.kernels.dispatch import resolve_interpret

__all__ = ["lns_quantize_pallas", "lns_requant_pallas"]


def _kernel(x_ref, s_ref, out_ref, *, bits: int, gamma: int):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)  # (block_r, 1), broadcasts over cols
    max_code = (1 << (bits - 1)) - 1
    neg = (x < 0).astype(jnp.uint32)
    mag = jnp.abs(x) / s
    e = -jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)) * gamma
    e = jnp.clip(jnp.floor(e + 0.5), 0, max_code).astype(jnp.uint32)
    out_ref[...] = ((neg << (bits - 1)) | e).astype(jnp.uint8)


def _requant_kernel(w_ref, out_ref, *, src: LNSFormat, dst: LNSFormat):
    # The kernel body IS the reference transform: lns_requant_packed is pure
    # integer bit-slicing, so tracing it inside the Pallas block keeps the
    # kernel and the jnp oracle one definition — they cannot drift.
    out_ref[...] = lns_requant_packed(w_ref[...], src, dst)


@functools.partial(
    jax.jit, static_argnames=("src", "dst", "block_r", "block_c", "interpret"))
def lns_requant_pallas(
    packed: jax.Array,
    src: LNSFormat,
    dst: LNSFormat,
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Re-grid packed wire words ``(R, C)`` from ``src`` to ``dst`` bits.

    The draft-view transform of self-speculative decoding: integer-only
    exponent re-grid (upscale multiplies by the γ ratio, downscale rounds
    ties away from zero), sign bit repositioned to ``dst.bits - 1``. Scales
    are untouched — callers share them with the source weight.
    """
    assert src.bits <= 8 and dst.bits <= 8, "packed-byte wire format"
    R, C = packed.shape
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    interpret = resolve_interpret(interpret)
    grid = (R // block_r, C // block_c)
    kernel = functools.partial(_requant_kernel, src=src, dst=dst)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.uint8),
        interpret=interpret,
    )(packed)


@functools.partial(
    jax.jit, static_argnames=("fmt", "block_r", "block_c", "interpret"))
def lns_quantize_pallas(
    x: jax.Array,
    scale: jax.Array,
    fmt: LNSFormat,
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Encode ``x (R,C)`` with per-row ``scale (R,1)`` into packed uint8.

    For per-tensor scaling pass ``jnp.full((R,1), s)``. ``fmt.bits`` must be
    <= 8 (the packed-byte wire format).
    """
    assert fmt.bits <= 8, "packed-byte kernel supports bits<=8"
    R, C = x.shape
    assert scale.shape == (R, 1), scale.shape
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    interpret = resolve_interpret(interpret)
    grid = (R // block_r, C // block_c)
    kernel = functools.partial(_kernel, bits=fmt.bits, gamma=fmt.gamma)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.uint8),
        interpret=interpret,
    )(x, scale)
