"""Fused paged attention over a block-paged KV pool: double-buffered page
DMAs, tile-local LNS decode, online softmax — decode *and* prefill shapes.

One kernel serves both serving shapes:

* **decode** — ``S == 1``: each slot's single query attends over the pages
  its block table names.
* **prefill over the block table** — ``S > 1``: the engine's batch-1
  suffix prefill. Queries sit at absolute positions ``lengths - S + s``
  (``pos_offset = n_cached`` for a prefix-cache hit), so the queries cover
  only the *suffix* while the gathered pages include the cached prefix —
  prefix-cached pages are attended but never recomputed, at kernel level
  rather than by re-gathering them into a scratch pool.

The KV pools stay in HBM (``memory_space=ANY``); the kernel drives its own
gather: the block table and per-slot lengths are scalar-prefetched into
SMEM, and a two-deep VMEM buffer ring overlaps the DMA of page ``i+1``
with the attention math on page ``i`` (see DESIGN.md §10). Each grid step
is one batch row and loops only over ``ceil(lengths[b] / page)`` resident
pages — short rows do proportionally less work, where the previous
``(B, max_pages)`` grid paid for the worst case in every row.

Packed LNS pages decode tile-locally in VMEM through the one shared
``core.lns.lns_decode_packed`` (scales applied per position/head), so the
kernel cannot drift from the jnp oracle. The online-softmax accumulator
``(m, l, acc)`` lives in loop carries; the full ``(S, positions)`` score
row never materializes.

Invalid tail positions (beyond a slot's length) are masked before the
softmax, so block-table entries pointing at the pool's sacrificial null
page are harmless. Head/page dims are used as-is — serving shapes are
small and CPU CI runs this kernel in interpret mode; real-TPU tiling pads
would go in ``ops.paged_attend_blocktable``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lns import LNSFormat, lns_decode_packed
from repro.kernels.dispatch import resolve_interpret

__all__ = ["paged_attend_pallas", "NUM_BUFFERS"]

# depth of the VMEM page-buffer ring: 2 = classic double buffering
# (prefetch page i+1 while attending page i)
NUM_BUFFERS = 2


def _kernel(tbl_ref, len_ref, q_ref, kp_hbm, vp_hbm, *rest, fmt, softcap,
            sm_scale, page):
    if fmt is not None:
        ks_hbm, vs_hbm, o_ref = rest
    else:
        o_ref = rest[0]
    b = pl.program_id(0)
    _, S, h, hd = q_ref.shape
    kv = kp_hbm.shape[-2]
    rep = h // kv
    ln = len_ref[b]
    n_pages = (ln + page - 1) // page  # >= 1: the engine never serves an
    # empty row (prompt >= 1 token and lengths include the token just
    # written), so the warm-up DMA below is always valid. Bucket-padded
    # prefill queries can push ln past the table span — clamp to the
    # table width (their outputs are discarded by the caller anyway)
    n_pages = jnp.minimum(n_pages, tbl_ref.shape[1])

    def body(kbuf, vbuf, sem, ksbuf=None, vsbuf=None):
        def dma(slot, i):
            """Async copies moving pool page ``tbl[b, i]`` into ring slot
            ``slot`` — one per pool operand, each on its own semaphore."""
            pg = tbl_ref[b, i]
            cps = [
                pltpu.make_async_copy(kp_hbm.at[pg], kbuf.at[slot],
                                      sem.at[slot, 0]),
                pltpu.make_async_copy(vp_hbm.at[pg], vbuf.at[slot],
                                      sem.at[slot, 1]),
            ]
            if fmt is not None:
                cps += [
                    pltpu.make_async_copy(ks_hbm.at[pg], ksbuf.at[slot],
                                          sem.at[slot, 2]),
                    pltpu.make_async_copy(vs_hbm.at[pg], vsbuf.at[slot],
                                          sem.at[slot, 3]),
                ]
            return cps

        for cp in dma(0, 0):  # warm-up: page 0 in flight before the loop
            cp.start()

        q = q_ref[0].astype(jnp.float32)              # (S, h, hd)
        qg = q.reshape(S, kv, rep, hd)
        q_pos = ln - S + jax.lax.broadcasted_iota(jnp.int32, (S, 1, 1), 0)

        def step(i, carry):
            m_prev, l_prev, acc = carry
            cur = jax.lax.rem(i, NUM_BUFFERS)
            nxt = jax.lax.rem(i + 1, NUM_BUFFERS)

            @pl.when(i + 1 < n_pages)
            def _prefetch():                 # overlap: next page's DMA
                for cp in dma(nxt, i + 1):   # issues while this page's
                    cp.start()               # attention math runs

            for cp in dma(cur, i):
                cp.wait()

            k = kbuf[cur]                    # (page, kv, hd)
            v = vbuf[cur]
            if fmt is not None:
                # tile-local unpack+decode through the one shared
                # definition in core.lns — no drift from the jnp oracle
                k = lns_decode_packed(k, fmt, jnp.float32) * \
                    ksbuf[cur].astype(jnp.float32)
                v = lns_decode_packed(v, fmt, jnp.float32) * \
                    vsbuf[cur].astype(jnp.float32)
            else:
                k = k.astype(jnp.float32)
                v = v.astype(jnp.float32)

            logits = jnp.einsum("sgrd,pgd->sgrp", qg, k,
                                preferred_element_type=jnp.float32)
            logits = logits.reshape(S, h, page) * sm_scale
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            pos = i * page + jax.lax.broadcasted_iota(
                jnp.int32, (S, 1, page), 2)
            logits = jnp.where(pos <= q_pos, logits, -1e30)  # (S, h, page)

            m_new = jnp.maximum(m_prev,
                                jnp.max(logits, axis=-1, keepdims=True))
            pexp = jnp.exp(logits - m_new)            # (S, h, page)
            corr = jnp.exp(m_prev - m_new)            # (S, h, 1)
            l_new = corr * l_prev + jnp.sum(pexp, axis=-1, keepdims=True)
            ctx = jnp.einsum("sgrp,pgd->sgrd",
                             pexp.reshape(S, kv, rep, page), v,
                             preferred_element_type=jnp.float32)
            acc = corr * acc + ctx.reshape(S, h, hd)
            return m_new, l_new, acc

        init = (jnp.full((S, h, 1), -1e30, jnp.float32),
                jnp.zeros((S, h, 1), jnp.float32),
                jnp.zeros((S, h, hd), jnp.float32))
        _, l, acc = jax.lax.fori_loop(0, n_pages, step, init)
        o_ref[0] = acc / jnp.maximum(l, 1e-30)

    kv_dt = kp_hbm.dtype
    scratch = {
        "kbuf": pltpu.VMEM((NUM_BUFFERS, page, kv, hd), kv_dt),
        "vbuf": pltpu.VMEM((NUM_BUFFERS, page, kv, hd), kv_dt),
    }
    n_ops = 2
    if fmt is not None:
        scratch["ksbuf"] = pltpu.VMEM((NUM_BUFFERS, page, kv, 1),
                                      ks_hbm.dtype)
        scratch["vsbuf"] = pltpu.VMEM((NUM_BUFFERS, page, kv, 1),
                                      vs_hbm.dtype)
        n_ops = 4
    scratch["sem"] = pltpu.SemaphoreType.DMA((NUM_BUFFERS, n_ops))
    pl.run_scoped(body, **scratch)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "softcap", "sm_scale", "interpret"),
)
def paged_attend_pallas(
    q: jax.Array,            # (B, S, h, hd)
    kp: jax.Array,           # (P, page, kv, hd) packed words or dense
    vp: jax.Array,
    k_scale: Optional[jax.Array],   # (P, page, kv, 1) when fmt is set
    v_scale: Optional[jax.Array],
    block_table: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,      # (B,) int32 valid positions per slot
    *,
    fmt: Optional[LNSFormat] = None,
    softcap: Optional[float] = None,
    sm_scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paged attention over a block-paged KV pool -> f32 (B, S, h, hd).

    ``lengths`` counts each slot's valid positions *including* the S just
    written, so query ``s`` sits at absolute position ``lengths - S + s``
    (matching ``dispatch._paged_attend_reference``). Must be >= 1 per row.
    """
    interpret = resolve_interpret(interpret)
    B, S, h, hd = q.shape
    _, page, kv, _ = kp.shape

    in_specs = [
        pl.BlockSpec((1, S, h, hd), lambda b, tbl, ln: (b, 0, 0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),   # KV pools stay in HBM;
        pl.BlockSpec(memory_space=pltpu.ANY),   # the kernel DMAs pages
    ]
    args = [q, kp, vp]
    if fmt is not None:
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, S, h, hd), lambda b, tbl, ln: (b, 0, 0, 0)),
    )
    kernel = functools.partial(_kernel, fmt=fmt, softcap=softcap,
                               sm_scale=sm_scale, page=page)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, h, hd), jnp.float32),
        interpret=interpret,
    )(block_table, lengths, *args)
