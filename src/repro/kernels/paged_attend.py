"""Paged-attention decode kernel: block-table gather + tile-local LNS decode.

One query token per slot (the serving decode shape) attends over the pages
its block table names. The grid is (batch, max_pages) with pages innermost:
each step DMAs one (page_size, KV, hd) K/V page — selected by the
scalar-prefetched block table in the BlockSpec index map, so the gather
never materializes a dense (B, max_len) view in HBM — decodes packed LNS
words in the prologue (the shared ``core.lns.lns_decode_packed``, scales
applied per position/head), and folds the page into a running
online-softmax accumulator held in VMEM scratch. The last page of each row
writes ``acc / l`` to the output.

Invalid tail positions (beyond the slot's length) are masked before the
softmax, so block-table entries that point at the pool's null page are
harmless. Head/page dims are used as-is — the serving shapes are small and
CPU CI runs this kernel in interpret mode; real-TPU tiling pads would go in
``ops.paged_attend_decode``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lns import LNSFormat, lns_decode_packed
from repro.kernels.dispatch import resolve_interpret

__all__ = ["paged_attend_pallas"]


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, *rest, fmt, softcap,
            sm_scale, page, rep):
    if fmt is not None:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, p = pl.program_id(0), pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = k_ref[0]  # (page, kv, hd)
    v = v_ref[0]
    if fmt is not None:
        # tile-local unpack+decode through the one shared definition in
        # core.lns, so the kernel cannot drift from the jnp oracle
        k = lns_decode_packed(k, fmt, jnp.float32) * ks_ref[0].astype(
            jnp.float32)
        v = lns_decode_packed(v, fmt, jnp.float32) * vs_ref[0].astype(
            jnp.float32)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)          # (h, hd)
    h = q.shape[0]
    kv = k.shape[1]
    qg = q.reshape(kv, rep, q.shape[-1])         # GQA head groups
    logits = jnp.einsum("krd,pkd->krp", qg, k).reshape(h, page) * sm_scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    logits = jnp.where(pos < len_ref[b], logits, -1e30)

    m_prev, l_prev, acc = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    pexp = jnp.exp(logits - m_new)               # (h, page)
    corr = jnp.exp(m_prev - m_new)               # (h, 1)
    l_new = corr * l_prev + jnp.sum(pexp, axis=-1, keepdims=True)
    ctx = jnp.einsum("krp,pkd->krd", pexp.reshape(kv, rep, page), v)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = corr * acc + ctx.reshape(h, -1)

    @pl.when(p == pl.num_programs(1) - 1)
    def _write():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "softcap", "sm_scale", "interpret"),
)
def paged_attend_pallas(
    q: jax.Array,            # (B, 1, h, hd)
    kp: jax.Array,           # (P, page, kv, hd) packed words or dense
    vp: jax.Array,
    k_scale: Optional[jax.Array],   # (P, page, kv, 1) when fmt is set
    v_scale: Optional[jax.Array],
    block_table: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,      # (B,) int32 valid positions per slot
    *,
    fmt: Optional[LNSFormat] = None,
    softcap: Optional[float] = None,
    sm_scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Decode-shape paged attention over a block-paged KV pool -> f32."""
    interpret = resolve_interpret(interpret)
    B, S, h, hd = q.shape
    assert S == 1, "the kernel serves the decode shape; S>1 is the reference"
    _, page, kv, _ = kp.shape
    mp = block_table.shape[1]
    rep = h // kv

    qmap = lambda b, p, tbl, ln: (b, 0, 0, 0)
    pgmap = lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, h, hd), qmap),
        pl.BlockSpec((1, page, kv, hd), pgmap),
        pl.BlockSpec((1, page, kv, hd), pgmap),
    ]
    args = [q, kp, vp]
    if fmt is not None:
        in_specs += [pl.BlockSpec((1, page, kv, 1), pgmap)] * 2
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, mp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, h, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),   # running max
            pltpu.VMEM((h, 1), jnp.float32),   # running denominator
            pltpu.VMEM((h, hd), jnp.float32),  # weighted-value accumulator
        ],
    )
    kernel = functools.partial(_kernel, fmt=fmt, softcap=softcap,
                               sm_scale=sm_scale, page=page, rep=rep)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, h, hd), jnp.float32),
        interpret=interpret,
    )(block_table, lengths, *args)
