"""Pallas TPU kernels for the LNS hot spots, behind a backend registry.

* ``lns_matmul``   — bit-exact Fig.-6 integer datapath (validation artifact)
* ``lns_qmatmul``  — fused dequantize->MXU matmul (production path)
* ``lns_quantize`` — fused Q_log encode + sign/exponent pack
* ``madam_update`` — fused Algorithm-1 step on integer exponent codes, in
  unpacked (code, sign) and packed-wire-word variants

Each kernel has a pure-jnp oracle in :mod:`repro.kernels.ref` and a jit'd
wrapper in :mod:`repro.kernels.ops`. Production code does not call either
directly: it goes through :mod:`repro.kernels.dispatch`, which picks the
``"pallas"`` or ``"reference"`` backend per platform (override with
``REPRO_KERNEL_BACKEND``) and auto-detects Pallas interpret mode
(``REPRO_KERNEL_INTERPRET``).
"""
from repro.kernels import dispatch
from repro.kernels.ops import (default_interpret, lns_matmul, lns_qmatmul,
                               madam_step, madam_step_packed, quantize_pack)

__all__ = ["default_interpret", "dispatch", "lns_matmul", "lns_qmatmul",
           "madam_step", "madam_step_packed", "quantize_pack"]
