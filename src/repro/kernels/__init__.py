"""Pallas TPU kernels for the LNS hot spots (validated in interpret mode).

* ``lns_matmul``   — bit-exact Fig.-6 integer datapath (validation artifact)
* ``lns_qmatmul``  — fused dequantize->MXU matmul (production path)
* ``lns_quantize`` — fused Q_log encode + sign/exponent pack
* ``madam_update`` — fused Algorithm-1 step on integer exponent codes

Each kernel has a pure-jnp oracle in :mod:`repro.kernels.ref` and a jit'd
wrapper in :mod:`repro.kernels.ops`.
"""
from repro.kernels.ops import (default_interpret, lns_matmul, lns_qmatmul,
                               madam_step, quantize_pack)

__all__ = ["default_interpret", "lns_matmul", "lns_qmatmul", "madam_step",
           "quantize_pack"]
