"""Fused LNS-dequantize -> MXU matmul — the TPU-native production kernel.

The TPU adaptation of the paper's co-design (DESIGN.md §2): LNS is the
*storage/bandwidth* format. Operands live in HBM as packed 8-bit LNS words
(2x fewer bytes than bf16, 4x fewer than f32); each VMEM tile is decoded in
the kernel prologue (sign bit-slice + exp2 of the exponent — cheap VPU work)
and fed to the MXU in bf16 with f32 accumulation. Memory-bound layers get
the LNS bandwidth win without giving up MXU throughput.

Per-channel scales stay *outside* the kernel: a row scale of A and a column
scale of B factor out of the matmul, so the epilogue multiplies the f32
output tile once — no per-element scale traffic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lns import LNSFormat, lns_decode_packed
from repro.kernels.dispatch import resolve_interpret

__all__ = ["lns_qmatmul_pallas"]


def _kernel(pa_ref, pb_ref, out_ref, *, fmt: LNSFormat, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # tile-local unpack+decode: the one shared definition in core.lns, so
    # the kernel prologue cannot drift from the jnp oracle
    a = lns_decode_packed(pa_ref[...], fmt, compute_dtype)
    b = lns_decode_packed(pb_ref[...], fmt, compute_dtype)
    out_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "compute_dtype", "block_m", "block_n", "block_k",
                     "interpret"),
)
def lns_qmatmul_pallas(
    pa: jax.Array,
    pb: jax.Array,
    fmt: LNSFormat,
    *,
    compute_dtype=jnp.bfloat16,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``pa (M,K)`` x ``pb (K,N)`` packed LNS words -> f32 (M,N) (unscaled).

    Tile sizes default to the MXU-aligned 128; VMEM per step is
    ``bm·bk + bk·bn`` bytes of codes + the bf16 decodes + the f32 out tile.
    ``interpret=None`` auto-detects the platform (compiled on real TPU).
    """
    interpret = resolve_interpret(interpret)
    M, K = pa.shape
    K2, N = pb.shape
    assert K == K2, (pa.shape, pb.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"shapes ({M},{K})x({K},{N}) must tile by ({block_m},{block_n},{block_k})")

    grid = (M // block_m, N // block_n, K // block_k)
    kernel = functools.partial(_kernel, fmt=fmt, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(pa, pb)
