"""Fused LNS-Madam weight update (Algorithm 1) as a Pallas kernel.

One pass over (code, sign, grad, v) producing (code', v'): second-moment
EMA, bias-corrected normalization, and the integer exponent step
``code' = clamp(round(code + η·γ_U·g*·sign(W)))`` — all in VMEM, so the
update path touches each weight exactly once in HBM (read code+grad+v,
write code+v). No integer->LNS conversion anywhere (paper §4).

The bias-correction factor ``bc = 1 - β^t`` depends on the step count, so it
arrives as a (1,1) operand rather than a static constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lns import LNSFormat

__all__ = ["madam_update_pallas"]


def _kernel(bc_ref, code_ref, sign_ref, g_ref, v_ref, code_out, v_out, *,
            lr: float, beta: float, eps: float, gamma: int, max_code: int):
    bc = bc_ref[0, 0]
    g = g_ref[...].astype(jnp.float32)
    v = (1.0 - beta) * g * g + beta * v_ref[...]
    gstar = g * jax.lax.rsqrt(v / bc + eps)
    step = (lr * gamma) * gstar * sign_ref[...].astype(jnp.float32)
    target = code_ref[...].astype(jnp.float32) + step
    code = jnp.clip(jnp.floor(target + 0.5), 0, max_code)
    code_out[...] = code.astype(code_out.dtype)
    v_out[...] = v


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "block_r", "block_c",
                     "interpret"),
)
def madam_update_pallas(
    code: jax.Array,
    sign: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = True,
):
    """Fused Madam step on 2-D LNS weights. Returns (new_code, new_v).

    ``count`` is the post-increment step (>= 1) used for bias correction.
    """
    R, C = code.shape
    assert sign.shape == (R, C) and g.shape == (R, C) and v.shape == (R, C)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    grid = (R // block_r, C // block_c)
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        max_code=fmt.max_code)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), code.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(bc, code, sign, g, v)
