"""Fused LNS-Madam weight update (Algorithm 1) as a Pallas kernel.

One pass over (code, sign, grad, v) producing (code', v'): second-moment
EMA, bias-corrected normalization, and the integer exponent step
``code' = clamp(round(code + η·γ_U·g*·sign(W)))`` — all in VMEM, so the
update path touches each weight exactly once in HBM (read code+grad+v,
write code+v). No integer->LNS conversion anywhere (paper §4).

The bias-correction factor ``bc = 1 - β^t`` depends on the step count, so it
arrives as a (1,1) operand rather than a static constant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lns import LNSFormat
from repro.kernels.dispatch import resolve_interpret

__all__ = ["madam_update_pallas", "madam_update_packed_pallas"]


def _step_math(code, sign, g, v, bc, *, lr, beta, eps, gamma, max_code):
    """Shared Algorithm-1 tile math: returns (new_code f32-rounded, new_v)."""
    g = g.astype(jnp.float32)
    v = (1.0 - beta) * g * g + beta * v
    gstar = g * jax.lax.rsqrt(v / bc + eps)
    step = (lr * gamma) * gstar * sign.astype(jnp.float32)
    target = code.astype(jnp.float32) + step
    return jnp.clip(jnp.floor(target + 0.5), 0, max_code), v


def _kernel(bc_ref, code_ref, sign_ref, g_ref, v_ref, code_out, v_out, *,
            lr: float, beta: float, eps: float, gamma: int, max_code: int):
    code, v = _step_math(code_ref[...], sign_ref[...], g_ref[...], v_ref[...],
                         bc_ref[0, 0], lr=lr, beta=beta, eps=eps, gamma=gamma,
                         max_code=max_code)
    code_out[...] = code.astype(code_out.dtype)
    v_out[...] = v


def _packed_kernel(bc_ref, w_ref, g_ref, v_ref, w_out, v_out, *,
                   lr: float, beta: float, eps: float, gamma: int, bits: int):
    """Packed-word variant: unpack, step, repack — all in VMEM, so the
    update reads/writes exactly one wire word per weight in HBM."""
    max_code = (1 << (bits - 1)) - 1
    w = w_ref[...].astype(jnp.int32)
    sign_bit = (w >> (bits - 1)) & 1
    code, v = _step_math(w & max_code, 1 - 2 * sign_bit, g_ref[...],
                         v_ref[...], bc_ref[0, 0], lr=lr, beta=beta, eps=eps,
                         gamma=gamma, max_code=max_code)
    w_out[...] = ((sign_bit << (bits - 1)) | code.astype(jnp.int32)
                  ).astype(w_out.dtype)
    v_out[...] = v


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "block_r", "block_c",
                     "interpret"),
)
def madam_update_pallas(
    code: jax.Array,
    sign: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
):
    """Fused Madam step on 2-D LNS weights. Returns (new_code, new_v).

    ``count`` is the post-increment step (>= 1) used for bias correction.
    ``interpret=None`` auto-detects the platform (compiled on real TPU).
    """
    interpret = resolve_interpret(interpret)
    R, C = code.shape
    assert sign.shape == (R, C) and g.shape == (R, C) and v.shape == (R, C)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    grid = (R // block_r, C // block_c)
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        max_code=fmt.max_code)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), code.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(bc, code, sign, g, v)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "block_r", "block_c",
                     "interpret"),
)
def madam_update_packed_pallas(
    packed: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
):
    """Fused Madam step on *packed wire words* — the production update.

    Reads (word, grad, v) and writes (word', v') in one HBM pass; the sign
    bit never leaves the word (multiplicative updates preserve sign), so
    the parameter traffic is 1 byte/element each way at B<=8. Returns
    ``(new_packed, new_v)``.
    """
    interpret = resolve_interpret(interpret)
    R, C = packed.shape
    assert g.shape == (R, C) and v.shape == (R, C), (packed.shape, g.shape,
                                                     v.shape)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    grid = (R // block_r, C // block_c)
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _packed_kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        bits=fmt.bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), packed.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(bc, packed, g, v)
