"""Fused LNS-Madam weight update (Algorithm 1) as a Pallas kernel.

One pass over (code, sign, grad, v) producing (code', v'): second-moment
EMA, bias-corrected normalization, and the integer exponent step
``code' = clamp(round(code + η·γ_U·g*·sign(W)))`` — all in VMEM, so the
update path touches each weight exactly once in HBM (read code+grad+v,
write code+v). No integer->LNS conversion anywhere (paper §4).

The bias-correction factor ``bc = 1 - β^t`` depends on the step count, so it
arrives as a (1,1) operand rather than a static constant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lns import LNSFormat, quantization_gap
from repro.kernels.dispatch import resolve_interpret

__all__ = ["madam_update_pallas", "madam_update_packed_pallas",
           "madam_update_packed_stats_pallas", "madam_stats_vec",
           "madam_stats_dict", "requant_spec", "MADAM_STAT_KEYS",
           "MADAM_STAT_WIDTH"]

# numerics-telemetry epilogue (DESIGN.md §14): per-tile partial sums the
# stats kernel variant writes next to (word', v'). Layout of the width-8
# f32 vector (last two slots reserved):
#   0 sat_lo    count of steps rounding below code 0 (overflow rail clamp)
#   1 sat_hi    count of steps rounding above max_code (underflow rail)
#   2 dead      count of nonzero intended steps with zero code delta
#   3 qerr_sum  sum of |2^(-(code'-target)/γ) - 1| (realized vs ideal
#               multiplicative step, the paper's Thm.-1 quantity)
#   4 code_sum  sum of new codes (drift toward a rail shows as a trend)
#   5 req_hi    count of codes that will clamp when re-gridded to the
#               forward format (the B_U -> B_W requant clip site)
MADAM_STAT_KEYS = ("sat_lo", "sat_hi", "dead_frac", "qerr_rel",
                   "qerr_gap_ratio", "code_mean", "requant_sat_hi")
MADAM_STAT_WIDTH = 8


def requant_spec(src: LNSFormat, dst: Optional[LNSFormat]):
    """Static ``(ratio, dst_max_code)`` for the forward re-grid stat, or
    ``None`` when the epilogue has nothing to count: no forward format,
    the identity re-grid (serving trains on the forward grid already), or
    a widening re-grid (finer grid, ``keep_range`` scales the ceiling)."""
    if dst is None:
        return None
    if (src.bits, src.gamma) == (dst.bits, dst.gamma):
        return None
    if dst.gamma >= src.gamma:
        return None
    return (src.gamma // dst.gamma, dst.max_code)


def madam_stats_vec(code, target, new_code, *, gamma: int, max_code: int,
                    requant=None):
    """Partial-sum stat vector over one tile (or one whole leaf).

    Pure elementwise jnp + full reductions, so the same function traces
    inside the Pallas kernel body and in the jnp reference backend —
    counts are exact on both. Zero-padded tiles contribute exactly zero
    to every slot (pad words are code 0 with g=0, a fixed point).
    """
    codef = code.astype(jnp.float32)
    rounded = jnp.floor(target + 0.5)
    f32 = lambda m: m.astype(jnp.float32)
    n_lo = jnp.sum(f32(rounded < 0))
    n_hi = jnp.sum(f32(rounded > max_code))
    dead = jnp.sum(f32((new_code == codef) & (target != codef)))
    qerr = jnp.sum(jnp.abs(jnp.exp2(-(new_code - target) / gamma) - 1.0))
    code_sum = jnp.sum(new_code)
    zero = jnp.zeros((), jnp.float32)
    if requant is not None:
        ratio, dst_max = requant
        nc = new_code.astype(jnp.int32)
        req_hi = jnp.sum(f32((nc + ratio // 2) // ratio > dst_max))
    else:
        req_hi = zero
    return jnp.stack([n_lo, n_hi, dead, qerr, code_sum, req_hi, zero, zero])


def madam_stats_dict(vec, n: int, fmt: LNSFormat,
                     requant_fmt: Optional[LNSFormat] = None):
    """Normalize a summed stat vector into the named per-leaf stats.

    ``qerr_gap_ratio`` divides the mean realized step error by the
    relative :func:`quantization_gap` at the leaf's format — the
    round-to-nearest floor is ~0.25 of the gap, so a ratio drifting far
    above that flags clipping/saturation rather than benign rounding.
    """
    del requant_fmt  # the static requant spec already shaped slot 5
    inv = 1.0 / float(max(n, 1))
    gap_rel = quantization_gap(jnp.ones((), jnp.float32), fmt)
    out = {
        "sat_lo": vec[0] * inv,
        "sat_hi": vec[1] * inv,
        "dead_frac": vec[2] * inv,
        "qerr_rel": vec[3] * inv,
        "code_mean": vec[4] * inv,
        "requant_sat_hi": vec[5] * inv,
    }
    out["qerr_gap_ratio"] = out["qerr_rel"] / gap_rel
    return out


def _step_math(code, sign, g, v, bc, *, lr, beta, eps, gamma, max_code):
    """Shared Algorithm-1 tile math: returns (new_code f32-rounded, new_v,
    target) — ``target`` is the pre-round/pre-clip exponent the stats
    epilogue compares the realized step against."""
    g = g.astype(jnp.float32)
    v = (1.0 - beta) * g * g + beta * v
    gstar = g * jax.lax.rsqrt(v / bc + eps)
    step = (lr * gamma) * gstar * sign.astype(jnp.float32)
    target = code.astype(jnp.float32) + step
    return jnp.clip(jnp.floor(target + 0.5), 0, max_code), v, target


def _kernel(bc_ref, code_ref, sign_ref, g_ref, v_ref, code_out, v_out, *,
            lr: float, beta: float, eps: float, gamma: int, max_code: int):
    code, v, _ = _step_math(code_ref[...], sign_ref[...], g_ref[...],
                            v_ref[...], bc_ref[0, 0], lr=lr, beta=beta,
                            eps=eps, gamma=gamma, max_code=max_code)
    code_out[...] = code.astype(code_out.dtype)
    v_out[...] = v


def _packed_kernel(bc_ref, w_ref, g_ref, v_ref, w_out, v_out, *,
                   lr: float, beta: float, eps: float, gamma: int, bits: int):
    """Packed-word variant: unpack, step, repack — all in VMEM, so the
    update reads/writes exactly one wire word per weight in HBM."""
    max_code = (1 << (bits - 1)) - 1
    w = w_ref[...].astype(jnp.int32)
    sign_bit = (w >> (bits - 1)) & 1
    code, v, _ = _step_math(w & max_code, 1 - 2 * sign_bit, g_ref[...],
                            v_ref[...], bc_ref[0, 0], lr=lr, beta=beta,
                            eps=eps, gamma=gamma, max_code=max_code)
    w_out[...] = ((sign_bit << (bits - 1)) | code.astype(jnp.int32)
                  ).astype(w_out.dtype)
    v_out[...] = v


def _packed_stats_kernel(bc_ref, w_ref, g_ref, v_ref, w_out, v_out,
                         stats_out, *, lr: float, beta: float, eps: float,
                         gamma: int, bits: int, requant):
    """Packed update + numerics epilogue: the stat partial sums are taken
    while (code, target, code') are live in VMEM — no second HBM pass."""
    max_code = (1 << (bits - 1)) - 1
    w = w_ref[...].astype(jnp.int32)
    sign_bit = (w >> (bits - 1)) & 1
    code = w & max_code
    new_code, v, target = _step_math(code, 1 - 2 * sign_bit, g_ref[...],
                                     v_ref[...], bc_ref[0, 0], lr=lr,
                                     beta=beta, eps=eps, gamma=gamma,
                                     max_code=max_code)
    w_out[...] = ((sign_bit << (bits - 1)) | new_code.astype(jnp.int32)
                  ).astype(w_out.dtype)
    v_out[...] = v
    stats_out[...] = madam_stats_vec(
        code, target, new_code, gamma=gamma, max_code=max_code,
        requant=requant).reshape(1, 1, MADAM_STAT_WIDTH)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "block_r", "block_c",
                     "interpret"),
)
def madam_update_pallas(
    code: jax.Array,
    sign: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
):
    """Fused Madam step on 2-D LNS weights. Returns (new_code, new_v).

    ``count`` is the post-increment step (>= 1) used for bias correction.
    ``interpret=None`` auto-detects the platform (compiled on real TPU).
    """
    interpret = resolve_interpret(interpret)
    R, C = code.shape
    assert sign.shape == (R, C) and g.shape == (R, C) and v.shape == (R, C)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    grid = (R // block_r, C // block_c)
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        max_code=fmt.max_code)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), code.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(bc, code, sign, g, v)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "block_r", "block_c",
                     "interpret"),
)
def madam_update_packed_pallas(
    packed: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
):
    """Fused Madam step on *packed wire words* — the production update.

    Reads (word, grad, v) and writes (word', v') in one HBM pass; the sign
    bit never leaves the word (multiplicative updates preserve sign), so
    the parameter traffic is 1 byte/element each way at B<=8. Returns
    ``(new_packed, new_v)``.
    """
    interpret = resolve_interpret(interpret)
    R, C = packed.shape
    assert g.shape == (R, C) and v.shape == (R, C), (packed.shape, g.shape,
                                                     v.shape)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    grid = (R // block_r, C // block_c)
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _packed_kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        bits=fmt.bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), packed.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(bc, packed, g, v)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "lr", "beta", "eps", "requant", "block_r",
                     "block_c", "interpret"),
)
def madam_update_packed_stats_pallas(
    packed: jax.Array,
    g: jax.Array,
    v: jax.Array,
    count: jax.Array,
    fmt: LNSFormat,
    *,
    lr: float,
    beta: float = 0.999,
    eps: float = 1e-30,
    requant=None,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
):
    """Packed Madam step with the numerics-stat epilogue fused in.

    Identical (word', v') to :func:`madam_update_packed_pallas` plus a
    summed ``(MADAM_STAT_WIDTH,)`` f32 stat vector (layout at the top of
    this module). Each tile writes its partial sums to a (1,1,W) lane and
    the grid-shaped output is reduced here — the weights and grads are
    still touched exactly once in HBM. ``requant`` is the static
    ``requant_spec(...)`` tuple or ``None``. Returns
    ``(new_packed, new_v, stats_vec)``.
    """
    interpret = resolve_interpret(interpret)
    R, C = packed.shape
    assert g.shape == (R, C) and v.shape == (R, C), (packed.shape, g.shape,
                                                     v.shape)
    assert R % block_r == 0 and C % block_c == 0, (
        f"({R},{C}) must tile by ({block_r},{block_c})")

    bc = (1.0 - beta ** count.astype(jnp.float32)).reshape(1, 1)
    gr, gc = R // block_r, C // block_c
    tile = lambda i, j: (i, j)
    kernel = functools.partial(
        _packed_stats_kernel, lr=lr, beta=beta, eps=eps, gamma=fmt.gamma,
        bits=fmt.bits, requant=requant)
    new_packed, new_v, stats = pl.pallas_call(
        kernel,
        grid=(gr, gc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((block_r, block_c), tile),
            pl.BlockSpec((1, 1, MADAM_STAT_WIDTH), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), packed.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
            jax.ShapeDtypeStruct((gr, gc, MADAM_STAT_WIDTH), jnp.float32),
        ],
        interpret=interpret,
    )(bc, packed, g, v)
    return new_packed, new_v, stats.sum(axis=(0, 1))
