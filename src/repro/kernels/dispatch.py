"""Kernel backend registry: one dispatch point for the LNS hot paths.

Every production consumer of the packed-LNS datapath (``qeinsum`` weight
GEMMs, the Madam update, activation encode) routes through this module
instead of importing a kernel directly (DESIGN.md §4). Two backends:

* ``"pallas"``    — the Pallas TPU kernels (compiled Mosaic on real TPUs,
  interpret mode elsewhere). Default on TPU/GPU.
* ``"reference"`` — pure-jnp oracles with bit-identical semantics. Default
  on CPU, where interpret-mode Pallas is a ~100x slowdown; also the
  equivalence anchor the tests pin the kernels against.

Selection precedence (one rule for every op, highest first):

1. :func:`configure` / the :func:`configured` context manager — the
   process-level override an application sets once at startup.
2. The explicit per-call ``backend=`` / ``interpret=`` argument — this is
   the channel config fields (``QuantConfig.backend`` et al.) thread
   through, so a config field behaves as a per-call argument.
3. ``REPRO_KERNEL_BACKEND`` / ``REPRO_KERNEL_INTERPRET`` env vars — the
   ambient outermost layer (CI legs, one-off shell runs).
4. Platform auto-detection: pallas+compiled on TPU/GPU, reference (and
   interpret-mode Pallas where explicitly requested) elsewhere — compiled
   Mosaic is never silently replaced by the interpreter on hardware, and
   the interpreter is never accidentally shipped to a TPU job.

Env vars and :func:`configure` state are read at trace time — set them
before the first jit of a step function.

Kernel-time attribution (DESIGN.md §13): every dispatched op checks
``repro.obs.kernel_stats`` for an active collector. Disabled — the
default — that is one module-global load per call (and these ops run at
*trace* time inside the serving jits, so the per-token hot loop never
sees even that). Enabled, calls are attributed by
(op, backend, bitwidth): trace-time entries bump compile counters,
eager calls record launch walltime, and a sampling knob occasionally
blocks until ready for true device time. :func:`profiler_trace`
(re-exported) wraps ``jax.profiler`` for whole-program XLA traces.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import (LNSFormat, compute_scale, lns_decode_packed,
                            lns_encode, lns_pack, lns_requant_packed,
                            lns_unpack, lns_word_dtype)
from repro.obs import kernel_stats
from repro.obs.kernel_stats import profiler_trace

__all__ = [
    "BACKENDS",
    "ENV_BACKEND",
    "ENV_INTERPRET",
    "configure",
    "configured",
    "get_configured",
    "default_backend",
    "resolve_backend",
    "resolve_interpret",
    "kernel_stats",
    "profiler_trace",
    "qmatmul",
    "encode_pack",
    "requant_pack",
    "madam_step",
    "paged_attend",
    "fused_sample",
]

BACKENDS = ("pallas", "reference")
ENV_BACKEND = "REPRO_KERNEL_BACKEND"
ENV_INTERPRET = "REPRO_KERNEL_INTERPRET"

_UNSET = object()  # configure() sentinel: "leave this layer untouched"


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """The process-level override layer (precedence layer 1). ``None``
    fields fall through to the per-call argument / env / auto layers."""

    backend: Optional[str] = None
    interpret: Optional[bool] = None


_configured = DispatchConfig()


def configure(*, backend=_UNSET, interpret=_UNSET) -> DispatchConfig:
    """Set the process-level kernel dispatch override.

    ``configure(backend="reference")`` pins every dispatched op to the
    jnp oracle regardless of per-call arguments or env vars; ``None``
    clears a field back to the lower layers. Omitted fields are left
    untouched. Returns the new state. Applies at trace time — call it
    before the first jit of a step function.
    """
    global _configured
    kw = {}
    if backend is not _UNSET:
        if backend is not None and backend not in BACKENDS:
            raise ValueError(
                f"backend {backend!r}: expected one of {BACKENDS} or None")
        kw["backend"] = backend
    if interpret is not _UNSET:
        kw["interpret"] = None if interpret is None else bool(interpret)
    _configured = dataclasses.replace(_configured, **kw)
    return _configured


def get_configured() -> DispatchConfig:
    """The current process-level override state (read-only snapshot)."""
    return _configured


@contextlib.contextmanager
def configured(*, backend=_UNSET, interpret=_UNSET) -> Iterator[DispatchConfig]:
    """Scoped :func:`configure`: apply overrides inside a ``with`` block,
    restore the previous state on exit (exceptions included).

    >>> with dispatch.configured(backend="reference"):
    ...     engine.run(requests)   # every dispatched op hits the oracle
    """
    global _configured
    prev = _configured
    try:
        yield configure(backend=backend, interpret=interpret)
    finally:
        _configured = prev


def default_backend() -> str:
    """``REPRO_KERNEL_BACKEND`` if set, else pallas on TPU/GPU, reference
    elsewhere. (Layers 3-4 only — :func:`resolve_backend` adds the rest.)"""
    env = os.environ.get(ENV_BACKEND, "").strip().lower()
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"{ENV_BACKEND}={env!r}: expected one of {BACKENDS}")
        return env
    return "pallas" if jax.default_backend() in ("tpu", "gpu") else "reference"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Full precedence chain: configure() > per-call arg > env > auto."""
    if _configured.backend is not None:
        return _configured.backend
    if backend is None:
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r}: expected one of {BACKENDS}")
    return backend


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Interpret-mode resolution: configure() > per-call arg > env > auto.

    Auto-detection: compiled wherever the pallas backend is the default
    (TPU: Mosaic, GPU: Triton), interpreter elsewhere — so the platforms
    that default to ``"pallas"`` never silently run the ~100x interpreter.
    Env values: {auto, 0, 1, false, true}.
    """
    if _configured.interpret is not None:
        return _configured.interpret
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_INTERPRET, "auto").strip().lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    if env not in ("", "auto"):
        raise ValueError(
            f"{ENV_INTERPRET}={env!r}: expected auto, 0, 1, false or true")
    return jax.default_backend() not in ("tpu", "gpu")


# ---------------------------------------------------------------------------
# dispatched operations


def qmatmul(pa: jax.Array, pb: jax.Array, fmt: LNSFormat,
            scale_a: Optional[jax.Array] = None,
            scale_b: Optional[jax.Array] = None, *,
            compute_dtype=jnp.bfloat16,
            backend: Optional[str] = None,
            interpret: Optional[bool] = None) -> jax.Array:
    """Packed ``pa (M,K) @ pb (K,N)`` -> f32, per-row/col scale epilogue."""
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "qmatmul", resolve_backend(backend), fmt.bits, pa, _qmatmul,
            pa, pb, fmt, scale_a, scale_b, compute_dtype=compute_dtype,
            backend=backend, interpret=interpret)
    return _qmatmul(pa, pb, fmt, scale_a, scale_b,
                    compute_dtype=compute_dtype, backend=backend,
                    interpret=interpret)


def _qmatmul(pa, pb, fmt, scale_a=None, scale_b=None, *,
             compute_dtype=jnp.bfloat16, backend=None, interpret=None):
    if resolve_backend(backend) == "pallas":
        from repro.kernels.ops import lns_qmatmul
        return lns_qmatmul(pa, pb, fmt, scale_a, scale_b,
                           compute_dtype=compute_dtype,
                           interpret=resolve_interpret(interpret))
    a = lns_decode_packed(pa, fmt, compute_dtype)
    b = lns_decode_packed(pb, fmt, compute_dtype)
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if scale_a is not None:
        out = out * scale_a
    if scale_b is not None:
        out = out * scale_b
    return out


def encode_pack(x: jax.Array, fmt: LNSFormat, scale_axis: Optional[int] = None,
                *, backend: Optional[str] = None,
                interpret: Optional[bool] = None):
    """Q_log-encode a 2-D tensor into packed words + its broadcast scale.

    Returns ``(packed (R,C), scale (R,1) f32)``; ``scale_axis=0`` keeps
    per-row scales, ``None`` is per-tensor (broadcast to (R,1)).
    """
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "encode_pack", resolve_backend(backend), fmt.bits, x,
            _encode_pack, x, fmt, scale_axis, backend=backend,
            interpret=interpret)
    return _encode_pack(x, fmt, scale_axis, backend=backend,
                        interpret=interpret)


def _encode_pack(x, fmt, scale_axis=None, *, backend=None, interpret=None):
    if resolve_backend(backend) == "pallas":
        from repro.kernels.ops import quantize_pack
        return quantize_pack(x, fmt, scale_axis,
                             interpret=resolve_interpret(interpret))
    R = x.shape[0]
    scale = compute_scale(x, axis=scale_axis)
    srow = jnp.broadcast_to(
        scale.reshape(-1, 1) if scale.ndim else scale, (R, 1)
    ).astype(jnp.float32)
    sign, code = lns_encode(x, fmt, srow)
    return lns_pack(sign, code, fmt), srow


def requant_pack(packed: jax.Array, src: LNSFormat, dst: LNSFormat, *,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Re-grid packed wire words from ``src`` to ``dst`` bits (any rank).

    The self-speculative draft transform (paper §6.1.1): a lower-bitwidth
    *view* of the same weights on a coarser exponent grid — integer-only,
    sign preserved, scales untouched. Both backends are bit-identical: the
    Pallas kernel body traces :func:`lns_requant_packed` directly.
    """
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "requant_pack", resolve_backend(backend), dst.bits, packed,
            _requant_pack, packed, src, dst, backend=backend,
            interpret=interpret)
    return _requant_pack(packed, src, dst, backend=backend,
                         interpret=interpret)


def _requant_pack(packed, src, dst, *, backend=None, interpret=None):
    if resolve_backend(backend) == "pallas":
        from repro.kernels.ops import requant_pack as requant_pack_op
        return requant_pack_op(packed, src, dst,
                               interpret=resolve_interpret(interpret))
    return lns_requant_packed(packed, src, dst)


def madam_step(packed: jax.Array, g: jax.Array, v: jax.Array,
               count: jax.Array, fmt: LNSFormat, *, lr: float,
               beta: float = 0.999, eps: float = 1e-30,
               with_stats: bool = False,
               requant_fmt: Optional[LNSFormat] = None,
               backend: Optional[str] = None,
               interpret: Optional[bool] = None):
    """Fused Algorithm-1 step on a packed >=2-D leaf. Returns
    ``(new_packed, new_v)``, or ``(new_packed, new_v, stats)`` with
    ``with_stats=True``.

    One HBM pass over (packed, grad, v): the second-moment EMA, the
    bias-corrected normalization, and the integer exponent step all happen
    on the word in VMEM — the sign bit is carried through untouched
    (multiplicative updates never flip sign). Leaves of any rank fold to
    2-D (the update is elementwise).

    ``with_stats`` folds the numerics-telemetry epilogue (DESIGN.md §14)
    into the same pass: ``stats`` is a dict of scalar traces keyed by
    ``MADAM_STAT_KEYS`` — rail saturation fractions, dead-update
    fraction, realized-vs-ideal step error, code mean, and (when
    ``requant_fmt`` names a coarser forward grid) the fraction of codes
    the B_U -> B_W re-grid will clamp.
    """
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "madam_step", resolve_backend(backend), fmt.bits, packed,
            _madam_step, packed, g, v, count, fmt, lr=lr, beta=beta,
            eps=eps, with_stats=with_stats, requant_fmt=requant_fmt,
            backend=backend, interpret=interpret)
    return _madam_step(packed, g, v, count, fmt, lr=lr, beta=beta, eps=eps,
                       with_stats=with_stats, requant_fmt=requant_fmt,
                       backend=backend, interpret=interpret)


def _madam_step(packed, g, v, count, fmt, *, lr, beta=0.999, eps=1e-30,
                with_stats=False, requant_fmt=None, backend=None,
                interpret=None):
    from repro.kernels.madam_update import madam_stats_dict, requant_spec
    shape = packed.shape
    if packed.ndim < 2:
        raise ValueError(f"madam_step needs a >=2-D leaf, got {shape}")
    p2 = packed.reshape(-1, shape[-1])
    g2 = g.reshape(p2.shape)
    v2 = v.reshape(p2.shape)
    requant = requant_spec(fmt, requant_fmt) if with_stats else None
    vec = None
    if resolve_backend(backend) == "pallas":
        from repro.kernels.ops import madam_step_packed, madam_step_packed_stats
        if with_stats:
            np_, nv, vec = madam_step_packed_stats(
                p2, g2, v2, count, fmt, lr=lr, beta=beta, eps=eps,
                requant=requant, interpret=resolve_interpret(interpret))
        else:
            np_, nv = madam_step_packed(
                p2, g2, v2, count, fmt, lr=lr, beta=beta, eps=eps,
                interpret=resolve_interpret(interpret))
    elif with_stats:
        np_, nv, vec = _madam_step_reference(p2, g2, v2, count, fmt, lr=lr,
                                             beta=beta, eps=eps,
                                             with_stats=True, requant=requant)
    else:
        np_, nv = _madam_step_reference(p2, g2, v2, count, fmt, lr=lr,
                                        beta=beta, eps=eps)
    if with_stats:
        stats = madam_stats_dict(vec, p2.size, fmt, requant_fmt)
        return np_.reshape(shape), nv.reshape(shape), stats
    return np_.reshape(shape), nv.reshape(shape)


def paged_attend(q: jax.Array, kp: jax.Array, vp: jax.Array,
                 k_scale: Optional[jax.Array], v_scale: Optional[jax.Array],
                 block_table: jax.Array, lengths: jax.Array, *,
                 fmt: Optional[LNSFormat] = None,
                 softcap: Optional[float] = None,
                 sm_scale: float,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Attend ``q`` over a block-paged KV pool through a block table.

    ``q`` is (B, S, H, hd); ``kp``/``vp`` are (P, page, KV, hd) pools —
    packed LNS words when ``fmt`` is given (with (P, page, KV, 1) scales),
    the compute dtype otherwise. ``block_table`` (B, max_pages) maps each
    slot's local page j to a pool page; ``lengths`` (B,) counts the valid
    positions per slot *including* the S just written, so query s sits at
    absolute position ``lengths - S + s``. Returns f32 (B, S, H, hd).

    The Pallas kernel serves decode (S == 1) *and* prefill-over-block-table
    (S > 1, the engine's batch-1 suffix prefill) shapes: pages gather
    tile-locally via scalar-prefetched block tables, double-buffered DMAs
    and in-kernel LNS decode (see ``kernels/paged_attend.py``). The
    reference backend is the jnp gather oracle below.

    Under an active mesh whose ``model`` axis divides the KV head count,
    either backend runs per-shard over its local head group via
    ``shard_map`` (pools head-sharded, one replicated logical block table)
    with an all-gather epilogue back to replicated heads — the collective
    placement lives here, in the dispatch layer, so the jnp reference and
    the Pallas kernel stay bit-comparable shard for shard.
    """
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "paged_attend", resolve_backend(backend),
            fmt.bits if fmt is not None else 0, q, _paged_attend,
            q, kp, vp, k_scale, v_scale, block_table, lengths, fmt=fmt,
            softcap=softcap, sm_scale=sm_scale, backend=backend,
            interpret=interpret)
    return _paged_attend(q, kp, vp, k_scale, v_scale, block_table, lengths,
                         fmt=fmt, softcap=softcap, sm_scale=sm_scale,
                         backend=backend, interpret=interpret)


def _paged_attend(q, kp, vp, k_scale, v_scale, block_table, lengths, *,
                  fmt=None, softcap=None, sm_scale, backend=None,
                  interpret=None):
    use_pallas = resolve_backend(backend) == "pallas"
    interp = resolve_interpret(interpret) if use_pallas else None

    def impl(q, kp, vp, ks, vs, bt, ln):
        if use_pallas:
            from repro.kernels.ops import paged_attend_blocktable
            return paged_attend_blocktable(q, kp, vp, ks, vs, bt, ln,
                                           fmt=fmt, softcap=softcap,
                                           sm_scale=sm_scale,
                                           interpret=interp)
        return _paged_attend_reference(q, kp, vp, ks, vs, bt, ln, fmt=fmt,
                                       softcap=softcap, sm_scale=sm_scale)

    from repro.distributed.sharding import current_mesh, model_axis_size
    mesh = current_mesh()
    m = model_axis_size(mesh)
    if mesh is not None and m > 1 and kp.shape[2] % m == 0:
        return _paged_attend_sharded(impl, mesh, q, kp, vp, k_scale,
                                     v_scale, block_table, lengths)
    return impl(q, kp, vp, k_scale, v_scale, block_table, lengths)


def _paged_attend_sharded(impl, mesh, q, kp, vp, k_scale, v_scale,
                          block_table, lengths):
    """Head-group-parallel paged attention over the mesh ``model`` axis.

    Each shard attends its local KV head group (and the matching query
    group — GQA groups are contiguous in the head axis, so an even head
    split never severs a group) against its local slice of every pool
    page; the block table and lengths are replicated, giving every shard
    the same page-local view of the one logical table. Heads never mix
    across shards inside attention, so per-shard results are bitwise what
    a single device computes for those heads; the trailing constraint is
    the explicit all-gather epilogue back to replicated heads.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    heads = P(None, None, "model", None)
    if k_scale is not None:
        body = lambda q, kp, vp, ks, vs, bt, ln: impl(q, kp, vp, ks, vs,
                                                      bt, ln)
        in_specs = (heads, heads, heads, heads, heads, P(None, None), P(None))
        args = (q, kp, vp, k_scale, v_scale, block_table, lengths)
    else:
        body = lambda q, kp, vp, bt, ln: impl(q, kp, vp, None, None, bt, ln)
        in_specs = (heads, heads, heads, P(None, None), P(None))
        args = (q, kp, vp, block_table, lengths)
    out = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=heads,
                    check_rep=False)(*args)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def fused_sample(logits: jax.Array, gumbel: Optional[jax.Array],
                 temp: Optional[jax.Array], *,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Token selection epilogue: ``logits (B, V)`` -> ``(B,)`` int32.

    ``gumbel is None`` is pure greedy (first-max-wins argmax over the raw
    logits). Otherwise each row draws ``argmax(logits / max(temp, 1e-6)
    + gumbel)`` when its ``temp > 0`` and falls back to greedy when not —
    exactly the sort-free fast path of ``server.sampling``. The gumbel
    noise is generated by the caller with ``jax.random`` (keys fold in the
    request seed/step), so a seeded request replays token-for-token on
    either backend; the kernel fuses only the scale/add/argmax epilogue.
    """
    if kernel_stats.active() is not None:
        return kernel_stats.observe(
            "fused_sample", resolve_backend(backend), 0, logits,
            _fused_sample, logits, gumbel, temp, backend=backend,
            interpret=interpret)
    return _fused_sample(logits, gumbel, temp, backend=backend,
                         interpret=interpret)


def _fused_sample(logits, gumbel, temp, *, backend=None, interpret=None):
    if resolve_backend(backend) == "pallas":
        from repro.kernels.ops import fused_sample as fused_sample_op
        return fused_sample_op(logits, gumbel, temp,
                               interpret=resolve_interpret(interpret))
    return _fused_sample_reference(logits, gumbel, temp)


def _fused_sample_reference(logits, gumbel, temp):
    """jnp oracle for the fused sampler epilogue (first-max-wins argmax,
    bit-identical to the host-side np.argmax the engine once used)."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    if gumbel is None:
        return greedy
    scaled = lg / jnp.maximum(temp, 1e-6)[:, None]
    toks = jnp.argmax(scaled + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0.0, toks, greedy)


def _paged_attend_reference(q, kp, vp, k_scale, v_scale, block_table,
                            lengths, *, fmt, softcap, sm_scale):
    """jnp oracle: gather the slot's pages, decode, masked softmax.

    GQA is grouped rather than materialized: q reshapes to
    ``(B, S, kv, rep, hd)`` and the einsums carry the (group, repeat)
    axes, so the gathered KV view is never ``jnp.repeat``-ed ``rep``-fold
    — head ``h`` maps to group ``h // rep``, matching repeat semantics.
    """
    B, S, h, hd = q.shape
    page, kv = kp.shape[1], kp.shape[2]
    mp = block_table.shape[1]
    cap = mp * page

    def view(pool, scale):
        x = pool[block_table].reshape(B, cap, kv, hd)
        if fmt is None:
            return x.astype(jnp.float32)
        s = scale[block_table].reshape(B, cap, kv, 1)
        return lns_decode_packed(x, fmt, jnp.float32) * s.astype(jnp.float32)

    rep = h // kv
    kf = view(kp, k_scale)                              # (B, cap, kv, hd)
    vf = view(vp, v_scale)
    qg = q.astype(jnp.float32).reshape(B, S, kv, rep, hd)
    logits = jnp.einsum("bsgrd,bkgd->bgrsk", qg, kf)    # (B, kv, rep, S, cap)
    logits = logits.reshape(B, h, S, cap) * sm_scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    abs_pos = jnp.arange(cap)
    q_pos = (lengths - S)[:, None] + jnp.arange(S)  # (B, S)
    mask = abs_pos[None, None, :] <= q_pos[:, :, None]
    logits = jnp.where(mask[:, None], logits, -1e30)
    p_attn = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bgrsk,bkgd->bsgrd",
                     p_attn.reshape(B, kv, rep, S, cap), vf)
    return ctx.reshape(B, S, h, hd)


def _madam_step_reference(packed, g, v, count, fmt: LNSFormat, *, lr, beta,
                          eps, with_stats=False, requant=None):
    """jnp oracle for the fused packed update — bit-exact to the kernel
    because both call the one shared ``_step_math`` tile function (and,
    with ``with_stats``, the one shared ``madam_stats_vec`` epilogue)."""
    from repro.kernels.madam_update import _step_math  # cycle-free lazy
    sign_bit = ((packed.astype(jnp.int32) >> (fmt.bits - 1)) & 1)
    _, code = lns_unpack(packed, fmt)
    bc = 1.0 - beta ** count.astype(jnp.float32)
    new_code, nv, target = _step_math(code, 1 - 2 * sign_bit, g, v, bc, lr=lr,
                                      beta=beta, eps=eps, gamma=fmt.gamma,
                                      max_code=fmt.max_code)
    word = (sign_bit << (fmt.bits - 1)) | new_code.astype(jnp.int32)
    word = word.astype(lns_word_dtype(fmt))
    if not with_stats:
        return word, nv
    from repro.kernels.madam_update import madam_stats_vec
    vec = madam_stats_vec(code, target, new_code, gamma=fmt.gamma,
                          max_code=fmt.max_code, requant=requant)
    return word, nv, vec
