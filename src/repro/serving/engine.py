"""Continuous-batching engine over the packed-LNS decode path.

The engine owns a fixed decode batch of ``num_slots`` rows and one KV/state
cache sized ``(num_slots, max_len)``. Each row is an independent serving
slot:

- the cache write cursor (``cache["idx"]``) is per-row, so a freed slot
  restarts at position 0 while its neighbours keep decoding;
- admission prefills the prompt through the *decode* path at batch 1 with
  the prompt right-padded to a shape bucket (a handful of jit entries,
  see ``_bucket``), then scatters the mini-cache row into the freed slot
  with the cursor rewound to the true prompt length — so the padded tail
  is dead weight that the slot's own decode overwrites token by token;
- the decode step itself sees a single ``(num_slots, 1)`` shape forever:
  admitting a request never recompiles it (``decode_compiles`` stays 1);
- a finished sequence (EOS or ``max_new_tokens``) releases its slot and
  its cache rows are recycled in place by the next admission's scatter.

Weights stay in the packed 8-bit LNS wire format (``LNSWeight``) for the
whole request lifetime: routed GEMMs decode tile-locally through
``kernels/dispatch``, fallback leaves decode per layer inside the step —
the engine never materializes the tree and loads training checkpoints
with zero re-encoding (same bytes on disk, in the train state, and here).

Padding-safety: right-padded prefill is exact for attention caches (the
padded keys sit beyond the rewound cursor, masked and later overwritten)
but NOT for recurrent state (Mamba/RWKV consume pad tokens) nor for ring
buffers shorter than the bucket (pads would wrap onto live keys). In those
cases the engine prefills at the exact prompt length instead — correctness
first, one extra compile per distinct length second.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import QuantConfig
from repro.models.common import ArchConfig
from repro.models.model import forward, init_caches
from repro.optim.madam import MadamConfig
from repro.serving.metrics import RequestMetrics, summarize
from repro.serving.request import Request, RequestQueue, RequestState
from repro.serving.scheduler import Scheduler
from repro.training.steps import build_decode_step

__all__ = ["Engine", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


def _set_cursor(caches, n):
    """Rewind every per-slot cache cursor in a (batch=1) cache tree to n."""
    def visit(path, leaf):
        if getattr(path[-1], "key", None) == "idx":
            return jnp.full_like(leaf, n)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, caches)


class Engine:
    """Continuous-batching serving engine. See module docstring."""

    def __init__(
        self,
        cfg: ArchConfig,
        qcfg: Optional[QuantConfig],
        mcfg: Optional[MadamConfig],
        params: Any,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        scan_unroll: int | bool = 1,
    ):
        self.cfg, self.qcfg, self.mcfg = cfg, qcfg, mcfg
        self.params = params
        self.num_slots, self.max_len = num_slots, max_len
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))

        prefix, _, period = cfg.layer_pattern()
        kinds = set(prefix) | set(period)
        self._recurrent = bool(kinds & {"mamba", "rwkv"})
        self._window = cfg.sliding_window if "local" in kinds else None

        self._decode_fn = jax.jit(
            build_decode_step(cfg, qcfg, mcfg, scan_unroll=scan_unroll),
            donate_argnums=(1,))
        # one fused call per admission: batch-1 prefill through the decode
        # path + scatter of the produced rows into the engine cache
        self._prefill_fn = jax.jit(self._prefill_impl, donate_argnums=(1,))

        self.caches = init_caches(num_slots, max_len, cfg)
        # zero batch-1 cache reused by every admission's prefill (the jit
        # body is functional, so the template itself never mutates)
        self._mini_template = init_caches(1, max_len, cfg)
        self.scheduler = Scheduler(num_slots)
        self.queue = RequestQueue()
        # host mirrors of the in-graph per-slot cursors / last tokens
        self._slot_len = np.zeros((num_slots,), np.int64)
        tok_width = (cfg.num_codebooks,) if cfg.num_codebooks else ()
        self._last_tok = np.zeros((num_slots,) + tok_width, np.int32)
        self.completed: List[RequestMetrics] = []
        self.finished: List[RequestState] = []  # keeps generated tokens
        self.decode_steps = 0
        self.prefills = 0
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------
    # jitted bodies

    def _prefill_impl(self, params, big, mini, tokens, n, slot):
        """Batch-1 decode-path prefill of ``tokens`` over the zero cache
        ``mini``, cursor rewound to the true prompt length ``n``, rows
        scattered into row ``slot`` of the engine cache ``big``. Returns
        (last-real-position logits, updated engine cache)."""
        out = forward(params, tokens, self.cfg, self.qcfg, caches=mini,
                      pos_offset=0)
        logits = jnp.take(out.logits, n - 1, axis=1)  # (1, V)
        filled = _set_cursor(out.caches, n)

        def upd(b, m):
            # the slot axis is wherever the two shapes disagree (axis 0 for
            # plain leaves, axis 1 for period-stacked ones)
            ax = next((i for i, (x, y) in enumerate(zip(b.shape, m.shape))
                       if x != y), 0)
            start = [0] * b.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(
                b, m.astype(b.dtype), tuple(start))
        return logits, jax.tree.map(upd, big, filled)

    # ------------------------------------------------------------------
    # shape bucketing

    def _bucket(self, plen: int) -> int:
        assert plen <= self.max_len  # guaranteed by submit()
        if self._recurrent:
            return plen  # pads would pollute the recurrent state
        for b in self.buckets:
            if b >= plen and (self._window is None or b <= self._window):
                return b
        return plen  # no safe bucket: exact shape (ring wrap / long prompt)

    @property
    def prefill_compiles(self) -> int:
        return self._prefill_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._decode_fn._cache_size()

    # ------------------------------------------------------------------
    # lifecycle

    def reset(self) -> None:
        """Clear all request/slot state but keep the compiled steps — a
        reset engine re-runs a trace with warm jit caches (benchmarks)."""
        self.caches = init_caches(self.num_slots, self.max_len, self.cfg)
        self.scheduler = Scheduler(self.num_slots)
        self.queue = RequestQueue()
        self._slot_len[:] = 0
        self._last_tok[:] = 0
        self.completed, self.finished = [], []
        self.decode_steps = self.prefills = 0
        self._t0 = None

    def submit(self, req: Request) -> None:
        # reject before any slot is bound: failing later (inside _admit)
        # would leak the already-occupied slot and wedge the engine
        if req.prompt_len > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt len {req.prompt_len} exceeds "
                f"engine max_len {self.max_len}")
        self.queue.push(req)

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    def _greedy(self, logits) -> np.ndarray:
        lg = np.asarray(logits, np.float32)
        if self.cfg.num_codebooks:
            lg = lg.reshape(lg.shape[0], self.cfg.num_codebooks,
                            self.cfg.vocab_size)
        return np.argmax(lg, axis=-1).astype(np.int32)

    def _admit(self, rs: RequestState, clock) -> None:
        req = rs.request
        plen = req.prompt_len
        bucket = self._bucket(plen)
        prompt = np.asarray(req.prompt, np.int32)
        tokens = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
        tokens[0, :plen] = prompt

        logits, self.caches = self._prefill_fn(
            self.params, self.caches, self._mini_template,
            jnp.asarray(tokens), jnp.asarray(plen, jnp.int32),
            jnp.asarray(rs.slot, jnp.int32))
        tok = self._greedy(logits)[0]
        self.prefills += 1
        self._slot_len[rs.slot] = plen
        self._last_tok[rs.slot] = tok
        rs.generated.append(tok.tolist() if tok.ndim else int(tok))
        rs.t_first_token = clock()
        self._maybe_finish(rs, clock)

    def _maybe_finish(self, rs: RequestState, clock) -> None:
        if rs.done or self._slot_len[rs.slot] + 1 >= self.max_len:
            rs.t_finish = clock()
            self.scheduler.release(rs.slot)
            self.finished.append(rs)
            self.completed.append(RequestMetrics.from_state(rs))

    def step(self, now: Optional[float] = None) -> bool:
        """Admit ready requests, then advance every occupied slot one
        token. Returns False when there was nothing to do.

        With an explicit ``now`` (simulated-time replay) every timestamp
        this step produces uses that value, so TTFT/latency stay in the
        caller's clock; otherwise the engine's monotonic clock is read at
        each event."""
        clock = self._now if now is None else (lambda: now)
        for rs in self.scheduler.admit_from(self.queue, clock()):
            self._admit(rs, clock)
        if not self.scheduler.running:
            return False

        tokens = self._last_tok[:, None]  # (B, 1[, K])
        pos = jnp.asarray(self._slot_len, jnp.int32)
        logits, self.caches = self._decode_fn(
            self.params, self.caches, {"tokens": jnp.asarray(tokens)}, pos)
        toks = self._greedy(logits)
        self.decode_steps += 1
        self._slot_len += 1  # every row's in-graph cursor advanced by 1
        self._last_tok = toks
        for slot, rs in list(self.scheduler.running.items()):
            t = toks[slot]
            rs.generated.append(t.tolist() if t.ndim else int(t))
            self._maybe_finish(rs, clock)
        return True

    def drain_finished(self) -> List[RequestState]:
        """Hand over (and forget) finished request states. Long-lived
        ``submit()``/``step()`` callers must drain periodically or the
        retained token lists grow without bound."""
        out, self.finished = self.finished, []
        self.completed = []
        return out

    def run(self, requests: Sequence[Request] = ()) -> Dict[str, float]:
        """Drive the request set to completion; returns aggregate metrics
        for the requests completed by *this* call (its own clock)."""
        for r in requests:
            self.submit(r)
        n0 = len(self.completed)
        self._t0 = time.monotonic()
        while self.queue or self.scheduler.running:
            if not self.step():
                nxt = self.queue.next_arrival()
                if nxt is not None:
                    time.sleep(min(max(nxt - self._now(), 0.0), 0.05))
        return summarize(self.completed[n0:], self._now())
