"""Continuous-batching engine over the packed-LNS decode path.

The engine owns a fixed decode batch of ``num_slots`` rows. Each row is an
independent serving slot:

- the cache write cursor (``cache["idx"]``) is per-row, so a freed slot
  restarts at position 0 while its neighbours keep decoding;
- admission prefills the prompt through the *decode* path at batch 1 with
  the prompt right-padded to a shape bucket (a handful of jit entries,
  see ``_bucket``), then scatters the produced rows into the freed slot
  with the cursor rewound to the true prompt length;
- the decode step itself sees a single ``(num_slots, 1)`` shape forever:
  admitting a request never recompiles it (``decode_compiles`` stays 1);
- a finished sequence (EOS, ``max_new_tokens``, or cache capacity — the
  latter flagged ``truncated`` in its metrics) releases its slot and its
  KV is recycled by a later admission.

KV storage comes in two layouts (DESIGN.md §7.1):

- **dense** (default): one ``(num_slots, max_len)`` buffer per layer; slot
  count is capped by worst-case context.
- **paged** (``page_size=...``): full-context attention layers share one
  global pool of ``page_size``-token pages per layer plus per-slot block
  tables; ``num_slots`` can exceed what dense allocation permits and
  admission is gated by the ``BlockAllocator`` (pool exhausted -> the
  request waits in the queue, nothing wedges). ``alloc_policy`` picks how
  pages are claimed: ``"reserve"`` (default) takes the worst case
  ``ceil((prompt+budget)/page_size)`` up front — no preemption, but long
  budgets throttle concurrency; ``"ondemand"`` takes only the prompt's
  pages and grows the block table page by page as decode proceeds,
  preempting the *youngest* running request by recompute when the pool
  runs dry (its tokens are kept; re-admission re-prefills prompt +
  generated and resumes the sampling chain at the same event counter —
  delivered tokens are never re-emitted or re-drawn, and the stream
  stays identical up to float-level batch-composition effects: the
  quantized decode path scales activations per *tensor*, so a different
  set of co-resident rows can shift any row's logits by an ULP and flip
  a greedy near-tie). With ``prefix_cache`` the allocator keeps
  a chain hash over page-aligned prompt prefixes: a hit maps the resident
  pages into the new slot's block table and prefills only the suffix
  (copy-on-write on a partially-reused boundary page). Sliding-window
  rings, recurrent state, and MLA caches keep the dense layout; prefix
  reuse switches off unless every stateful layer is paged (those layers
  would otherwise never see the skipped tokens).

Weights stay in the packed 8-bit LNS wire format (``LNSWeight``) for the
whole request lifetime: routed GEMMs decode tile-locally through
``kernels/dispatch``, fallback leaves decode per layer inside the step —
the engine never materializes the tree and loads training checkpoints
with zero re-encoding (same bytes on disk, in the train state, and here).

Online serving: sampling runs **on device inside the jitted decode step**
— temperature / top-k / top-p / seed are per-slot ``(B,)`` batch inputs
(``repro.server.sampling``), so per-request settings never recompile and
only the sampled token ids cross to the host. Each appended token fires
``token_sink`` and each terminal transition fires ``finish_sink`` (the
gateway's stream hooks), ``abort()`` cancels a request mid-queue or
mid-flight (slot + KV pages released, co-batched rows undisturbed), and
``drain_finished()`` bounds the archives for long-lived callers.

Padding-safety: right-padded prefill is exact for *dense* attention caches
(the padded keys sit beyond the rewound cursor, masked and later
overwritten) and for *paged* pools (pad writes past a slot's page span are
dropped by the scatter; pads inside the span are masked and overwritten by
decode). It is NOT exact for recurrent state (Mamba/RWKV consume pad
tokens) nor for ring buffers shorter than the bucket (pads would wrap onto
live keys) — there the engine prefills at the exact prompt length instead:
correctness first, one extra compile per distinct length second.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.quantizer import QuantConfig
from repro.distributed.params_sharding import (cache_logical_axes,
                                               params_shardings,
                                               tree_shardings)
from repro.distributed.sharding import serving_rules, shard_ctx
from repro.kernels import dispatch
from repro.models.common import ArchConfig
from repro.models.model import forward, init_caches
from repro.optim.madam import MadamConfig
from repro.server.sampling import sample_logits, sampling_rows, set_row
from repro.serving.metrics import RequestMetrics, summarize
from repro.serving.request import Request, RequestQueue, RequestState
from repro.serving.scheduler import BlockAllocator, Scheduler
from repro.serving.spec import (SpecAutotuner, SpecConfig, build_draft_params,
                                request_class, spec_supported)
from repro.training.steps import build_decode_step

__all__ = ["Engine", "DEFAULT_BUCKETS", "ADMIT_FAIL_TRIP"]

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)

# consecutive admission failures after which step() stops isolating them
# and re-raises: one malformed request failing alone is serving as
# intended, but *every* admission failing means the engine itself is
# broken (device OOM, poisoned params) and masking that would keep
# /health green on a node that can no longer serve anything
ADMIT_FAIL_TRIP = 8

# layer kinds whose KV can live in a block-paged pool (full-context,
# non-MLA attention); everything else keeps the dense per-slot layout
_PAGED_KINDS = frozenset({"dense", "global", "moe", "shared_attn"})
_POOL_KEYS = ("kp", "vp", "kp_scale", "vp_scale")


def _set_cursor(caches, n):
    """Rewind every per-slot cache cursor in a (batch=1) cache tree to n."""
    def visit(path, leaf):
        if getattr(path[-1], "key", None) == "idx":
            return jnp.full_like(leaf, n)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, caches)


def _set_cursor_rows(caches, n):
    """Set every per-slot cache cursor to the per-row vector ``n`` (B,) —
    the speculative rewind: cursor leaves are (B,) or period-stacked
    (n_periods, B), both broadcast targets of a (B,) row vector."""
    def visit(path, leaf):
        if getattr(path[-1], "key", None) == "idx":
            return jnp.broadcast_to(n.astype(leaf.dtype), leaf.shape)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, caches)


def _slot_scatter(b, m, slot):
    """Write the batch-1 leaf ``m`` into row ``slot`` of ``b`` — the slot
    axis is wherever the two shapes disagree (axis 0 for plain leaves,
    axis 1 for period-stacked ones)."""
    ax = next((i for i, (x, y) in enumerate(zip(b.shape, m.shape))
               if x != y), 0)
    start = [0] * b.ndim
    start[ax] = slot
    return jax.lax.dynamic_update_slice(b, m.astype(b.dtype), tuple(start))


class Engine:
    """Continuous-batching serving engine. See module docstring."""

    def __init__(
        self,
        cfg: ArchConfig,
        qcfg: Optional[QuantConfig],
        mcfg: Optional[MadamConfig],
        params: Any,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        scan_unroll: int | bool = 1,
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        prefix_cache: bool = True,
        alloc_policy: str = "reserve",
        speculate_k: int = 0,
        draft_bitwidth: int = 6,
        spec_autotune: bool = False,
        mesh=None,
        observer=None,
        checkpoint_id: Optional[str] = None,
    ):
        if alloc_policy not in ("reserve", "ondemand"):
            raise ValueError(f"alloc_policy must be 'reserve' or "
                             f"'ondemand', got {alloc_policy!r}")
        self.cfg, self.qcfg, self.mcfg = cfg, qcfg, mcfg
        # Mesh-native serving: one jax Mesh threaded from launch/serve.py
        # down to the kernels. Weights/KV shard per serving_rules (head- and
        # column-parallel where divisible, every contraction over a
        # replicated axis); activations and all host inputs replicate on the
        # batch axis, so the token stream stays token-for-token equal to the
        # single-device engine. The block allocator / prefix registry stay
        # host-side and mesh-wide: one logical block table, each shard
        # holding the page-local view of its own head group.
        self._mesh = mesh
        self._mesh_rules = serving_rules(cfg, mesh) if mesh is not None \
            else None
        self._repl = NamedSharding(mesh, PartitionSpec()) \
            if mesh is not None else None
        self.params = self._place_params(params)
        self.num_slots, self.max_len = num_slots, max_len
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))

        prefix, _, period = cfg.layer_pattern()
        kinds = set(prefix) | set(period)
        self._recurrent = bool(kinds & {"mamba", "rwkv"})
        self._window = cfg.sliding_window if "local" in kinds else None

        self._paged = bool(page_size) and not cfg.use_mla \
            and bool(kinds & _PAGED_KINDS)
        self.page_size = page_size if self._paged else None
        if self._paged:
            self._max_pages = -(-max_len // page_size)
            self.num_pages = num_pages or num_slots * self._max_pages
            self._null_page = self.num_pages
            # skipping re-prefill of a cached prefix is only sound when no
            # layer carries non-paged state that would miss those tokens
            self._prefix_ok = prefix_cache and kinds <= _PAGED_KINDS
        else:
            self.num_pages = 0
            self._prefix_ok = False
        self.alloc_policy = alloc_policy if self._paged else None
        self._ondemand = self._paged and alloc_policy == "ondemand"

        # self-speculative decoding (DESIGN.md §11): the draft model is a
        # low-bitwidth re-grid *view* of the serving weights, built lazily
        # per bitwidth in _draft_params. Sliding-window rings over-allocate
        # by k_max positions (window_slack) so a post-rejection rewind can
        # never have let the write head lap a maskable position.
        self.spec: Optional[SpecConfig] = None
        self._spec_slack = 0
        if speculate_k:
            reason = spec_supported(cfg)
            if reason is not None:
                raise ValueError(
                    f"speculative decoding unsupported here: {reason}")
            self.spec = SpecConfig(k=int(speculate_k),
                                   draft_bits=int(draft_bitwidth),
                                   autotune=bool(spec_autotune))
            self._spec_k_max = max(k for _, k in self.spec.arms()) \
                if spec_autotune else self.spec.k
            if self._window is not None:
                self._spec_slack = self._spec_k_max

        self._scan_unroll = scan_unroll
        decode = build_decode_step(cfg, qcfg, mcfg, scan_unroll=scan_unroll)
        self._decode_step = decode

        def decode_sample(params, caches, batch, pos, samp):
            # sampling fused into the decode jit: logits never leave the
            # device, only the (B,)/(B, K) token ids transfer
            logits, caches = decode(params, caches, batch, pos)
            return self._sample_impl(logits, samp), caches

        self._decode_fn = jax.jit(decode_sample, donate_argnums=(1,))
        self._sample_fn = jax.jit(self._sample_impl)  # prefill logits
        if self.spec is not None:
            # one fused launch per cycle: k draft decodes + the S=k verify
            # + accept/rewind. k is static (the draft loop unrolls in the
            # trace) and the draft tree's LNSFormat is static aux data, so
            # each (bits, k) arm compiles its own entry exactly once.
            self._spec_fn = jax.jit(self._spec_cycle_impl,
                                    static_argnames=("k",),
                                    donate_argnums=(2,))
            self._draft_views: Dict[int, Any] = {}
        # per-token / terminal event hooks (the gateway driver's taps);
        # called synchronously from step()/_admit() with (rid, token) and
        # (rid, reason, RequestState | None)
        self.token_sink: Optional[Callable[[int, Any], None]] = None
        self.finish_sink: Optional[
            Callable[[int, str, Optional[RequestState]], None]] = None
        # observability (repro.obs.EngineObserver, DESIGN.md §13): every
        # hot-path hook site is a single `is not None` check, so the
        # default costs one attribute load per step — no allocation
        self.observer = observer
        # identity of the loaded weights, surfaced by /health; serving
        # launchers stamp it (checkpoint path / smoke-init tag)
        self.checkpoint_id = checkpoint_id
        # one fused call per admission: batch-1 prefill through the decode
        # path + scatter of the produced rows into the engine cache
        impl = self._prefill_paged_impl if self._paged else self._prefill_impl
        self._prefill_fn = jax.jit(impl, donate_argnums=(1,))
        if not self._paged:
            # zero batch-1 cache reused by every dense admission's prefill
            # (the jit body is functional, the template never mutates);
            # ring slack must match the engine cache or scatter shapes split
            self._mini_template = self._place_caches(
                init_caches(1, max_len, cfg, window_slack=self._spec_slack))

        self._reset_state()

    # -- mesh plumbing ------------------------------------------------------

    def _ctx(self):
        """Activate the serving mesh + rules for the enclosed jit call.

        Trace-time state (``shard()`` constraints, the dispatch layer's
        shard_map gate) reads a thread-local, and the driver runs the
        engine on its own thread — so every jit call site wraps itself
        instead of relying on whoever constructed the engine."""
        if self._mesh is None:
            return contextlib.nullcontext()
        return shard_ctx(self._mesh, self._mesh_rules)

    def _put(self, x, dtype=None):
        """Host input -> device, replicated across the mesh. Continuous
        batching feeds (B,)/(B, S) host arrays every step; replication is
        the layout ``device_put`` accepts for any B and the one the
        equality guarantee needs (no batch-sharded GEMM rows)."""
        if self._mesh is None:
            return jnp.asarray(x, dtype)
        return jax.device_put(np.asarray(x, dtype), self._repl)

    def _place_params(self, params):
        if self._mesh is None:
            return params
        with self._ctx():
            shardings = params_shardings(params, self._mesh,
                                         self._mesh_rules, serving=True)
        return jax.device_put(params, shardings)

    def _place_caches(self, caches):
        if self._mesh is None:
            return caches
        with self._ctx():
            shardings = tree_shardings(cache_logical_axes(caches),
                                       self._mesh, self._mesh_rules)
        return jax.device_put(caches, shardings)

    # -----------------------------------------------------------------------

    def _reset_state(self) -> None:
        cfg = self.cfg
        self.caches = self._place_caches(
            init_caches(self.num_slots, self.max_len, cfg,
                        page_size=self.page_size,
                        num_pages=self.num_pages or None,
                        window_slack=self._spec_slack))
        allocator = None
        if self._paged:
            allocator = BlockAllocator(self.num_pages, self.page_size)
            self._block_tables = np.full(
                (self.num_slots, self._max_pages), self._null_page, np.int32)
            self._slot_pages: List[Optional[List[int]]] = \
                [None] * self.num_slots
        self.scheduler = Scheduler(self.num_slots, allocator=allocator)
        self.queue = RequestQueue()
        # host mirrors of the in-graph per-slot cursors / last tokens
        self._slot_len = np.zeros((self.num_slots,), np.int64)
        tok_width = (cfg.num_codebooks,) if cfg.num_codebooks else ()
        self._last_tok = np.zeros((self.num_slots,) + tok_width, np.int32)
        # per-slot sampling params + sample-event counters (batch inputs
        # of the fused decode step; idle slots park at greedy)
        self._samp = sampling_rows(self.num_slots)
        self.completed: List[RequestMetrics] = []
        self.finished: List[RequestState] = []  # keeps generated tokens
        self.aborted: List[RequestState] = []   # cancelled mid-flight
        self._run_sink: Optional[List[RequestMetrics]] = None
        self.decode_steps = 0
        self.prefills = 0
        self.admit_failures = 0          # requests that blew up in _admit
        self._admit_fail_streak = 0      # consecutive; trips the engine
        self.prefill_tokens = 0          # padded tokens actually prefilled
        self.prefix_hits = 0             # admissions that reused pages
        self.prefix_reused_tokens = 0    # prompt tokens skipped via reuse
        # on-demand paging: states parked by preemption (rid -> state,
        # request itself waits at the queue head) and growth counters
        self._preempted: Dict[int, RequestState] = {}
        self.preemptions = 0             # recompute evictions under pressure
        self.decode_page_allocs = 0      # pages mapped mid-decode (ondemand)
        # speculative decoding counters (zeroed even when spec is off so
        # stats consumers can read them unconditionally)
        self.spec_cycles = 0             # fused draft+verify launches
        self.spec_draft_steps = 0        # draft decodes (k per cycle)
        self.spec_verify_steps = 0       # S=k verify passes (1 per cycle)
        self.spec_drafted = 0            # draft tokens scored (live slots)
        self.spec_accepted = 0           # drafts the target agreed with
        self.spec_emitted = 0            # tokens delivered by spec cycles
        self.spec_fallbacks = 0          # steps forced down the 1-token path
        self.spec_pages_trimmed = 0      # overshoot pages returned (ondemand)
        self._tuner: Optional[SpecAutotuner] = None
        if self.spec is not None:
            self._spec_arm = (self.spec.draft_bits, self.spec.k)
            if self.spec.autotune:
                self._tuner = SpecAutotuner(self.spec)
        # eager epoch: now() is read from other threads (online arrival
        # stamps) — lazy init would race the first step()'s _now()
        self._t0: Optional[float] = time.monotonic()

    @property
    def allocator(self) -> Optional[BlockAllocator]:
        return self.scheduler.allocator

    # ------------------------------------------------------------------
    # observability (only touched when an observer is attached)

    def attach_observer(self, observer) -> None:
        """Attach a ``repro.obs.EngineObserver`` (None detaches). Spans
        and timeline rows are stamped with the engine clock, so attach
        before (or at) the run whose events you want coherent."""
        self.observer = observer

    def _obs_gauges(self) -> Dict[str, int]:
        """The step-timeline gauge row (allocator counts are O(1))."""
        alloc = self.allocator
        g = {"running": len(self.scheduler.running),
             "queued": len(self.queue),
             "preempts": self.preemptions}
        if alloc is not None:
            g["pages_free"] = alloc.free
            g["pages_cached"] = alloc.cached
        return g

    # ------------------------------------------------------------------
    # jitted bodies

    def _prefill_impl(self, params, big, mini, tokens, n, slot):
        """Dense-cache admission: batch-1 decode-path prefill of ``tokens``
        over the zero cache ``mini``, cursor rewound to the true prompt
        length ``n``, rows scattered into row ``slot`` of the engine cache
        ``big``. Returns (last-real-position logits, updated cache)."""
        out = forward(params, tokens, self.cfg, self.qcfg, caches=mini,
                      pos_offset=0)
        logits = jnp.take(out.logits, n - 1, axis=1)  # (1, V)
        filled = _set_cursor(out.caches, n)
        upd = lambda b, m: _slot_scatter(b, m, slot)
        return logits, jax.tree.map(upd, big, filled)

    def _prefill_paged_impl(self, params, big, tokens, n_new, n_cached,
                            n_total, table, cow_src, cow_dst, slot):
        """Paged admission, *in place* over the global pool: the pool
        leaves carry no batch axis, so the batch-1 suffix prefill attends
        and writes through the slot's real block table (``table``,
        (max_pages,) pool page ids) directly — prefix-cached pages are
        read where they live, never re-gathered into a scratch pool, and
        the fresh pages are written exactly once (the kernel's
        prefill-over-block-table path).

        The only page whose *content* must move is the copy-on-write
        boundary: ``cow_src`` (the shared page) is copied onto ``cow_dst``
        (the fresh copy, ``table[n_full]``) before the forward, so the
        suffix writes land on a page already holding the shared prefix
        tokens. Without a CoW boundary both ids name the null page — a
        self-copy of the sacrificial page, free and harmless.

        Suffix tokens prefill at ``pos_offset=n_cached``; bucket-padding
        writes past the prompt land in the slot's own still-unused
        positions or the null page (out-of-span writes drop). Dense
        (non-paged) layer rows and the cursor scatter into row ``slot``;
        the cursor rewinds to the true prompt length ``n_total``.
        Returns (last-real-position logits, updated cache)."""

        def mini_layer(c, stacked):
            if isinstance(c, dict) and "kp" in c:
                out = {}
                for k, v in c.items():
                    if k in _POOL_KEYS:  # shared leaf + the CoW page copy
                        out[k] = (v.at[:, cow_dst].set(v[:, cow_src])
                                  if stacked else
                                  v.at[cow_dst].set(v[cow_src]))
                    else:  # "idx": suffix prefill resumes at n_cached
                        shape = (v.shape[0], 1) if stacked else (1,)
                        out[k] = jnp.full(shape, n_cached, v.dtype)
                return out
            ax = 1 if stacked else 0  # zeros fold to constants inside jit
            return {k: jnp.zeros(v.shape[:ax] + (1,) + v.shape[ax + 1:],
                                 v.dtype) for k, v in c.items()}

        def map_tree(tree, fn):
            out: Dict[str, Any] = {}
            if "prefix" in tree:
                out["prefix"] = [fn(c, False) for c in tree["prefix"]]
            if "period" in tree:
                out["period"] = {k: fn(c, True)
                                 for k, c in tree["period"].items()}
            return out

        mini = map_tree(big, mini_layer)
        out = forward(params, tokens, self.cfg, self.qcfg, caches=mini,
                      pos_offset=n_cached, block_tables=table[None])
        logits = jnp.take(out.logits, n_new - 1, axis=1)  # (1, V)
        filled = _set_cursor(out.caches, n_total)

        def scatter_layer(b, m, stacked):
            if isinstance(b, dict) and "kp" in b:
                out = {}
                for k in b:
                    if k in _POOL_KEYS:
                        # the mini leaf IS the updated global pool (the
                        # forward wrote through the real page ids)
                        out[k] = m[k]
                    else:
                        out[k] = _slot_scatter(b[k], m[k], slot)
                return out
            return jax.tree.map(lambda x, y: _slot_scatter(x, y, slot), b, m)

        def zip_tree(btree, mtree):
            out: Dict[str, Any] = {}
            if "prefix" in btree:
                out["prefix"] = [scatter_layer(x, y, False) for x, y in
                                 zip(btree["prefix"], mtree["prefix"])]
            if "period" in btree:
                out["period"] = {k: scatter_layer(btree["period"][k],
                                                  mtree["period"][k], True)
                                 for k in btree["period"]}
            return out

        return logits, zip_tree(big, filled)

    def _spec_cycle_impl(self, dparams, params, caches, last_tok, pos, samp,
                         block_tables, *, k):
        """One fused speculative cycle (DESIGN.md §11), a single jit:

        1. k greedy S=1 draft decodes with the re-grid view ``dparams``,
           advancing the per-row cursors pos -> pos+k (draft KV written by
           the *target-precision* cache path — the draft only changes the
           weights the logits come from, never the cache contents, so an
           accepted position's KV is exactly what the baseline engine
           would have written for that token);
        2. cursor rewind to ``pos`` and one S=k verify ``forward`` with
           the full-precision weights over [last_tok, draft[:, :-1]] —
           position j's logits condition on the same prefix the baseline
           would see when sampling its (step+j)-th token;
        3. per-position target sampling with the fold counter offset by j
           (``sample_logits(step_offset=j)`` — seeded chains replay
           token-for-token), the longest-agreeing-prefix accept rule, and
           an in-graph rewind of every cursor to pos+m.

        Returns ``(s, acc, m, caches)``: the target's samples (B, k), the
        accepted-draft count (B,), and the emitted count ``m = min(acc+1,
        k)`` — the bonus +1 is the target's own sample at the first
        disagreement (or the run's end), which is always correct to emit.
        Rejected writes at positions >= pos+m are dead: cursors moved
        back, so they are masked everywhere and overwritten before those
        positions ever become attendable again.
        """
        decode = self._decode_step
        cur = last_tok
        drafts = []
        for j in range(k):  # static unroll: one launch, no host ping-pong
            batch = {"tokens": cur[:, None]}
            if block_tables is not None:
                batch["block_tables"] = block_tables
            logits, caches = decode(dparams, caches, batch, pos + j)
            cur = dispatch.fused_sample(logits.astype(jnp.float32), None, None)
            drafts.append(cur)
        draft = jnp.stack(drafts, axis=1)                       # (B, k)

        caches = _set_cursor_rows(caches, pos)
        x = jnp.concatenate([last_tok[:, None], draft[:, :-1]], axis=1)
        out = forward(params, x, self.cfg, self.qcfg, caches=caches,
                      pos_offset=pos, block_tables=block_tables,
                      scan_unroll=self._scan_unroll)
        caches = out.caches
        s = jnp.stack([self._sample_impl(out.logits[:, j], samp,
                                         step_offset=j)
                       for j in range(k)], axis=1)              # (B, k)

        eq = (draft == s).astype(jnp.int32)
        acc = jnp.sum(jnp.cumprod(eq, axis=1), axis=1)          # (B,)
        m = jnp.minimum(acc + 1, k)
        caches = _set_cursor_rows(caches, pos + m)
        return s, acc, m, caches

    def _draft_params(self, bits: int):
        """The (cached) draft view at ``bits`` wire bits — shared scales,
        shared non-LNS leaves; bits == serving bits returns the target
        tree itself (the identity arm: every draft accepts)."""
        view = self._draft_views.get(bits)
        if view is None:
            view = build_draft_params(self.params, bits)
            self._draft_views[bits] = view
            self._numerics_cache = None  # new view -> re-derive health
        return view

    def numerics_snapshot(self) -> Dict[str, Any]:
        """Per-tree LNS numerics health for ``/health`` (DESIGN.md §14).

        Weight-tree code-rail occupancy (the live-weights readiness
        signal: codes piling at either rail mean the serving copy lost
        resolution) plus the re-grid error of every built draft view.
        Computed lazily and cached — invalidated when a new draft view is
        built (and, later, when live-weight swaps land), so the driver's
        stats refresh never re-reduces the tree.
        """
        cached = getattr(self, "_numerics_cache", None)
        if cached is not None:
            return cached
        from repro.obs.numerics import tree_code_stats
        from repro.serving.spec import draft_requant_error
        snap: Dict[str, Any] = {"weights": tree_code_stats(self.params)}
        drafts = {}
        for bits, view in sorted(getattr(self, "_draft_views", {}).items()):
            if view is self.params:
                continue
            drafts[f"b{bits}"] = draft_requant_error(self.params, view)
        if drafts:
            snap["draft_requant"] = drafts
        self._numerics_cache = snap
        return snap

    # ------------------------------------------------------------------
    # shape bucketing

    def _bucket(self, plen: int) -> int:
        assert plen <= self.max_len  # guaranteed by submit()
        if self._recurrent:
            return plen  # pads would pollute the recurrent state
        for b in self.buckets:
            if b >= plen and (self._window is None or b <= self._window):
                return b
        return plen  # no safe bucket: exact shape (ring wrap / long prompt)

    @property
    def prefill_compiles(self) -> int:
        return self._prefill_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._decode_fn._cache_size()

    # ------------------------------------------------------------------
    # lifecycle

    def reset(self) -> None:
        """Clear all request/slot state but keep the compiled steps — a
        reset engine re-runs a trace with warm jit caches (benchmarks)."""
        self._reset_state()

    def validate(self, prompt: Sequence, max_new_tokens: int = 0) -> None:
        """Raise ValueError if this request can *never* be hosted: prompt
        beyond the cache, page demand beyond the pool, or a prompt whose
        shape doesn't fit the model (flat ids vs per-codebook rows, wrong
        row width — those would otherwise blow up inside the prefill jit
        at admission time). The one admission formula, shared by
        ``submit()`` and the online gateway's pre-flight check (a 400,
        not backpressure)."""
        try:
            arr = np.asarray(prompt)
        except ValueError:
            raise ValueError("prompt rows must share one shape") from None
        if arr.size and not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(f"prompt must hold integer token ids, got "
                             f"dtype {arr.dtype}")
        k = self.cfg.num_codebooks
        if k:
            if arr.ndim != 2 or arr.shape[1] != k:
                raise ValueError(f"model expects prompt shape (len, {k}) — "
                                 f"one id row per codebook — got "
                                 f"{arr.shape}")
        elif arr.ndim != 1:
            raise ValueError(f"model expects a flat list of token ids, got "
                             f"shape {arr.shape}")
        prompt_len = arr.shape[0]
        if prompt_len < 1:
            raise ValueError("prompt must hold at least one token")
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            # without this, out-of-range ids are silently clamped by the
            # embedding gather under jit and decode 200s with garbage
            raise ValueError(f"prompt token ids must be in [0, "
                             f"{self.cfg.vocab_size}), got [{lo}, {hi}]")
        if prompt_len > self.max_len:
            raise ValueError(f"prompt len {prompt_len} exceeds engine "
                             f"max_len {self.max_len}")
        if self._paged:
            need = self._pages_for(prompt_len, max_new_tokens)
            if need > self.num_pages:
                raise ValueError(f"needs {need} KV pages, pool holds "
                                 f"{self.num_pages}")

    def submit(self, req: Request) -> None:
        # reject before any slot is bound: failing later (inside _admit)
        # would leak the already-occupied slot and wedge the engine.
        # The online driver validates at its pre-flight (same formula)
        # and marks the request, so the O(prompt) scan isn't paid twice
        if not getattr(req, "_prevalidated", False):
            try:
                self.validate(req.prompt, req.max_new_tokens)
            except ValueError as e:
                raise ValueError(f"request {req.rid}: {e}") from None
        self.queue.push(req)

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    def now(self) -> float:
        """Engine-clock timestamp (seconds since first use) — online
        callers stamp ``Request.arrival`` with this so queue-wait and
        TTFT share the engine's timebase."""
        return self._now()

    def _sample_impl(self, logits, samp, step_offset=None):
        """On-device sampler body (jitted standalone for prefill logits,
        inlined into the decode jit for the hot loop; ``step_offset``
        shifts the fold counter for the speculative verify positions)."""
        return sample_logits(logits, samp,
                             num_codebooks=self.cfg.num_codebooks,
                             vocab_size=self.cfg.vocab_size,
                             step_offset=step_offset)

    def _samp_row(self, slot: int) -> Dict[str, jax.Array]:
        """Batch-1 view of one slot's sampling params (prefill sample)."""
        return {k: self._put(v[slot:slot + 1])
                for k, v in self._samp.items()}

    # ------------------------------------------------------------------
    # paged admission bookkeeping (host side)

    def _pages_for(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case pages a request holds: its prompt plus the budget's
        decode writes (the final token is returned but never cached)."""
        n_pos = min(prompt_len + max(max_new_tokens - 1, 0), self.max_len)
        return -(-n_pos // self.page_size)

    def _pages_needed(self, req: Request) -> int:
        return self._pages_for(req.prompt_len, req.max_new_tokens)

    def _reserve_pages(self, req: Request,
                       n_tokens: Optional[int] = None
                       ) -> Optional[Dict[str, Any]]:
        """Match the prompt's cached prefix and reserve this request's
        pages; None (nothing held) if the pool can't host it right now.

        ``n_tokens`` is the number of positions admission will prefill —
        the prompt length, except when resuming a preempted request
        (prompt plus the tokens generated before eviction). Under the
        ``reserve`` policy the whole worst-case budget is taken up front;
        under ``ondemand`` only the prefill's pages are taken and decode
        grows the block table page by page (``_grow_decode_pages``).

        Under pressure the match degrades before the reservation fails:
        first the copy-on-write hold goes (it transiently pins one page
        beyond the request's own demand — on a pool sized exactly at
        ``_pages_needed`` that hold would otherwise wedge the identical
        reservation forever), then the whole prefix match (releasing the
        shared pages back to the evictable set, so any request ``submit``
        accepted can always be hosted with zero reuse once slots drain)."""
        alloc = self.allocator
        page = self.page_size
        plen = req.prompt_len if n_tokens is None else n_tokens
        need = -(-plen // page) if self._ondemand \
            else self._pages_needed(req)
        keys: List[bytes] = []
        matched: List[int] = []
        if self._prefix_ok:
            # memoized on the request (an exhausted pool retries this
            # reservation every step), tagged with the page size — one
            # trace may be replayed through engines with different pages
            memo = getattr(req, "_chain_keys", None)
            if memo is None or memo[0] != page:
                memo = (page, BlockAllocator.chain_keys(req.prompt, page))
                req._chain_keys = memo
            keys = memo[1]
            matched = alloc.match(keys)
        # always recompute at least the last prompt token (its logits seed
        # decoding), so reuse is capped at plen - 1
        n_cached = min(len(matched) * page, plen - 1)
        n_full = n_cached // page
        cow = matched[n_full] if n_cached % page else None
        shared = matched[:n_full]
        alloc.retain(shared)
        if cow is not None:
            alloc.retain([cow])
        fresh = alloc.alloc(need - n_full)
        if fresh is None and cow is not None:
            alloc.release([cow])           # forfeit the boundary reuse
            cow = None
            n_cached = n_full * page
            fresh = alloc.alloc(need - n_full)
        if fresh is None and shared:
            alloc.release(shared)          # forfeit the prefix match
            shared, n_full, n_cached = [], 0, 0
            fresh = alloc.alloc(need)
        if fresh is None:                  # genuine pressure: nothing held
            return None
        return {"n_cached": n_cached, "n_full": n_full, "cow": cow,
                "shared": shared, "fresh": fresh, "keys": keys}

    # ------------------------------------------------------------------
    # on-demand paging: decode-time growth + preemption by recompute

    @staticmethod
    def _age(rs: RequestState):
        """Total order on running requests, oldest first (victim choice
        and growth priority must agree, or progress isn't guaranteed)."""
        return (rs.t_admit, rs.request.arrival, rs.request.rid)

    def _preempt(self, rs: RequestState) -> None:
        """Evict a running request to reclaim its pages: slot, sampler
        row, and pages are released; the state parks in ``_preempted``
        and the request returns to the head of the queue. Re-admission
        recomputes the evicted KV (``_admit`` resume path) — tokens
        already delivered are never re-emitted or re-drawn."""
        self.preemptions += 1
        self._preempted[rs.request.rid] = rs
        self._release_slot(rs)
        self.queue.requeue(rs.request)
        if self.observer is not None:
            self.observer.preempted(rs, self._now())

    def _write_span(self, rs: RequestState, lookahead: int) -> tuple:
        """The position span ``[n, last]`` the next ``lookahead`` decode
        writes may touch for ``rs`` — clamped to the request's own page
        demand (``_pages_for``'s formula: the final budgeted token is
        returned but never cached) so speculative lookahead never maps
        pages the request cannot use. The immediate next write position
        is always in the span (the baseline single-token step)."""
        n = int(self._slot_len[rs.slot])
        req = rs.request
        limit = min(req.prompt_len + max(req.max_new_tokens - 1, 0),
                    self.max_len)
        return n, min(n + lookahead, max(limit, n + 1)) - 1

    def _grow_decode_pages(self, lookahead: int = 1) -> None:
        """Map fresh pages onto every running slot whose next ``lookahead``
        decode writes cross into unmapped territory (``ondemand`` policy:
        the admission reservation covered only the prefill; a speculative
        cycle asks for its whole k-token span up front). Under pool
        exhaustion the *youngest* running request yields (preemption by
        recompute) until the allocation succeeds — the oldest running
        request is never a victim, so FCFS progress is guaranteed."""
        page = self.page_size
        for rs in sorted(self.scheduler.running.values(), key=self._age):
            if self.scheduler.running.get(rs.slot) is not rs:
                continue  # evicted by an older slot's growth this step
            n, last = self._write_span(rs, lookahead)
            bt = self._block_tables[rs.slot]
            for pi in range(n // page,
                            min(last // page, self._max_pages - 1) + 1):
                if bt[pi] != self._null_page:
                    continue
                got = self.allocator.alloc(1)
                while got is None:
                    victim = max(
                        (v for v in self.scheduler.running.values()
                         if v is not rs), key=self._age, default=None)
                    if victim is None:
                        victim = rs  # alone and still starved: yield fully
                    self._preempt(victim)
                    if victim is rs:
                        break
                    got = self.allocator.alloc(1)
                if got is None:
                    break  # rs evicted itself; its row idles this step
                bt[pi] = got[0]
                self._slot_pages[rs.slot].append(got[0])
                self.decode_page_allocs += 1

    def _trim_overshoot(self, rs: RequestState) -> None:
        """Return a slot's overshoot pages after a speculative cycle: any
        page wholly beyond the next write position was mapped for draft
        tokens the verify rejected. Only decode-growth pages live out
        there (prefix/prefill pages all sit at or below the cursor's
        page), so the release can never touch a shared or registered
        page."""
        page = self.page_size
        keep = int(self._slot_len[rs.slot]) // page  # next-write page
        bt = self._block_tables[rs.slot]
        pages = self._slot_pages[rs.slot]
        for pi in range(keep + 1, self._max_pages):
            pid = int(bt[pi])
            if pid == self._null_page:
                continue
            self.allocator.release([pid])
            pages.remove(pid)
            bt[pi] = self._null_page
            self.spec_pages_trimmed += 1

    # ------------------------------------------------------------------
    # admission / decode

    def _admit(self, rs: RequestState, clock,
               resv: Optional[Dict[str, Any]] = None) -> None:
        req = rs.request
        prompt = np.asarray(req.prompt, np.int32)
        g = len(rs.generated)
        if g:
            # resuming a preempted request (ondemand policy): recompute
            # the evicted KV by prefilling the prompt plus every already
            # delivered token except the last (which seeds decoding and,
            # like any fresh prefill's sampled token, is never cached)
            tail = np.asarray(rs.generated[:-1],
                              np.int32).reshape((-1,) + prompt.shape[1:])
            prompt = np.concatenate([prompt, tail])
        plen = len(prompt)

        if self._paged:
            n_cached = resv["n_cached"]
            held = resv["shared"] + resv["fresh"]
            n_pages = resv["n_full"] + len(resv["fresh"])
            bt = np.full((self._max_pages,), self._null_page, np.int32)
            bt[:resv["n_full"]] = resv["shared"]
            bt[resv["n_full"]:n_pages] = resv["fresh"]
            # copy-on-write boundary: the shared page's content is copied
            # onto its fresh twin inside the prefill jit; null -> null
            # (a free self-copy of the sacrificial page) when absent
            if resv["cow"] is not None:
                cow_src, cow_dst = resv["cow"], int(bt[resv["n_full"]])
            else:
                cow_src = cow_dst = self._null_page
            n_new = plen - n_cached
            bucket = self._bucket(n_new)
            tokens = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
            tokens[0, :n_new] = prompt[n_cached:]
            with self._ctx():
                logits, self.caches = self._prefill_fn(
                    self.params, self.caches, self._put(tokens),
                    self._put(n_new, jnp.int32),
                    self._put(n_cached, jnp.int32),
                    self._put(plen, jnp.int32),
                    self._put(bt), self._put(cow_src, jnp.int32),
                    self._put(cow_dst, jnp.int32),
                    self._put(rs.slot, jnp.int32))
            if resv["cow"] is not None:  # content copied; drop the hold
                self.allocator.release([resv["cow"]])
                resv["cow"] = None  # a later unwind must not re-release
            if self._prefix_ok:  # publish this prompt's full pages
                # keys cover the *original* prompt only — resumed tokens
                # are generated content, never prefix-cache material
                for i, key in enumerate(resv["keys"]):
                    self.allocator.register(key, int(bt[i]))
            self._block_tables[rs.slot] = bt
            self._slot_pages[rs.slot] = held
            if n_cached:
                self.prefix_hits += 1
                self.prefix_reused_tokens += n_cached
        else:
            bucket = self._bucket(plen)
            tokens = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
            tokens[0, :plen] = prompt
            with self._ctx():
                logits, self.caches = self._prefill_fn(
                    self.params, self.caches, self._mini_template,
                    self._put(tokens), self._put(plen, jnp.int32),
                    self._put(rs.slot, jnp.int32))

        set_row(self._samp, rs.slot, req.sampling)  # sample event 0
        if g:
            # every emitted token was already delivered; the last one
            # seeds decoding and the sampling chain resumes at event g —
            # same seed, same counter, so no token is ever re-drawn
            # (logits can still move by an ULP vs the unpreempted run:
            # per-tensor activation scales couple co-resident rows)
            tok = np.asarray(rs.generated[-1], np.int32)
            self._samp["step"][rs.slot] = g
        else:
            with self._ctx():
                tok = np.asarray(
                    self._sample_fn(logits, self._samp_row(rs.slot)))[0]
            self._samp["step"][rs.slot] = 1
        self.prefills += 1
        self.prefill_tokens += bucket
        self._slot_len[rs.slot] = plen
        self._last_tok[rs.slot] = tok
        if not g:
            rs.generated.append(tok.tolist() if tok.ndim else int(tok))
            rs.t_first_token = clock()
            if self.token_sink is not None:
                self.token_sink(req.rid, rs.generated[-1])
        self._maybe_finish(rs, clock)

    def _maybe_finish(self, rs: RequestState, clock) -> None:
        # the cursor names the *next* write position: the slot is out of
        # capacity only once it passes max_len - 1 (position max_len - 1
        # itself is usable — finishing one step earlier wasted it)
        full = self._slot_len[rs.slot] >= self.max_len
        if rs.done or full:
            budget = len(rs.generated) >= rs.request.max_new_tokens
            reason = ("stop" if rs.hit_stop else
                      "length" if budget else "capacity")
            self._finish(rs, clock, reason)

    def _release_slot(self, rs: RequestState) -> None:
        """Free a terminal request's slot, sampler row, and (paged) the
        pages recorded on the slot."""
        self.scheduler.release(rs.slot)
        set_row(self._samp, rs.slot, None)  # idle slots sample greedy
        if self._paged:
            pages = self._slot_pages[rs.slot]
            if pages:
                self.allocator.release(pages)
            self._slot_pages[rs.slot] = None
            # stale decode writes from the recycled row must land in
            # the null page, never in someone else's live pages
            self._block_tables[rs.slot] = self._null_page

    def _finish(self, rs: RequestState, clock, reason: str) -> None:
        """Terminal transition: stamp the state, release the slot and its
        KV pages, archive, and fire ``finish_sink``."""
        rs.t_finish = clock()
        rs.finish_reason = reason
        self._release_slot(rs)
        if reason == "aborted":
            self.aborted.append(rs)
        else:
            self.finished.append(rs)
            m = RequestMetrics.from_state(rs, truncated=reason == "capacity")
            self.completed.append(m)
            if self._run_sink is not None:
                self._run_sink.append(m)
        if self.finish_sink is not None:
            self.finish_sink(rs.request.rid, reason, rs)
        if self.observer is not None:
            self.observer.finished(rs, reason)

    def _cache_poisoned(self) -> bool:
        """True when a failed donated call consumed the cache buffers."""
        return any(getattr(leaf, "is_deleted", None) and leaf.is_deleted()
                   for leaf in jax.tree.leaves(self.caches))

    def _archive_error(self, rs: RequestState) -> None:
        """Shared tail of both admission-failure paths: stamp, count,
        archive, and fire the terminal event. Slot/page unwinding stays
        caller-side — the reservation path never bound a slot."""
        rs.finish_reason = "error"
        self.admit_failures += 1
        self._admit_fail_streak += 1
        self.aborted.append(rs)
        if self.finish_sink is not None:
            self.finish_sink(rs.request.rid, "error", rs)

    def _fail_admission(self, rs: RequestState, resv: Optional[Dict],
                        clock) -> None:
        """Unwind a failed ``_admit``: free the slot, return the page
        reservation (wherever the failure left it), archive the state
        with reason "error", and fire ``finish_sink`` so an online
        caller's stream terminates instead of hanging."""
        rs.t_finish = clock()
        # pages recorded on the slot (failure after _admit's bookkeeping,
        # cow already dropped) are released by the shared teardown; a
        # failure before that point leaves the reservation ours to return
        recorded = self._paged and self._slot_pages[rs.slot] is not None
        self._release_slot(rs)
        if self._paged and not recorded and resv is not None:
            self.allocator.release(resv["shared"] + resv["fresh"])
            if resv["cow"] is not None:
                self.allocator.release([resv["cow"]])
        self._archive_error(rs)

    def abort(self, rid: int, now: Optional[float] = None) -> bool:
        """Cancel a request mid-queue, mid-prefill, or mid-decode.

        A queued request is simply dropped; a running one releases its
        slot and (paged mode) its KV pages immediately — refcounts return
        to baseline and the co-batched rows never see a perturbation
        (their cache rows, cursors, and sampling chains are untouched).
        Returns False if ``rid`` is not live here (already finished or
        never submitted) — aborts are naturally racy, callers shouldn't
        treat that as an error."""
        clock = self._now if now is None else (lambda: now)
        req = self.queue.remove(rid)
        if req is not None:
            # a preempted request waits in the queue with its state
            # parked; its pages were already released at eviction
            rs = self._preempted.pop(rid, None)
            if rs is not None:
                rs.t_finish = clock()
                rs.finish_reason = "aborted"
                self.aborted.append(rs)
            if self.finish_sink is not None:
                self.finish_sink(rid, "aborted", rs)
            if self.observer is not None:
                self.observer.aborted_queued(rid, clock())
            return True
        for rs in self.scheduler.running.values():
            if rs.request.rid == rid:
                self._finish(rs, clock, "aborted")
                return True
        return False

    # ------------------------------------------------------------------
    # speculative decoding (host side)

    def _spec_ready(self, k: int) -> bool:
        """Every running slot can host a k-token speculative span: the
        dense row-insert must not clamp at capacity, and (paged) every
        page a surviving write could land in must be mapped — a dropped
        write is only safe past the request's own budget limit."""
        for rs in self.scheduler.running.values():
            n = int(self._slot_len[rs.slot])
            if n + k > self.max_len:
                return False
            if self._paged:
                page = self.page_size
                _, last = self._write_span(rs, k)
                bt = self._block_tables[rs.slot]
                for pi in range(n // page, last // page + 1):
                    if bt[pi] == self._null_page:
                        return False
        return True

    def _spec_step(self, clock, k: int) -> None:
        """Run one fused speculative cycle and apply its outcome on the
        host: emit the accepted run (plus the verify's bonus token) per
        live slot, advance the cursor/sampler mirrors by the emitted
        count, finish any terminal transition inside the run, and return
        overshoot pages. Every emitted token is the *target* model's own
        sample at the correct fold counter, so the stream is
        token-for-token the baseline engine's (see DESIGN.md §11 for the
        per-tensor activation-scale ULP caveat)."""
        bits, _ = self._spec_arm
        obs = self.observer
        t0 = time.monotonic()
        t_s0 = self._now() if obs is not None else 0.0
        pos0 = self._slot_len.copy()
        batch_bt = self._put(self._block_tables) if self._paged else None
        samp = {kk: self._put(v) for kk, v in self._samp.items()}
        with self._ctx():
            s_dev, acc_dev, m_dev, self.caches = self._spec_fn(
                self._draft_params(bits), self.params, self.caches,
                self._put(self._last_tok), self._put(pos0, jnp.int32),
                samp, batch_bt, k=k)
        s = np.array(s_dev)
        acc = np.array(acc_dev)
        m = np.array(m_dev).astype(np.int64)
        self._admit_fail_streak = 0
        self.spec_cycles += 1
        self.spec_draft_steps += k
        self.spec_verify_steps += 1
        # per-row mirrors advance by the emitted count — idle rows too
        # (they drafted greedily into dead rows, exactly as the baseline
        # step advances every row by 1)
        self._slot_len = pos0 + m
        self._samp["step"] += m.astype(np.int32)
        self._last_tok = s[np.arange(self.num_slots), m - 1].astype(np.int32)
        emitted_total = 0
        per_class: Dict[str, Any] = {}
        obs_rows: Optional[List] = [] if obs is not None else None
        for slot, rs in list(self.scheduler.running.items()):
            a = int(acc[slot])
            if obs_rows is not None:
                obs_rows.append((rs.request.rid, a, int(m[slot])))
            self.spec_drafted += k
            self.spec_accepted += a
            rs.spec_cycles += 1
            rs.spec_drafted += k
            rs.spec_accepted += a
            if self._tuner is not None:
                cls = request_class(rs.request)
                ca, cd = per_class.get(cls, (0, 0))
                per_class[cls] = (ca + a, cd + k)
            for j in range(int(m[slot])):
                rs.generated.append(int(s[slot, j]))
                emitted_total += 1
                if self.token_sink is not None:
                    self.token_sink(rs.request.rid, rs.generated[-1])
                if rs.done:
                    # a stop/budget transition inside the accepted run is
                    # terminal — the run's later tokens were never part of
                    # the baseline stream and are dropped unemitted (the
                    # cursor overshoot is moot: the slot releases below)
                    break
            self._maybe_finish(rs, clock)
            if self._ondemand and self.scheduler.running.get(slot) is rs:
                self._trim_overshoot(rs)
        self.spec_emitted += emitted_total
        if obs is not None:
            obs.spec_cycle(t_s0, self._now(), k=k, rows=obs_rows,
                           emitted=emitted_total,
                           gauges=self._obs_gauges())
        if self._tuner is not None:
            self._tuner.observe(self._spec_arm, emitted_total,
                                time.monotonic() - t0, per_class)
            self._spec_arm = self._tuner.propose()

    @property
    def spec_accept_rate(self) -> float:
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)

    def spec_snapshot(self) -> Optional[Dict[str, Any]]:
        """Flat JSON-safe dict of speculative-decoding state for
        ``/metrics`` (None when speculation is off)."""
        if self.spec is None:
            return None
        snap: Dict[str, Any] = {
            "spec_cycles": self.spec_cycles,
            "spec_draft_steps": self.spec_draft_steps,
            "spec_verify_steps": self.spec_verify_steps,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_fallbacks": self.spec_fallbacks,
            "spec_pages_trimmed": self.spec_pages_trimmed,
            "spec_accept_rate": round(self.spec_accept_rate, 4),
            "spec_draft_bits": self._spec_arm[0],
            "spec_k": self._spec_arm[1],
        }
        if self._tuner is not None:
            snap.update(self._tuner.snapshot())
        return snap

    def step(self, now: Optional[float] = None) -> bool:
        """Admit ready requests, then advance every occupied slot one
        token. Returns False when there was nothing to do.

        With an explicit ``now`` (simulated-time replay) every timestamp
        this step produces uses that value, so TTFT/latency stay in the
        caller's clock; otherwise the engine's monotonic clock is read at
        each event."""
        clock = self._now if now is None else (lambda: now)
        obs = self.observer
        while self.scheduler.has_free():
            req = self.queue.pop_ready(clock())
            if req is None:
                break
            resv = None
            resume = self._preempted.get(req.rid) if self._paged else None
            if self._paged:
                n_tok = req.prompt_len
                if resume is not None:
                    n_tok += len(resume.generated) - 1
                try:
                    resv = self._reserve_pages(req, n_tok)
                except Exception:
                    # a prompt the reservation can't even hash (slipped
                    # past validate()) fails alone, before slot binding;
                    # archive an "error" state (slot -1: never bound) so
                    # offline callers' accounting still balances
                    rs = RequestState(request=req, slot=-1,
                                      t_admit=clock())
                    rs.t_finish = clock()
                    self._archive_error(rs)
                    if self._admit_fail_streak >= ADMIT_FAIL_TRIP:
                        raise
                    continue
                if resv is None:  # pool exhausted: wait for a release
                    self.queue.requeue(req)
                    break
            rs = self.scheduler.admit(req, clock())
            if resume is not None:
                # continuity across preemption: same token list (the
                # resume prefill keys off it) and original timestamps,
                # so TTFT/latency metrics span the whole request
                del self._preempted[req.rid]
                rs.generated = resume.generated
                rs.t_admit = resume.t_admit
                rs.t_first_token = resume.t_first_token
            t_p0 = self._now() if obs is not None else 0.0
            try:
                self._admit(rs, clock, resv)
                self._admit_fail_streak = 0
                if obs is not None:
                    obs.admitted(rs, resumed=resume is not None)
                    obs.prefill(rs, t_p0, self._now(),
                                gauges=self._obs_gauges())
            except Exception:
                # a request that blows up inside admission (a shape that
                # slipped past validate(), a prefill-time failure) must
                # fail alone: release its slot and reservation, fire its
                # terminal event, and keep serving the co-batched rows —
                # one malformed request must not take down the engine.
                # Unless *every* admission is failing: then the engine
                # itself is broken and the fault must propagate (503),
                # not hide behind per-request errors.
                if rs.finish_reason is not None:
                    # the request already reached its terminal transition
                    # inside _admit (1-token / instant-stop finish) and
                    # the raise came *after* it (e.g. a sink tap) —
                    # teardown already ran, unwinding again would
                    # double-release pages held by live neighbours
                    raise
                if self._cache_poisoned():
                    # the prefill jit donates self.caches: an
                    # *execution*-time failure (device OOM on an
                    # accelerator) consumed the donated buffers, so the
                    # co-batched rows are gone too — isolation would be
                    # a lie and the next decode step would die with a
                    # confusing "Array deleted"; fail now, with the
                    # real cause (trace-time failures — the bad-shape
                    # class — never execute, so the cache stays live
                    # and those are genuinely isolated)
                    raise
                self._fail_admission(rs, resv, clock)
                if self._admit_fail_streak >= ADMIT_FAIL_TRIP:
                    raise
        spec_k = self._spec_arm[1] if self.spec is not None else 0
        if self._ondemand:
            self._grow_decode_pages(lookahead=max(spec_k, 1))
        if not self.scheduler.running:
            return False

        if spec_k:
            if self._spec_ready(spec_k):
                self._spec_step(clock, spec_k)
                return True
            # a slot too close to capacity / an unmapped page under pool
            # pressure: advance everyone one plain token this step
            self.spec_fallbacks += 1

        t_d0 = self._now() if obs is not None else 0.0
        tokens = self._last_tok[:, None]  # (B, 1[, K])
        pos = self._put(self._slot_len, jnp.int32)
        batch = {"tokens": self._put(tokens)}
        if self._paged:
            batch["block_tables"] = self._put(self._block_tables)
        samp = {k: self._put(v) for k, v in self._samp.items()}
        with self._ctx():
            toks_dev, self.caches = self._decode_fn(
                self.params, self.caches, batch, pos, samp)
        # a successful decode proves the engine itself is healthy, so
        # keep isolating whatever admissions are failing — the trip is
        # for a broken engine, not a kill switch one bad client can pull
        # while co-batched traffic is being served fine
        self._admit_fail_streak = 0
        # token ids only — logits stay on device (np.asarray of a jax
        # array is a read-only view; copy so _last_tok stays writable)
        toks = np.array(toks_dev)
        self.decode_steps += 1
        self._slot_len += 1  # every row's in-graph cursor advanced by 1
        self._samp["step"] += 1
        self._last_tok = toks
        live = list(self.scheduler.running.items())
        for slot, rs in live:
            t = toks[slot]
            rs.generated.append(t.tolist() if t.ndim else int(t))
            if self.token_sink is not None:
                self.token_sink(rs.request.rid, rs.generated[-1])
            self._maybe_finish(rs, clock)
        if obs is not None:
            obs.decode_step(t_d0, self._now(), emitted=len(live),
                            gauges=self._obs_gauges())
        return True

    def drain_finished(self) -> List[RequestState]:
        """Hand over (and forget) finished request states, and clear the
        metrics archive with them. Long-lived ``submit()``/``step()``
        callers must drain periodically or the retained token lists grow
        without bound. Safe at any point: ``run()`` accounts its own
        completions in a run-local sink, not by slicing ``completed``."""
        out, self.finished = self.finished, []
        self.completed = []
        self.aborted = []
        return out

    def run(self, requests: Sequence[Request] = ()) -> Dict[str, float]:
        """Drive the request set to completion; returns aggregate metrics
        for the requests completed by *this* call (its own clock)."""
        for r in requests:
            self.submit(r)
        self._run_sink = sink = []
        self._t0 = time.monotonic()
        try:
            while self.queue or self.scheduler.running:
                if not self.step():
                    nxt = self.queue.next_arrival()
                    if nxt is not None:
                        time.sleep(min(max(nxt - self._now(), 0.0), 0.05))
        finally:
            self._run_sink = None
        return summarize(sink, self._now())
