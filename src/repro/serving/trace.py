"""Synthetic request traces shared by the serve CLI and the benchmarks.

One generator, three length distributions:

  fixed   — every request is exactly (prompt_len, gen_len)
  uniform — mild jitter around the nominal lengths (CLI ``--mixed``)
  bimodal — chat-style short turns mixed with a long-generation tail,
            the regime where lock-step batching stalls whole groups

``rate`` > 0 spreads arrivals as a Poisson process (requests/second);
otherwise everything arrives at t=0.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.serving.request import Request

__all__ = ["synthetic_trace", "max_trace_len"]


def synthetic_trace(cfg, *, requests: int, prompt_len: int, gen_len: int,
                    lengths: str = "fixed", rate: float = 0.0,
                    seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(requests):
        if lengths == "fixed":
            p, g = prompt_len, gen_len
        elif lengths == "uniform":
            p = max(1, int(round(prompt_len * rng.uniform(0.5, 1.5))))
            g = max(1, int(round(gen_len * rng.uniform(0.5, 1.5))))
        elif lengths == "bimodal":
            p = max(1, int(round(prompt_len * rng.uniform(0.5, 1.5))))
            if rng.uniform() < 0.25:  # long tail
                g = max(1, int(round(3.0 * gen_len * rng.uniform(0.8, 1.2))))
            else:
                g = max(1, int(round(0.5 * gen_len * rng.uniform(0.5, 1.5))))
        else:
            raise ValueError(f"unknown length distribution {lengths!r}")
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        shape = (p, cfg.num_codebooks) if cfg.num_codebooks else (p,)
        prompt = rng.integers(0, cfg.vocab_size, shape, dtype=np.int32)
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=g, arrival=t))
    return out


def max_trace_len(prompt_len: int, gen_len: int, lengths: str = "fixed") -> int:
    """Cache capacity covering any request the distribution can draw."""
    if lengths == "bimodal":
        return int(1.5 * prompt_len + 3.6 * gen_len) + 2
    if lengths == "uniform":
        return int(1.5 * prompt_len + 1.5 * gen_len) + 2
    return prompt_len + gen_len + 2
