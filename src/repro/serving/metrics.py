"""Per-request latency accounting and aggregate serving statistics."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.serving.request import RequestState

__all__ = ["RequestMetrics", "summarize", "percentile"]


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    rid: int
    slot: int
    arrival: float
    t_admit: float
    t_first_token: float
    t_finish: float
    prompt_len: int
    new_tokens: int
    # capacity-truncated: the slot ran out of cache positions before the
    # request reached a stop token or its token budget — not a normal
    # completion
    truncated: bool = False
    # speculative decoding: draft tokens scored for this request and how
    # many the verify pass accepted (0/0 when speculation was off or the
    # request never rode a spec cycle)
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def spec_accept_rate(self) -> Optional[float]:
        """Fraction of this request's draft tokens the target model
        agreed with; None when no drafts were scored for it."""
        if not self.spec_drafted:
            return None
        return self.spec_accepted / self.spec_drafted

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival (queueing included)."""
        return self.t_first_token - self.arrival

    @property
    def queued_s(self) -> float:
        """Time spent waiting for a slot (arrival -> admission)."""
        return self.t_admit - self.arrival

    @property
    def latency(self) -> float:
        return self.t_finish - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token over the decode phase (first token ->
        finish); None for single-token requests."""
        if self.new_tokens < 2 or self.t_finish <= self.t_first_token:
            return None
        return (self.t_finish - self.t_first_token) / (self.new_tokens - 1)

    @property
    def decode_tps(self) -> Optional[float]:
        if self.new_tokens < 2 or self.t_finish <= self.t_first_token:
            return None
        return (self.new_tokens - 1) / (self.t_finish - self.t_first_token)

    @classmethod
    def from_state(cls, rs: RequestState,
                   truncated: bool = False) -> "RequestMetrics":
        assert rs.t_first_token is not None and rs.t_finish is not None
        return cls(rid=rs.request.rid, slot=rs.slot,
                   arrival=rs.request.arrival, t_admit=rs.t_admit,
                   t_first_token=rs.t_first_token, t_finish=rs.t_finish,
                   prompt_len=rs.request.prompt_len,
                   new_tokens=len(rs.generated), truncated=truncated,
                   spec_drafted=rs.spec_drafted,
                   spec_accepted=rs.spec_accepted)


def percentile(vals: List[float], q: float) -> float:
    """Nearest-rank percentile (NaN when empty); sorts internally —
    shared by summarize() and the gateway benchmark.

    ``q`` is clamped to [0, 1]: q=0 is the minimum, q=1.0 the maximum
    (``int(1.0 * (n-1) + 0.5)`` lands exactly on the last rank). An
    out-of-range q previously indexed from the wrong end of the sorted
    list (negative index wrap) — clamping makes q<0 the min and q>1 the
    max instead."""
    if not vals:
        return float("nan")
    q = min(max(q, 0.0), 1.0)
    vals = sorted(vals)
    i = min(len(vals) - 1, int(q * (len(vals) - 1) + 0.5))
    return vals[i]


def summarize(metrics: List[RequestMetrics], wall: float) -> Dict[str, float]:
    """Aggregate a finished run: goodput and latency percentiles."""
    total_new = sum(m.new_tokens for m in metrics)
    ttfts = [m.ttft for m in metrics]
    lats = [m.latency for m in metrics]
    queued = [m.queued_s for m in metrics]
    tpots = [m.tpot for m in metrics if m.tpot is not None]
    accepts = [m.spec_accept_rate for m in metrics
               if m.spec_accept_rate is not None]
    spec_drafted = sum(m.spec_drafted for m in metrics)
    spec_accepted = sum(m.spec_accepted for m in metrics)
    return {
        "completed": float(len(metrics)),
        "truncated": float(sum(m.truncated for m in metrics)),
        "wall_s": wall,
        "generated_tokens": float(total_new),
        "tokens_per_s": total_new / wall if wall > 0 else float("nan"),
        "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else float("nan"),
        "ttft_p95_s": percentile(ttfts, 0.95),
        "latency_p50_s": percentile(lats, 0.50),
        "latency_p95_s": percentile(lats, 0.95),
        "queued_p50_s": percentile(queued, 0.50),
        "queued_p95_s": percentile(queued, 0.95),
        "tpot_p50_s": percentile(tpots, 0.50),
        "tpot_p95_s": percentile(tpots, 0.95),
        # speculative decoding: request-level accept-rate distribution
        # (only requests that rode at least one spec cycle count) plus
        # run totals; all-zero/NaN when speculation was off
        "spec_requests": float(len(accepts)),
        "spec_drafted_tokens": float(spec_drafted),
        "spec_accepted_tokens": float(spec_accepted),
        "spec_accept_rate": (spec_accepted / spec_drafted
                             if spec_drafted else float("nan")),
        "spec_accept_rate_p50": percentile(accepts, 0.50),
        "spec_accept_rate_p95": percentile(accepts, 0.95),
    }
