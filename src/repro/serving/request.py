"""Request lifecycle for the continuous-batching serving engine.

A ``Request`` is a prompt plus a generation budget; a ``RequestState`` is a
request bound to a decode slot, accumulating generated tokens and the
timestamps the metrics layer reads (arrival -> admit -> first token ->
finish). ``RequestQueue`` is the arrival-ordered waiting line the scheduler
drains into freed slots.

Stop handling: ``eos_id`` accepts a single token id **or any iterable of
ids** — instruct checkpoints routinely emit several terminators
(``<|eot|>`` + ``<|eos|>``), and codebook stacks stop when every codebook's
token is a stop id. The per-request ``sampling`` params (see
``repro.server.sampling.SamplingParams``) may carry additional stop ids;
``stop_ids`` is the union the engine actually checks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, FrozenSet, Iterable, List, Optional, Sequence, Union

__all__ = ["Request", "RequestState", "RequestQueue"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]            # token ids; rows may be per-codebook
    max_new_tokens: int
    arrival: float = 0.0             # seconds relative to engine start
    eos_id: Union[int, Iterable[int], None] = None
    sampling: Optional[Any] = None   # SamplingParams; None => greedy

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def stop_ids(self) -> FrozenSet[int]:
        """Every token id that terminates this request (eos + sampling).
        Memoized — it is consulted per generated token on the decode hot
        path, and neither input field mutates after construction."""
        memo = self.__dict__.get("_stop_ids")
        if memo is None:
            eos = self.eos_id
            if eos is None:
                memo = frozenset()
            elif isinstance(eos, int) or hasattr(eos, "item"):
                memo = frozenset({int(eos)})
            else:
                memo = frozenset(int(t) for t in eos)
            extra = getattr(self.sampling, "stop", None)
            if extra:
                memo |= frozenset(extra)
            self.__dict__["_stop_ids"] = memo
        return memo


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    t_admit: float
    generated: List = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    # terminal disposition: "stop" (stop token), "length" (budget),
    # "capacity" (cache full -> truncated), "aborted" (cancelled)
    finish_reason: Optional[str] = None
    # speculative decoding accounting (engine fills these when a spec
    # cycle covered this request's slot): cycles seen, draft tokens
    # scored for it, and how many of those the verify pass accepted
    spec_cycles: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def aborted(self) -> bool:
        return self.finish_reason == "aborted"

    @property
    def hit_stop(self) -> bool:
        """Last generated token is in the request's stop set (all
        codebooks must agree on a multi-codebook stack)."""
        stops = self.request.stop_ids
        if not stops or not self.generated:
            return False
        last = self.generated[-1]
        if isinstance(last, (list, tuple)):  # multi-codebook step
            return all(t in stops for t in last)
        return last in stops

    @property
    def done(self) -> bool:
        return (len(self.generated) >= self.request.max_new_tokens
                or self.hit_stop)


class RequestQueue:
    """FIFO over arrival time: a request becomes admissible once the
    engine clock passes its ``arrival`` (open-loop trace replay)."""

    def __init__(self, requests: Iterable[Request] = ()):
        self._q: deque = deque(sorted(requests, key=lambda r: r.arrival))

    def push(self, req: Request) -> None:
        if self._q and req.arrival < self._q[-1].arrival:
            items = sorted([*self._q, req], key=lambda r: r.arrival)
            self._q = deque(items)
        else:
            self._q.append(req)

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._q and self._q[0].arrival <= now:
            return self._q.popleft()
        return None

    def requeue(self, req: Request) -> None:
        """Put a just-popped request back at the head (admission failed —
        e.g. the KV-page pool can't host it yet). Arrival order holds
        because ``req`` was the head a moment ago."""
        self._q.appendleft(req)

    def remove(self, rid: int) -> Optional[Request]:
        """Cancel a still-queued request; returns it, or None if ``rid``
        is not waiting here (already admitted, finished, or unknown)."""
        for i, r in enumerate(self._q):
            if r.rid == rid:
                del self._q[i]
                return r
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
