"""Request lifecycle for the continuous-batching serving engine.

A ``Request`` is a prompt plus a generation budget; a ``RequestState`` is a
request bound to a decode slot, accumulating generated tokens and the
timestamps the metrics layer reads (arrival -> admit -> first token ->
finish). ``RequestQueue`` is the arrival-ordered waiting line the scheduler
drains into freed slots.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, List, Optional, Sequence

__all__ = ["Request", "RequestState", "RequestQueue"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]            # token ids; rows may be per-codebook
    max_new_tokens: int
    arrival: float = 0.0             # seconds relative to engine start
    eos_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    t_admit: float
    generated: List = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        if eos is None or not self.generated:
            return False
        last = self.generated[-1]
        if isinstance(last, (list, tuple)):  # multi-codebook step
            return all(t == eos for t in last)
        return last == eos


class RequestQueue:
    """FIFO over arrival time: a request becomes admissible once the
    engine clock passes its ``arrival`` (open-loop trace replay)."""

    def __init__(self, requests: Iterable[Request] = ()):
        self._q: deque = deque(sorted(requests, key=lambda r: r.arrival))

    def push(self, req: Request) -> None:
        if self._q and req.arrival < self._q[-1].arrival:
            items = sorted([*self._q, req], key=lambda r: r.arrival)
            self._q = deque(items)
        else:
            self._q.append(req)

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._q and self._q[0].arrival <= now:
            return self._q.popleft()
        return None

    def requeue(self, req: Request) -> None:
        """Put a just-popped request back at the head (admission failed —
        e.g. the KV-page pool can't host it yet). Arrival order holds
        because ``req`` was the head a moment ago."""
        self._q.appendleft(req)

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
