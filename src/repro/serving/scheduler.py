"""Slot scheduler + KV-page allocator: continuous batching over a fixed
decode batch and a block-paged KV pool.

The engine decodes a fixed batch of ``num_slots`` rows forever; the
scheduler's job is purely occupancy — hand a freed row to the next waiting
request the moment a sequence finishes, instead of waiting for the whole
batch to drain (the lock-step failure mode this subsystem replaces).

``BlockAllocator`` owns the paged KV pool's page lifecycle: a free list,
per-page reference counts (prefix-shared pages are held by every slot that
mapped them), and the prefix-cache registry — a chain hash over
page-aligned prompt prefixes mapping to resident pages. Pages whose
refcount drops to zero but that still back a registered prefix move to an
LRU of evictable cached pages; allocation prefers truly free pages and
evicts the oldest unreferenced cached page only under pressure (the
registry entry dies with it).

Mesh-native serving (DESIGN.md §12) changes none of this bookkeeping: page
ids are *logical* and mesh-wide. Each shard of the ``model`` axis holds the
same pages of every per-layer pool, sliced to its local KV head group —
one logical block table (replicated) indexes every shard's page-local
view, so refcounts, the prefix registry, CoW holds, and on-demand growth
run host-side exactly once regardless of mesh shape. Allocation decisions
therefore never diverge between shards, which is what keeps preemption
and rollback refcounts-to-baseline guarantees intact under GSPMD.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request, RequestState

__all__ = ["BlockAllocator", "Scheduler"]


class BlockAllocator:
    """Refcounted page allocator + prefix-cache registry (host side)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(
                f"need num_pages/page_size >= 1, got {num_pages}/{page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self._registry: Dict[bytes, int] = {}   # prefix chain key -> page
        self._page_key: Dict[int, bytes] = {}   # inverse, for eviction
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # ref==0, cached

    @property
    def available(self) -> int:
        """Pages allocatable right now (free + evictable cached)."""
        return len(self._free) + len(self._lru)

    @property
    def cached(self) -> int:
        """Unreferenced pages kept resident for prefix reuse."""
        return len(self._lru)

    @property
    def free(self) -> int:
        """Truly free pages (no content, no registry entry)."""
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Pages currently referenced by at least one slot."""
        return len(self._ref)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve ``n`` pages (ref=1 each) or None if the pool can't —
        the caller requeues the request; nothing is partially taken."""
        if n > self.available:
            return None
        out = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:  # evict the oldest unreferenced cached page
                p, _ = self._lru.popitem(last=False)
                del self._registry[self._page_key.pop(p)]
            self._ref[p] = 1
            out.append(p)
        return out

    def retain(self, pages: Sequence[int]) -> None:
        """Take a reference on already-resident pages (prefix hits)."""
        for p in pages:
            if self._ref.get(p, 0) == 0:
                self._lru.pop(p, None)
            self._ref[p] = self._ref.get(p, 0) + 1

    def release(self, pages: Sequence[int]) -> None:
        for p in pages:
            r = self._ref.get(p, 0) - 1
            if r < 0:
                raise ValueError(f"page {p} released more than retained")
            if r == 0:
                del self._ref[p]
                if p in self._page_key:
                    self._lru[p] = None   # stays resident, evictable
                else:
                    self._free.append(p)
            else:
                self._ref[p] = r

    # -- prefix registry ---------------------------------------------------

    @staticmethod
    def chain_keys(prompt, page_size: int) -> List[bytes]:
        """Rolling hash per full prompt page: key_i commits to every token
        in pages 0..i, so one dict probe matches an entire prefix chain."""
        arr = np.ascontiguousarray(np.asarray(prompt, np.int32))
        keys, h = [], b""
        for i in range(len(arr) // page_size):
            h = hashlib.sha1(
                h + arr[i * page_size:(i + 1) * page_size].tobytes()).digest()
            keys.append(h)
        return keys

    def match(self, keys: Sequence[bytes]) -> List[int]:
        """Longest resident chain of full prefix pages (no refs taken)."""
        out = []
        for k in keys:
            p = self._registry.get(k)
            if p is None:
                break
            out.append(p)
        return out

    def register(self, key: bytes, page: int) -> None:
        """Publish ``page`` as the cached copy of chain ``key`` (first
        writer wins; a page backs at most one key)."""
        if key not in self._registry and page not in self._page_key:
            self._registry[key] = page
            self._page_key[page] = key


class Scheduler:
    def __init__(self, num_slots: int,
                 allocator: Optional[BlockAllocator] = None):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        self.allocator = allocator
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.running: Dict[int, RequestState] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def has_free(self) -> bool:
        return bool(self._free)

    def admit(self, req: Request, now: float) -> RequestState:
        """Bind ``req`` to the lowest free slot."""
        slot = self._free.pop()
        rs = RequestState(request=req, slot=slot, t_admit=now)
        self.running[slot] = rs
        return rs

    def release(self, slot: int) -> Optional[RequestState]:
        """Free a slot whose sequence finished; its cache row is recycled
        in place by the next admission's scatter."""
        rs = self.running.pop(slot, None)
        if rs is not None:
            self._free.append(slot)
            self._free.sort(reverse=True)
        return rs
