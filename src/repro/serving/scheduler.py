"""Slot scheduler: continuous batching over a fixed decode batch.

The engine decodes a fixed batch of ``num_slots`` rows forever; the
scheduler's job is purely occupancy — hand a freed row to the next waiting
request the moment a sequence finishes, instead of waiting for the whole
batch to drain (the lock-step failure mode this subsystem replaces).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.serving.request import Request, RequestQueue, RequestState

__all__ = ["Scheduler"]


class Scheduler:
    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.running: Dict[int, RequestState] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def has_free(self) -> bool:
        return bool(self._free)

    def admit(self, req: Request, now: float) -> RequestState:
        """Bind ``req`` to the lowest free slot."""
        slot = self._free.pop()
        rs = RequestState(request=req, slot=slot, t_admit=now)
        self.running[slot] = rs
        return rs

    def admit_from(self, queue: RequestQueue, now: float) -> List[RequestState]:
        """Drain ready requests into free slots; returns the admissions."""
        admitted = []
        while self.has_free():
            req = queue.pop_ready(now)
            if req is None:
                break
            admitted.append(self.admit(req, now))
        return admitted

    def release(self, slot: int) -> Optional[RequestState]:
        """Free a slot whose sequence finished; its cache row is recycled
        in place by the next admission's scatter."""
        rs = self.running.pop(slot, None)
        if rs is not None:
            self._free.append(slot)
            self._free.sort(reverse=True)
        return rs
