"""Self-speculative decoding support: draft views, support gating, autotuner.

The draft model is *free* in LNS-Madam: bitwidth is a pure re-grid of the
packed wire word (`lns_requant_packed`), so a B=6/7 draft is the same
8-bit weights on a coarser exponent grid — shared scale tensors, zero
extra checkpoints (paper §6.1.1; the per-bitwidth datapath argument of
the Bitwidth-Specific Logarithmic Arithmetic paper in PAPERS.md). The
engine re-grids the serving tree once at init via
:func:`build_draft_params`, runs k greedy draft steps per slot, then
scores all k tokens with the full-precision weights in a single S=k
verify pass (see ``serving/engine.py`` and DESIGN.md §11).

This module is engine-agnostic: it owns the parameter transform, the
"can this architecture rewind?" predicate, and the accept-rate feedback
autotuner over (draft bitwidth, k) arms.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.lns import LNSWeight, is_lns_weight
from repro.kernels import dispatch

__all__ = ["SpecConfig", "spec_supported", "build_draft_params",
           "draft_requant_error", "request_class", "SpecAutotuner"]

Arm = Tuple[int, int]  # (draft_bits, k)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-level speculative decoding settings.

    k:            draft tokens per cycle (>= 1); the verify pass scores all
                  k in one S=k suffix forward.
    draft_bits:   wire bitwidth of the draft view (8 = identity view —
                  drafts are the target model itself; every draft accepts).
    autotune:     explore (bits, k) arms from accept-rate/throughput
                  feedback instead of pinning the configured pair.
    bits_choices/k_choices: the autotuner's arm grid (the configured
                  (draft_bits, k) is always included).
    decide_every: cycles between autotuner arm decisions.
    min_visits:   decisions each arm gets before exploitation starts.
    ema:          smoothing factor for reward / accept-rate EMAs.
    """

    k: int = 4
    draft_bits: int = 6
    autotune: bool = False
    bits_choices: Tuple[int, ...] = (6, 7, 8)
    k_choices: Tuple[int, ...] = (2, 4, 8)
    decide_every: int = 8
    min_visits: int = 1
    ema: float = 0.25

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculate k must be >= 1, got {self.k}")
        if not 2 <= self.draft_bits <= 8:
            raise ValueError(
                f"draft_bits must be in [2, 8], got {self.draft_bits}")

    def arms(self) -> List[Arm]:
        """The autotuner grid (configured arm first, then the rest)."""
        base = (self.draft_bits, self.k)
        grid = [base]
        for b, k in itertools.product(self.bits_choices, self.k_choices):
            if (b, k) != base:
                grid.append((b, k))
        return grid


def spec_supported(cfg) -> Optional[str]:
    """None when speculative decoding is sound for ``cfg``, else the reason.

    Rewind works by resetting per-slot KV cursors — attention caches
    (dense, ring, paged, MLA) are position-addressed, so rejected writes
    are simply overwritten and never attendable. Recurrent layers fold
    state irreversibly (no cursor to rewind) and multi-codebook heads
    emit token *tuples* the accept math does not model.
    """
    prefix, _, period = cfg.layer_pattern()
    kinds = set(prefix) | set(period)
    if kinds & {"mamba", "rwkv"}:
        return "recurrent layers cannot rewind rejected draft state"
    if getattr(cfg, "num_codebooks", 0):
        return "multi-codebook sampling is not modelled by the accept rule"
    return None


def build_draft_params(params, bits: int, *, backend: Optional[str] = None):
    """Re-grid every ``LNSWeight`` leaf of ``params`` to ``bits`` wire bits.

    Scale tensors (and every non-LNS leaf: embeddings kept in LNS too, so
    in practice norms/biases) are shared **by reference** — the view costs
    one uint8 tree, nothing else. ``bits == fmt.bits`` leaves are returned
    unchanged, so the B=8 view *is* the target tree. The packed transform
    goes through ``dispatch.requant_pack`` (Pallas on TPU/GPU, the
    bit-identical jnp re-grid on CPU).
    """
    def one(leaf):
        if not is_lns_weight(leaf):
            return leaf
        dst = leaf.fmt.with_bits(bits)
        if dst == leaf.fmt:
            return leaf
        packed = dispatch.requant_pack(leaf.packed, leaf.fmt, dst,
                                       backend=backend)
        return LNSWeight(packed, leaf.scale, None, dst)

    return jax.tree.map(one, params, is_leaf=is_lns_weight)


def draft_requant_error(params, draft_params) -> Dict[str, float]:
    """Numerics health of a draft view vs. its target tree.

    The re-grid is the same clamp-after-rescale as every other LNS clip
    site, so two quantities capture its damage (DESIGN.md §14):
    ``rel_err_mean`` — mean |decode(draft) - decode(target)| over mean
    |decode(target)| (the realized re-grid error, the serving analogue of
    the paper's Thm.-1 update error) — and ``sat_hi_frac`` — the fraction
    of target codes the down-grid clamps at the coarse grid's underflow
    rail. Host-side (a handful of reductions per leaf); the engine caches
    the result per built bitwidth.
    """
    from repro.core.lns import lns_decode_packed
    import jax.numpy as jnp
    src_leaves = [x for x in jax.tree.leaves(params, is_leaf=is_lns_weight)
                  if is_lns_weight(x)]
    dst_leaves = [x for x in jax.tree.leaves(draft_params,
                                             is_leaf=is_lns_weight)
                  if is_lns_weight(x)]
    err = ref = 0.0
    sat = 0.0
    n = 0
    bits = None
    for s, d in zip(src_leaves, dst_leaves):
        bits = d.fmt.bits
        if d is s:  # identity view (bits == fmt.bits): zero error
            n += s.packed.size
            continue
        sv = lns_decode_packed(s.packed, s.fmt, jnp.float32)
        dv = lns_decode_packed(d.packed, d.fmt, jnp.float32)
        err += float(jnp.sum(jnp.abs(dv - sv)))
        ref += float(jnp.sum(jnp.abs(sv)))
        ratio = s.fmt.gamma // d.fmt.gamma
        if ratio >= 1:
            code = (s.packed.astype(jnp.int32)) & s.fmt.max_code
            sat += float(jnp.sum((code + ratio // 2) // ratio
                                 > d.fmt.max_code))
        n += s.packed.size
    if n == 0:
        return {"elements": 0}
    return {"bits": bits, "elements": n,
            "rel_err_mean": err / ref if ref > 0 else 0.0,
            "sat_hi_frac": sat / n}


def request_class(request) -> str:
    """Autotuner request class: greedy requests accept far more drafts
    than sampled ones (temperature noise breaks draft/target agreement),
    so accept-rate feedback is tracked per class."""
    sp = request.sampling
    return "greedy" if sp is None or sp.is_greedy else "sampled"


class SpecAutotuner:
    """Deterministic bandit over (draft_bits, k) arms.

    Reward is *measured emitted tokens per second per cycle* (EMA per
    arm) — the only number that folds accept rate, draft cost, and verify
    cost into one objective. Exploration is deterministic (no RNG, so a
    replayed trace tunes identically): arms are first visited round-robin
    ``min_visits`` times, then every fourth decision re-measures the
    least-recently-decided arm while the rest exploit the best EMA.
    Per-(bits, class) accept-rate EMAs ride along for observability
    (``/metrics``) and are the raw feedback signal requested by DESIGN
    §11.
    """

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg
        self.arms: List[Arm] = cfg.arms()
        self.current: Arm = (cfg.draft_bits, cfg.k)
        self.reward: Dict[Arm, float] = {}
        self.visits: Dict[Arm, int] = {a: 0 for a in self.arms}
        self.accept: Dict[Tuple[int, str], float] = {}
        self.cycles = 0
        self.decisions = 0

    def observe(self, arm: Arm, emitted: int, wall_s: float,
                class_accepts: Dict[str, Tuple[int, int]]) -> None:
        """Record one cycle run under ``arm``: ``emitted`` tokens in
        ``wall_s`` seconds, plus per-class (accepted, drafted) counts."""
        self.cycles += 1
        self.visits[arm] = self.visits.get(arm, 0) + 1
        ema = self.cfg.ema
        if wall_s > 0:
            r = emitted / wall_s
            prev = self.reward.get(arm)
            self.reward[arm] = r if prev is None else (1 - ema) * prev + ema * r
        for cls, (acc, drafted) in class_accepts.items():
            if drafted <= 0:
                continue
            key = (arm[0], cls)
            rate = acc / drafted
            prev = self.accept.get(key)
            self.accept[key] = (rate if prev is None
                                else (1 - ema) * prev + ema * rate)

    def propose(self) -> Arm:
        """The arm for the next cycle (changes every ``decide_every``)."""
        if self.cycles % self.cfg.decide_every:
            return self.current
        self.decisions += 1
        cold = [a for a in self.arms if self.visits[a] < self.cfg.min_visits]
        if cold:
            self.current = cold[0]
        elif self.decisions % 4 == 0:
            self.current = min(self.arms, key=lambda a: self.visits[a])
        else:
            self.current = max(
                self.arms, key=lambda a: self.reward.get(a, 0.0))
        return self.current

    def snapshot(self) -> Dict[str, object]:
        """Flat dict for ``/metrics`` (JSON-safe keys only)."""
        out: Dict[str, object] = {
            "spec_arm_bits": self.current[0],
            "spec_arm_k": self.current[1],
            "spec_tuner_cycles": self.cycles,
        }
        for (bits, cls), rate in sorted(self.accept.items()):
            out[f"spec_accept_rate_b{bits}_{cls}"] = round(rate, 4)
        for (bits, k), r in sorted(self.reward.items()):
            out[f"spec_reward_b{bits}_k{k}"] = round(r, 2)
        return out
