from repro.serving.engine import DEFAULT_BUCKETS, Engine
from repro.serving.metrics import RequestMetrics, summarize
from repro.serving.request import Request, RequestQueue, RequestState
from repro.serving.scheduler import BlockAllocator, Scheduler
from repro.serving.spec import (SpecAutotuner, SpecConfig,
                                build_draft_params, spec_supported)
from repro.serving.trace import max_trace_len, synthetic_trace

__all__ = ["BlockAllocator", "DEFAULT_BUCKETS", "Engine", "Request",
           "RequestMetrics", "RequestQueue", "RequestState", "Scheduler",
           "SpecAutotuner", "SpecConfig", "build_draft_params",
           "max_trace_len", "spec_supported", "summarize",
           "synthetic_trace"]
