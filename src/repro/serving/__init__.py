from repro.serving.engine import DEFAULT_BUCKETS, Engine
from repro.serving.metrics import RequestMetrics, summarize
from repro.serving.request import Request, RequestQueue, RequestState
from repro.serving.scheduler import BlockAllocator, Scheduler
from repro.serving.trace import max_trace_len, synthetic_trace

__all__ = ["BlockAllocator", "DEFAULT_BUCKETS", "Engine", "Request",
           "RequestMetrics", "RequestQueue", "RequestState", "Scheduler",
           "max_trace_len", "summarize", "synthetic_trace"]
