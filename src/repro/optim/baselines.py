"""Baseline optimizers (paper §6.1.2): SGD+momentum and AdamW, plus the
*quantized weight update* wrapper of Eq. 4 used by the Fig.-7 comparison
(W ← Q_log(U(W, ∇W)) at B_U bits)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, lns_quantize

__all__ = ["sgd", "adamw", "quantized_update"]


class SGDState(NamedTuple):
    momentum: Any
    count: jax.Array


def sgd(lr: float = 0.1, momentum: float = 0.9, weight_decay: float = 1e-4):
    """SGD with momentum + decoupled weight decay (paper's CV default)."""

    def init(params):
        return SGDState(momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                        count=jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params, key=None):
        def leaf(p, g, m):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.momentum)
        out = [leaf(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
                SGDState(momentum=jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
                         count=state.count + 1))

    return init, update


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw(lr: float = 3e-5, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01):
    """AdamW (paper's NLP default)."""

    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(m=z(), v=z(), count=jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params, key=None):
        c = state.count + 1
        cf = c.astype(jnp.float32)

        def leaf(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** cf)
            vh = v / (1 - b2 ** cf)
            new = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return new.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
                AdamWState(m=jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
                           v=jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
                           count=c))

    return init, update


def quantized_update(opt, fmt: LNSFormat, scale_axis: Optional[int] = -1):
    """Eq. 4 wrapper: quantize the updated weights onto the LNS grid.

    This is how SGD/AdamW are made to 'update in LNS' for the Fig.-7
    degradation study — and why they degrade: their update magnitudes are
    not proportional to the weights (Theorem 1)."""
    init, update = opt

    def qupdate(grads, state, params, key=None):
        new_params, new_state = update(grads, state, params, key=key)

        def q(p):
            if p.ndim < 2:  # same fp carve-out as Madam-LNS
                return p
            ax = scale_axis if p.ndim >= 2 else None
            return lns_quantize(p, fmt, scale_axis=ax)

        return jax.tree.map(q, new_params), new_state

    return init, qupdate
