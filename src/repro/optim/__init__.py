from repro.optim.baselines import adamw, quantized_update, sgd
from repro.optim.madam import (LNSWeight, MadamConfig, MadamState,
                               attach_proxies, grad_proxies, init_lns_params,
                               is_lns_weight, madam_fp, madam_lns,
                               materialize)

__all__ = [
    "LNSWeight", "MadamConfig", "MadamState", "init_lns_params", "is_lns_weight",
    "materialize", "grad_proxies", "attach_proxies",
    "madam_lns", "madam_fp", "sgd", "adamw", "quantized_update",
]
