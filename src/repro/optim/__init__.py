from repro.optim.baselines import adamw, quantized_update, sgd
from repro.optim.madam import (LNSWeight, MadamConfig, MadamState, init_lns_params,
                               madam_fp, madam_lns, materialize)

__all__ = [
    "LNSWeight", "MadamConfig", "MadamState", "init_lns_params", "materialize",
    "madam_lns", "madam_fp", "sgd", "adamw", "quantized_update",
]
