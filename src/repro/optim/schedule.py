"""Learning-rate schedules. The paper finds Madam robust at a fixed η=2⁻⁷;
warmup/cosine are provided for the SGD/AdamW baselines and large-scale runs
(ImageNet §.5.4 uses a 10-epoch warmup)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "warmup_cosine", "warmup_stable_decay"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak_lr - floor) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def warmup_stable_decay(peak_lr: float, warmup_steps: int, stable_steps: int,
                        decay_steps: int, floor_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay_frac = jnp.clip((step - warmup_steps - stable_steps) / max(decay_steps, 1), 0.0, 1.0)
        dec = peak_lr * (1.0 - (1.0 - floor_frac) * decay_frac)
        out = jnp.where(step < warmup_steps, warm, jnp.where(step < warmup_steps + stable_steps, peak_lr, dec))
        return out
    return fn
