"""Madam on LNS — paper §4, Algorithm 1.

The co-design half of LNS-Madam: weights live *permanently* as LNS integer
exponent codes (no floating-point master copy), and the multiplicative
update is an **integer add on the exponent**:

    code ← clamp( round( code + η·γ_U · (g/√ĝ₂) ⊙ sign(W) ), 0, 2^(B_U−1)−1 )

(our codes store the negated exponent, so a magnitude *decrease* is a code
*increase*; the sign never flips — multiplicative updates preserve sign).

Because the weights are already LNS codes there is no integer→LNS conversion
in the update path (paper §4, last paragraph), and the state is
1 B sign + 2 B code per element instead of a 4 B fp32 master + 4 B Adam m.

Leaves with fewer than 2 dims (norm gains, biases — the paper keeps BN at
full precision) take a full-precision Madam step on a dense fp32 copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, compute_scale, lns_decode, lns_encode
from repro.numerics.rounding import round_nearest, stochastic_round

__all__ = ["LNSWeight", "MadamConfig", "MadamState", "init_lns_params",
           "materialize", "madam_lns", "madam_fp"]


class LNSWeight(NamedTuple):
    """A weight tensor stored natively in LNS (sign, exponent code, scale)."""

    sign: jax.Array  # int8 in {-1, +1}
    code: jax.Array  # fmt.code_dtype, [0, max_code]
    scale: jax.Array  # f32, power-of-two, broadcastable per-channel scale


def is_lns_weight(leaf) -> bool:
    return isinstance(leaf, LNSWeight)


@dataclasses.dataclass(frozen=True)
class MadamConfig:
    """Algorithm-1 hyperparameters (paper defaults: η=2⁻⁷, β=0.999).

    ``factored`` replaces the full second-moment EMA with Adafactor-style
    row/col factors for >=2-D leaves — a beyond-paper scaling feature that
    makes optimizer state O(R+C) instead of O(R·C) (used by the trillion-
    parameter MoE configs; DESIGN.md §8).
    """

    lr: float = 2.0 ** -7
    beta: float = 0.999
    update_format: LNSFormat = LNSFormat(bits=16, gamma=8 * (1 << 8))
    stochastic: bool = False          # SR on the exponent round (Q_U option)
    eps: float = 1e-30
    fp_lr: Optional[float] = None     # lr for the fp (ndim<2) leaves
    fp_clip: float = 10.0             # Madam's p-clamp for fp leaves
    factored: bool = False            # Adafactor-style factored g2

    def __post_init__(self):
        if self.update_format.bits < 2:
            raise ValueError("update_format.bits must be >= 2")


class MadamState(NamedTuple):
    g2: Any          # second-moment EMA pytree (fp32), like params
    count: jax.Array


def _lns_leaf_filter(path, leaf) -> bool:
    """Default policy: >=2-D tensors live in LNS; 1-D/scalars stay fp."""
    return hasattr(leaf, "ndim") and leaf.ndim >= 2


def init_lns_params(params, cfg: MadamConfig, scale_axis="auto",
                    leaf_filter: Callable = _lns_leaf_filter):
    """Encode a dense parameter pytree into mixed LNSWeight/fp leaves.

    ``scale_axis="auto"`` keeps per-channel resolution on every axis except
    the contraction (-2) axis — so stacked (scanned) layer weights and MoE
    expert stacks each get their own output-channel scales.
    """
    fmt = cfg.update_format

    def enc(path, w):
        if not leaf_filter(path, w):
            return w.astype(jnp.float32)
        if scale_axis == "auto":
            ax = tuple(i for i in range(w.ndim) if i != w.ndim - 2)
        else:
            ax = scale_axis
        scale = compute_scale(w, axis=ax)
        sign, code = lns_encode(w, fmt, scale)
        return LNSWeight(sign=sign, code=code, scale=scale)

    return jax.tree_util.tree_map_with_path(enc, params)


def materialize(params, cfg: MadamConfig, dtype=jnp.bfloat16):
    """Decode LNSWeight leaves to dense arrays for the forward pass.

    fp leaves (norm gains etc.) pass through untouched — they stay fp32.
    """
    fmt = cfg.update_format

    def dec(leaf):
        if is_lns_weight(leaf):
            return lns_decode(leaf.sign, leaf.code, fmt, leaf.scale, dtype=dtype)
        return leaf

    return jax.tree.map(dec, params, is_leaf=is_lns_weight)


def madam_lns(cfg: MadamConfig):
    """Build the (init, update) pair for LNS-native Madam.

    ``update(grads, state, params, key=None)`` consumes gradients w.r.t. the
    *dense* (materialized) weights and returns new (params, state). ``key``
    is required when ``cfg.stochastic``.
    """
    fmt = cfg.update_format

    def _shape_of(p):
        return p.code.shape if is_lns_weight(p) else p.shape

    def _v_init(p):
        shape = _shape_of(p)
        if cfg.factored and len(shape) >= 2:
            return {"r": jnp.zeros(shape[:-1], jnp.float32),
                    "c": jnp.zeros(shape[:-2] + shape[-1:], jnp.float32)}
        return jnp.zeros(shape, jnp.float32)

    def _v_update(g, v):
        """EMA update; returns (new_v, dense v-hat for normalization)."""
        if isinstance(v, dict):  # factored
            r = cfg.beta * v["r"] + (1.0 - cfg.beta) * jnp.mean(g * g, axis=-1)
            c = cfg.beta * v["c"] + (1.0 - cfg.beta) * jnp.mean(g * g, axis=-2)
            denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), 1e-30)
            vhat = r[..., None] * c[..., None, :] / denom[..., None]
            return {"r": r, "c": c}, vhat
        nv = (1.0 - cfg.beta) * g * g + cfg.beta * v
        return nv, nv

    def init(params) -> MadamState:
        g2 = jax.tree.map(_v_init, params, is_leaf=is_lns_weight)
        return MadamState(g2=g2, count=jnp.zeros((), jnp.int32))

    def update(grads, state: MadamState, params, key: Optional[jax.Array] = None):
        count = state.count + 1
        # bias-corrected second-moment EMA (Algorithm 1 + init correction)
        bc = 1.0 - cfg.beta ** count.astype(jnp.float32)

        leaves_p, treedef = jax.tree_util.tree_flatten(params, is_leaf=is_lns_weight)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_v = treedef.flatten_up_to(state.g2)
        if cfg.stochastic:
            if key is None:
                raise ValueError("stochastic Q_U requires a PRNG key")
            keys = list(jax.random.split(key, len(leaves_p)))
        else:
            keys = [None] * len(leaves_p)

        new_p, new_v = [], []
        for p, g, v, k in zip(leaves_p, leaves_g, leaves_v, keys):
            g = g.astype(jnp.float32)
            v, vhat = _v_update(g, v)
            gstar = g * jax.lax.rsqrt(vhat / bc + cfg.eps)
            if is_lns_weight(p):
                # integer exponent step: Δcode = +η·γ_U·g*·sign(W)
                step = cfg.lr * fmt.gamma * gstar * p.sign.astype(jnp.float32)
                target = p.code.astype(jnp.float32) + step
                rounded = (stochastic_round(k, target) if cfg.stochastic
                           else round_nearest(target))
                code = jnp.clip(rounded, 0, fmt.max_code).astype(fmt.code_dtype)
                new_p.append(LNSWeight(sign=p.sign, code=code, scale=p.scale))
            else:
                # fp Madam for norm gains / biases (paper's BN carve-out)
                lr = cfg.fp_lr if cfg.fp_lr is not None else cfg.lr
                w = p * jnp.exp(-lr * jnp.sign(p) * gstar)
                # allow zero-crossing for fp leaves via an additive floor
                w = jnp.where(jnp.abs(p) < 1e-8, p - lr * gstar * 1e-8, w)
                new_p.append(jnp.clip(w, -cfg.fp_clip, cfg.fp_clip))
            new_v.append(v)

        return (jax.tree_util.tree_unflatten(treedef, new_p),
                MadamState(g2=jax.tree_util.tree_unflatten(treedef, new_v), count=count))

    return init, update


def madam_fp(lr: float = 2.0 ** -7, beta: float = 0.999, clip: float = 10.0,
             eps: float = 1e-30):
    """Full-precision Madam (Eq. 9) — Bernstein et al.'s optimizer, the
    paper's pre-quantization baseline and the Fig.-7 comparison anchor."""

    def init(params) -> MadamState:
        return MadamState(g2=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state: MadamState, params, key=None):
        count = state.count + 1
        bc = 1.0 - beta ** count.astype(jnp.float32)

        def leaf(p, g, v):
            g = g.astype(jnp.float32)
            v = (1.0 - beta) * g * g + beta * v
            gstar = g * jax.lax.rsqrt(v / bc + eps)
            w = p.astype(jnp.float32) * jnp.exp(-lr * jnp.sign(p) * gstar)
            return jnp.clip(w, -clip, clip).astype(p.dtype), v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state.g2)
        out = [leaf(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_p, MadamState(g2=new_v, count=count)

    return init, update
