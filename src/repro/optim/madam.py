"""Madam on LNS — paper §4, Algorithm 1.

The co-design half of LNS-Madam: weights live *permanently* as packed LNS
wire words (no floating-point master copy — see :class:`repro.core.lns
.LNSWeight` and DESIGN.md §3), and the multiplicative update is an
**integer add on the exponent**:

    code ← clamp( round( code + η·γ_U · (g/√ĝ₂) ⊙ sign(W) ), 0, 2^(B_U−1)−1 )

(our codes store the negated exponent, so a magnitude *decrease* is a code
*increase*; the sign bit never flips — multiplicative updates preserve
sign).

Because the weights are already packed LNS words there is no integer→LNS
conversion in the update path (paper §4, last paragraph), and the state is
one ``ceil(B_U/8)``-byte word per element instead of a 4 B fp32 master +
4 B Adam m. Every >=2-D leaf takes the fused ``madam_update_packed``
kernel step through :mod:`repro.kernels.dispatch` — one HBM pass over
(word, grad, v) per leaf; the jnp reference backend is the bit-exact
oracle (and the only path for the factored / stochastic variants).

Leaves with fewer than 2 dims (norm gains, biases — the paper keeps BN at
full precision) take a full-precision Madam step on a dense fp32 copy.

Gradients: training never densifies the packed tree. The train step
differentiates w.r.t. the zero ``delta`` carriers from
:func:`grad_proxies`; dL/ddelta == dL/dW at W = decode(packed), produced
either by the routed GEMM's custom VJP or by the decode-plus-delta
fallback in the model layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import (LNSFormat, LNSWeight, is_lns_weight, lns_pack,
                            lns_unpack, lns_weight_encode)
from repro.kernels import dispatch
from repro.numerics.rounding import round_nearest, stochastic_round
from repro.obs.numerics import path_name

__all__ = ["LNSWeight", "MadamConfig", "MadamState", "init_lns_params",
           "is_lns_weight", "materialize", "grad_proxies", "attach_proxies",
           "madam_lns", "madam_fp"]


@dataclasses.dataclass(frozen=True)
class MadamConfig:
    """Algorithm-1 hyperparameters (paper defaults: η=2⁻⁷, β=0.999).

    ``factored`` replaces the full second-moment EMA with Adafactor-style
    row/col factors for >=2-D leaves — a beyond-paper scaling feature that
    makes optimizer state O(R+C) instead of O(R·C) (used by the trillion-
    parameter MoE configs; DESIGN.md §8).
    """

    lr: float = 2.0 ** -7
    beta: float = 0.999
    update_format: LNSFormat = LNSFormat(bits=16, gamma=8 * (1 << 8))
    stochastic: bool = False          # SR on the exponent round (Q_U option)
    eps: float = 1e-30
    fp_lr: Optional[float] = None     # lr for the fp (ndim<2) leaves
    fp_clip: float = 10.0             # Madam's p-clamp for fp leaves
    factored: bool = False            # Adafactor-style factored g2

    def __post_init__(self):
        if self.update_format.bits < 2:
            raise ValueError("update_format.bits must be >= 2")

    # The ``backend`` field (deprecated PR 6) is gone: kernel backend
    # selection lives in ``repro.kernels.dispatch.configure()`` /
    # ``configured()`` or the per-call ``backend=`` op argument.
    @property
    def backend(self):
        raise AttributeError(
            "MadamConfig.backend was removed: select the kernel backend "
            "with repro.kernels.dispatch.configure(backend=...) or the "
            "configured(...) context manager")


def _reject_backend_kwarg(cls):
    """``MadamConfig(backend=...)`` gets an actionable error instead of the
    generated "unexpected keyword argument" TypeError."""
    orig = cls.__init__

    def __init__(self, *args, **kwargs):
        if "backend" in kwargs:
            raise TypeError(
                f"{cls.__name__}.backend was removed: select the kernel "
                f"backend with repro.kernels.dispatch.configure"
                f"(backend=...) or the configured(...) context manager")
        orig(self, *args, **kwargs)

    cls.__init__ = __init__
    return cls


_reject_backend_kwarg(MadamConfig)


class MadamState(NamedTuple):
    g2: Any          # second-moment EMA pytree (fp32), like params
    count: jax.Array


def _lns_leaf_filter(path, leaf) -> bool:
    """Default policy: >=2-D tensors live in LNS; 1-D/scalars stay fp.

    Scanned ``period`` parameters carry a leading stack axis that does not
    count toward the rank — a stacked norm gain (L, d) is still a 1-D gain
    per layer and keeps the paper's full-precision carve-out (the seed
    quantized these by accident and hid it behind the whole-tree
    materialize; with packed leaves riding ``lax.scan`` the distinction is
    load-bearing: every scan xs leaf must share the stack axis).
    """
    if not hasattr(leaf, "ndim"):
        return False
    stacked = any(getattr(k, "key", None) == "period" for k in path)
    return leaf.ndim - (1 if stacked else 0) >= 2


def init_lns_params(params, cfg: MadamConfig, scale_axis="auto",
                    leaf_filter: Callable = _lns_leaf_filter):
    """Encode a dense parameter pytree into mixed LNSWeight/fp leaves.

    ``scale_axis="auto"`` keeps per-channel resolution on every axis except
    the contraction (-2) axis — so stacked (scanned) layer weights and MoE
    expert stacks each get their own output-channel scales, and the scale
    is constant along the contraction axis (the condition for factoring it
    out of the routed GEMM's epilogue).
    """
    fmt = cfg.update_format

    def enc(path, w):
        if not leaf_filter(path, w):
            return w.astype(jnp.float32)
        if scale_axis == "auto":
            ax = tuple(i for i in range(w.ndim) if i != w.ndim - 2)
        else:
            ax = scale_axis
        return lns_weight_encode(w, fmt, scale_axis=ax)

    return jax.tree_util.tree_map_with_path(enc, params)


def materialize(params, cfg: Optional[MadamConfig] = None,
                dtype=jnp.bfloat16):
    """Decode LNSWeight leaves to dense arrays (whole tree at once).

    NOT a production path anymore: train/prefill/decode/serving consume the
    packed leaves directly through ``kernels/dispatch`` (DESIGN.md §4).
    Kept for the unfused baseline benchmark, offline export, and tests.
    fp leaves (norm gains etc.) pass through untouched — they stay fp32.
    """
    del cfg  # each leaf carries its own fmt now

    def dec(leaf):
        if is_lns_weight(leaf):
            return leaf.decode(dtype)
        return leaf

    return jax.tree.map(dec, params, is_leaf=is_lns_weight)


def grad_proxies(params, dtype=jnp.bfloat16):
    """Zero tangent carriers, one per LNSWeight leaf (fp leaves pass as-is).

    Differentiating a loss w.r.t. this tree yields exactly dL/dW for the
    packed leaves without a dense master copy existing as a primal: inside
    jit the zeros fold to a broadcast constant, the routed GEMM's custom
    VJP writes the weight cotangent into the carrier, and the decode
    fallback adds the (zero) carrier after decode so autodiff routes the
    cotangent the same way.
    """
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype) if is_lns_weight(p) else p,
        params, is_leaf=is_lns_weight)


def attach_proxies(params, proxies):
    """Merge proxy leaves back into the packed tree for a forward pass."""
    return jax.tree.map(
        lambda p, d: p.replace(delta=d) if is_lns_weight(p) else d,
        params, proxies, is_leaf=is_lns_weight)


def madam_lns(cfg: MadamConfig):
    """Build the (init, update) pair for LNS-native Madam.

    ``update(grads, state, params, key=None)`` consumes gradients w.r.t.
    the *decoded* weight values (the :func:`grad_proxies` cotangents) and
    returns new (params, state). ``key`` is required when
    ``cfg.stochastic``.
    """
    fmt = cfg.update_format

    def _shape_of(p):
        return p.shape  # LNSWeight exposes packed.shape; arrays their own

    def _v_init(p):
        shape = _shape_of(p)
        if cfg.factored and len(shape) >= 2:
            return {"r": jnp.zeros(shape[:-1], jnp.float32),
                    "c": jnp.zeros(shape[:-2] + shape[-1:], jnp.float32)}
        return jnp.zeros(shape, jnp.float32)

    def _v_update(g, v):
        """EMA update; returns (new_v, dense v-hat for normalization)."""
        if isinstance(v, dict):  # factored
            r = cfg.beta * v["r"] + (1.0 - cfg.beta) * jnp.mean(g * g, axis=-1)
            c = cfg.beta * v["c"] + (1.0 - cfg.beta) * jnp.mean(g * g, axis=-2)
            denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), 1e-30)
            vhat = r[..., None] * c[..., None, :] / denom[..., None]
            return {"r": r, "c": c}, vhat
        nv = (1.0 - cfg.beta) * g * g + cfg.beta * v
        return nv, nv

    def _lns_leaf_reference(p: LNSWeight, g, v, k, bc, *, requant=None,
                            with_stats=False):
        """jnp fallback: factored v-hat and/or stochastic exponent round."""
        leaf_fmt = p.fmt or fmt
        v, vhat = _v_update(g, v)
        gstar = g * jax.lax.rsqrt(vhat / bc + cfg.eps)
        sign, code = lns_unpack(p.packed, leaf_fmt)
        step = cfg.lr * leaf_fmt.gamma * gstar * sign.astype(jnp.float32)
        target = code.astype(jnp.float32) + step
        rounded = (stochastic_round(k, target) if cfg.stochastic
                   else round_nearest(target))
        new_code = jnp.clip(rounded, 0, leaf_fmt.max_code)
        np_ = p.replace(packed=lns_pack(sign, new_code, leaf_fmt))
        if not with_stats:
            return np_, v
        from repro.kernels.madam_update import madam_stats_dict, madam_stats_vec
        vec = madam_stats_vec(code, target, new_code, gamma=leaf_fmt.gamma,
                              max_code=leaf_fmt.max_code, requant=requant)
        return np_, v, madam_stats_dict(vec, code.size, leaf_fmt)

    def init(params) -> MadamState:
        g2 = jax.tree.map(_v_init, params, is_leaf=is_lns_weight)
        return MadamState(g2=g2, count=jnp.zeros((), jnp.int32))

    def update(grads, state: MadamState, params,
               key: Optional[jax.Array] = None, *, with_stats: bool = False,
               requant_fmt: Optional[LNSFormat] = None):
        count = state.count + 1
        # bias-corrected second-moment EMA (Algorithm 1 + init correction)
        bc = 1.0 - cfg.beta ** count.astype(jnp.float32)

        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=is_lns_weight)
        paths = [pth for pth, _ in flat]
        leaves_p = [leaf for _, leaf in flat]
        leaves_g = treedef.flatten_up_to(grads)
        leaves_v = treedef.flatten_up_to(state.g2)
        if cfg.stochastic:
            if key is None:
                raise ValueError("stochastic Q_U requires a PRNG key")
            keys = list(jax.random.split(key, len(leaves_p)))
        else:
            keys = [None] * len(leaves_p)

        stats = {} if with_stats else None
        new_p, new_v = [], []
        for pth, p, g, v, k in zip(paths, leaves_p, leaves_g, leaves_v, keys):
            g = g.astype(jnp.float32)
            if is_lns_weight(p):
                leaf_fmt = p.fmt or fmt
                leaf_stats = None
                if cfg.stochastic or isinstance(v, dict) or p.ndim < 2:
                    if with_stats:
                        from repro.kernels.madam_update import requant_spec
                        np_, nv, leaf_stats = _lns_leaf_reference(
                            p, g, v, k, bc,
                            requant=requant_spec(leaf_fmt, requant_fmt),
                            with_stats=True)
                    else:
                        np_, nv = _lns_leaf_reference(p, g, v, k, bc)
                else:
                    # fused kernel: one HBM pass over (word, grad, v) —
                    # with_stats folds the numerics epilogue into that pass
                    if with_stats:
                        pk, nv, leaf_stats = dispatch.madam_step(
                            p.packed, g, v, count, leaf_fmt, lr=cfg.lr,
                            beta=cfg.beta, eps=cfg.eps, with_stats=True,
                            requant_fmt=requant_fmt)
                    else:
                        pk, nv = dispatch.madam_step(
                            p.packed, g, v, count, leaf_fmt, lr=cfg.lr,
                            beta=cfg.beta, eps=cfg.eps)
                    np_ = p.replace(packed=pk)
                if with_stats:
                    leaf_stats["scale_log2"] = jnp.mean(
                        jnp.log2(p.scale.astype(jnp.float32)))
                    stats[path_name(pth)] = leaf_stats
                new_p.append(np_)
                new_v.append(nv)
            else:
                # fp Madam for norm gains / biases (paper's BN carve-out)
                v, vhat = _v_update(g, v)
                gstar = g * jax.lax.rsqrt(vhat / bc + cfg.eps)
                lr = cfg.fp_lr if cfg.fp_lr is not None else cfg.lr
                w = p * jnp.exp(-lr * jnp.sign(p) * gstar)
                # allow zero-crossing for fp leaves via an additive floor
                w = jnp.where(jnp.abs(p) < 1e-8, p - lr * gstar * 1e-8, w)
                new_p.append(jnp.clip(w, -cfg.fp_clip, cfg.fp_clip))
                new_v.append(v)

        out = (jax.tree_util.tree_unflatten(treedef, new_p),
               MadamState(g2=jax.tree_util.tree_unflatten(treedef, new_v),
                          count=count))
        return out + (stats,) if with_stats else out

    return init, update


def madam_fp(lr: float = 2.0 ** -7, beta: float = 0.999, clip: float = 10.0,
             eps: float = 1e-30):
    """Full-precision Madam (Eq. 9) — Bernstein et al.'s optimizer, the
    paper's pre-quantization baseline and the Fig.-7 comparison anchor."""

    def init(params) -> MadamState:
        return MadamState(g2=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state: MadamState, params, key=None):
        count = state.count + 1
        bc = 1.0 - beta ** count.astype(jnp.float32)

        def leaf(p, g, v):
            g = g.astype(jnp.float32)
            v = (1.0 - beta) * g * g + beta * v
            gstar = g * jax.lax.rsqrt(v / bc + eps)
            w = p.astype(jnp.float32) * jnp.exp(-lr * jnp.sign(p) * gstar)
            return jnp.clip(w, -clip, clip).astype(p.dtype), v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state.g2)
        out = [leaf(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_p, MadamState(g2=new_v, count=count)

    return init, update
