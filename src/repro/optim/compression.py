"""Distributed gradient compression (beyond-paper, §8 of DESIGN.md).

Lifts the paper's Q_G into the data-parallel collective: gradients are
LNS-encoded *before* the cross-replica reduction, cutting all-reduce bytes
4× vs fp32 (2× vs bf16). Error feedback (memory of the compression residual)
keeps convergence; signSGD-with-majority-vote (paper ref [12], same authors)
is the 1-bit extreme and doubles as a straggler/fault-tolerant reduction.

These run inside ``shard_map`` over the data axes; under plain ``pjit`` the
quantize-then-psum pattern still lowers to a quantized all-reduce.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, lns_quantize

__all__ = ["lns_compressed_psum", "sign_majority_psum", "error_feedback_update"]


def lns_compressed_psum(grads, axis_names, fmt: Optional[LNSFormat] = None,
                        residuals=None):
    """All-reduce a gradient pytree with LNS-quantized contributions.

    Each participant quantizes its local contribution onto the LNS grid
    (per-tensor scale) and the reduction sums the quantized values — the
    wire format is (sign, int8 code, one f32 scale). With ``residuals`` an
    error-feedback pytree is maintained: residual = local − quantized is
    added to the next step's contribution.

    Returns (reduced_grads, new_residuals).
    """
    fmt = fmt or LNSFormat(bits=8, gamma=8)

    def leaf(g, r):
        local = g if r is None else g + r.astype(g.dtype)
        q = lns_quantize(local, fmt, scale_axis=None)
        new_r = (local - q).astype(jnp.float32) if r is not None else None
        return jax.lax.psum(q, axis_names), new_r

    if residuals is None:
        reduced = jax.tree.map(lambda g: leaf(g, None)[0], grads)
        return reduced, None
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def sign_majority_psum(grads, axis_names):
    """signSGD with majority vote [12]: 1-bit compression, fault tolerant.

    Each worker contributes sign(g); the server step is sign(Σ signs). A
    worker sending garbage flips at most its own vote — the majority is
    robust to blind/byzantine stragglers (paper ref [12] Thm 2)."""

    def leaf(g):
        votes = jax.lax.psum(jnp.sign(g).astype(jnp.float32), axis_names)
        return jnp.sign(votes).astype(g.dtype)

    return jax.tree.map(leaf, grads)


def error_feedback_update(grads, residuals, fmt: LNSFormat):
    """Pure (no-collective) error-feedback compression step, for unit tests
    and for pre-compressing before a pjit-visible psum."""

    def leaf(g, r):
        local = g + r.astype(g.dtype)
        q = lns_quantize(local, fmt, scale_axis=None)
        return q, (local - q).astype(jnp.float32)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))
