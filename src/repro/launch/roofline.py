"""Three-term roofline analysis from a compiled dry-run artifact.

TPU v5e-like constants: 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. ``compiled.cost_analysis()`` supplies per-device
HLO FLOPs and bytes (post-SPMD, i.e. already divided across chips);
collective bytes are parsed from the partitioned HLO text by summing the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Terms (seconds per step, per chip — identical to the assignment's
``global / (chips x peak)`` since the per-device program is global/chips):

    T_compute    = flops_per_device / 197e12
    T_memory     = bytes_per_device / 819e9
    T_collective = collective_bytes_per_device / 50e9
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "CollectiveStats", "RooflineReport", "collective_bytes",
           "analyze", "model_flops"]

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s
ICI_BW = 50e9         # B/s/link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape tokens like  bf16[256,4096]{1,0}  or  f32[]  appearing in operand
# position inside a collective's argument list
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of collective ops in a (partitioned) HLO module.

    `-start` variants are counted; their paired `-done` is skipped so async
    collectives aren't double counted.
    """
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(1)
        # operand list: everything after the opcode's opening paren
        args = line[m.end():]
        # cut at the first top-level close paren
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        total = 0
        for sm in _SHAPE_RE.finditer(args):
            total += _shape_bytes(sm.group(1), sm.group(2))
        if total == 0:
            # fall back to the op's output shape (pre-opcode segment)
            pre = line[: m.start()]
            for sm in _SHAPE_RE.finditer(line[m.start():m.end()]):
                total += _shape_bytes(sm.group(1), sm.group(2))
            if total == 0:
                for sm in _SHAPE_RE.finditer(pre):
                    total += _shape_bytes(sm.group(1), sm.group(2))
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_global: float
    peak_bytes_per_device: Optional[float] = None
    collectives: Optional[Dict[str, int]] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs peak, at the modeled step time
        max(T_c, T_m, T_coll) — the §Perf score for this cell."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops_global / self.chips / t) / PEAK_FLOPS

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.collective_bytes_per_device,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_device": self.peak_bytes_per_device,
            "collectives": self.collectives,
        }


def model_flops(cfg, spec, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D inference; N = active
    params (MoE), D = tokens processed this step."""
    n = cfg.active_params_count()
    if kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n * tokens
    tokens = spec.global_batch * 1  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: Dict, hlo_text: str, mf: float,
            peak_bytes: Optional[float] = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=float(coll.total_bytes),
        t_compute=flops / PEAK_FLOPS,
        t_memory=byts / HBM_BW,
        t_collective=coll.total_bytes / ICI_BW,
        model_flops_global=mf,
        peak_bytes_per_device=peak_bytes,
        collectives={k: v for k, v in coll.bytes_by_kind.items() if v},
    )
