"""End-to-end training driver (runs the smoke-scale configs on CPU; the
same code path drives TPU pods — only the mesh and config names change).

Sets the XLA latency-hiding-scheduler flags that overlap collectives with
compute on real TPWs before jax initializes, builds the LNS-native train
step under the logical sharding rules, and runs the fault-tolerant
supervisor loop with async checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
import os

# Comm/compute overlap knobs for real TPU runs (latency-hiding scheduler +
# async collective fusion). Harmless no-ops on the CPU backend.
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true"
)
if os.environ.get("REPRO_TPU_FLAGS"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + TPU_PERF_FLAGS)

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_rules, get_smoke_config
from repro.core.quantizer import QuantConfig
from repro.distributed.params_sharding import batch_shardings
from repro.distributed.sharding import shard_ctx
from repro.launch.mesh import make_host_mesh
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM
from repro.training.loop import SupervisorConfig, run_supervised


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2.0 ** -7)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--format", default="lns8",
                    choices=["lns8", "fp8", "fp32"])
    ap.add_argument("--ckpt-dir", default="/tmp/lns_madam_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="collect per-layer numerics telemetry and export "
                         "a Chrome trace (train_step spans + numerics "
                         "counter tracks; opens in Perfetto) into DIR when "
                         "the run ends")
    ap.add_argument("--numerics-log", default=None, metavar="FILE",
                    help="structured jsonl step log (one line per step: "
                         "loss, wall time, per-layer LNS health)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace "
                         "(TensorBoard format) written to DIR")
    ap.add_argument("--kernel-stats", action="store_true",
                    help="per-(op, backend, bitwidth) kernel-time "
                         "attribution, printed after the run")
    ap.add_argument("--quiet", default=True,
                    type=lambda s: s.lower() not in ("0", "false", "no"),
                    metavar="BOOL",
                    help="--quiet=false prints a progress line every "
                         "--progress-every steps through the observer")
    ap.add_argument("--progress-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    qcfg = {"lns8": QuantConfig.lns_madam(), "fp8": QuantConfig.fp8(),
            "fp32": QuantConfig.full_precision()}[args.format]
    mcfg = MadamConfig(lr=args.lr)
    mesh = make_host_mesh(data=jax.device_count())
    rules = get_rules(args.arch)

    observer = None
    if args.trace_dir or args.numerics_log or not args.quiet:
        from repro.obs import NumericsObserver
        observer = NumericsObserver(log_path=args.numerics_log,
                                    quiet=args.quiet,
                                    progress_every=args.progress_every)
    if args.kernel_stats:
        from repro.obs import kernel_stats
        kernel_stats.enable()

    with shard_ctx(mesh, rules):
        state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
        n = sum(x.size for x in jax.tree.leaves(state.params))
        print(f"arch={cfg.name} params={n:,} format={args.format} "
              f"mesh={dict(mesh.shape)}")
        step_fn = jax.jit(build_train_step(
            cfg, qcfg, mcfg, accum_steps=args.accum_steps,
            numerics=observer is not None))
        data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)

        def put(b):
            b = jax.tree.map(jnp.asarray, b)
            sh = batch_shardings(b, mesh, rules)
            return jax.device_put(b, sh)

        def run():
            return run_supervised(
                step_fn, state, data, ckpt,
                SupervisorConfig(max_steps=args.steps,
                                 save_every=args.save_every),
                device_put_batch=put, observer=observer)

        t0 = time.monotonic()
        if args.jax_profile:
            from repro.obs.kernel_stats import profiler_trace
            with profiler_trace(args.jax_profile):
                report = run()
        else:
            report = run()
        dt = time.monotonic() - t0
        tok = args.steps * args.batch * args.seq
        print(f"done: {report.steps_done} steps in {dt:.1f}s "
              f"({tok / dt:.0f} tok/s) loss {report.losses[0]:.4f} -> "
              f"{report.losses[-1]:.4f}; recovered={report.failures_recovered} "
              f"stragglers={report.straggler_events}")
        if observer is not None:
            summary = observer.summary()
            worst = summary.get("worst_sat_frac")
            if worst is not None:
                print(f"numerics: worst saturation {worst:.4f} "
                      f"({summary['worst_sat_site']}), update qerr mean "
                      f"{summary.get('update.qerr_rel_mean', 0):.2e}")
            if args.trace_dir:
                print("trace:", observer.export(args.trace_dir,
                                                tag=cfg.name))
            observer.close()
        if args.kernel_stats:
            from repro.obs import kernel_stats
            for name, row in kernel_stats.get().items():
                print(f"  kernel {name}: calls={row['calls']} "
                      f"traces={row['traces']} time={row['time_s']:.4f}s")


if __name__ == "__main__":
    main()
