"""Serving CLI: a thin driver over the continuous-batching engine.

Offline (default): builds a mixed-length synthetic request trace,
initializes the model in the packed 8-bit LNS serving format, and drives
``repro.serving.Engine`` — variable-length requests are admitted into
freed decode slots mid-run, finished sequences release their KV rows, and
per-request TTFT / latency / tokens-per-second are reported alongside the
aggregate goodput.

  python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --slots 4 --prompt-len 32 --gen-len 32

Online (``--http HOST:PORT``): boots the asyncio gateway
(``repro.server``) over the same engine instead of replaying a trace —
OpenAI-style ``POST /v1/completions`` with per-request sampling and SSE
token streaming, ``DELETE /v1/requests/{id}`` mid-flight cancellation,
``GET /health`` / ``GET /metrics``. Ctrl-C shuts down cleanly (live
requests are aborted, their slots and KV pages released).

  python -m repro.launch.serve --arch smollm-135m --smoke \
      --http 127.0.0.1:8000
  curl -N localhost:8000/v1/completions -d \
      '{"prompt": [1,2,3], "max_tokens": 8, "stream": true}'
"""
import argparse
import asyncio

import jax

from repro.configs import get_config, get_rules, get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.distributed.sharding import shard_ctx
from repro.launch.mesh import make_host_mesh
from repro.optim.madam import MadamConfig
from repro.serving import Engine, max_trace_len, synthetic_trace
from repro.training import init_train_state


def _serve_http(engine, http: str, model: str, max_queue: int) -> None:
    """Run the online gateway until interrupted; clean shutdown aborts
    live requests so their slots and KV pages are released."""
    from repro.server.app import Gateway
    from repro.server.driver import EngineDriver

    host, _, port = http.rpartition(":")
    driver = EngineDriver(engine, max_inflight=max_queue).start()

    async def _run():
        gw = await Gateway(driver, host=host or "127.0.0.1",
                           port=int(port or 8000), model=model).start()
        h, p = gw.address
        print(f"gateway listening on http://{h}:{p}  "
              f"(slots={engine.num_slots} max_len={engine.max_len} "
              f"max_queue={max_queue})", flush=True)
        try:
            await gw.serve_forever()
        finally:
            await gw.stop()

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        print("shutting down: aborting live requests", flush=True)
    finally:
        driver.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (concurrent sequences)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt/gen lengths across the trace "
                         "(alias for --lengths uniform)")
    ap.add_argument("--lengths", default=None,
                    choices=("fixed", "uniform", "bimodal"),
                    help="trace length distribution (bimodal = the "
                         "serving bench's short-chat/long-doc mix)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load in requests/s (0 = all at t=0)")
    ap.add_argument("--serve-bits", type=int, default=8,
                    help="LNS weight bitwidth for serving")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV page size in tokens: switch the full-context "
                         "attention layers to the block-paged pool")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages per layer (default: dense-equivalent "
                         "slots * ceil(max_len / page_size))")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix page reuse")
    ap.add_argument("--alloc-policy", default="reserve",
                    choices=("reserve", "ondemand"),
                    help="paged-KV page claiming: 'reserve' takes the "
                         "worst case up front, 'ondemand' grows the block "
                         "table as decode proceeds and preempts by "
                         "recompute under pool pressure")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="self-speculative decoding: draft tokens per "
                         "fused draft+verify cycle (0 = off)")
    ap.add_argument("--draft-bitwidth", type=int, default=6,
                    help="wire bitwidth of the draft re-grid view "
                         "(8 = identity draft; 6/7 = coarser LNS grid)")
    ap.add_argument("--spec-autotune", action="store_true",
                    help="explore (draft bitwidth, k) arms from "
                         "accept-rate/throughput feedback")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="serve online over HTTP/SSE instead of replaying "
                         "a synthetic trace (port 0 = ephemeral)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="per-slot cache capacity (online mode; offline "
                         "derives it from the trace distribution)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission-control watermark: live requests "
                         "beyond this are refused with 429")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serving mesh shape, e.g. '2,2': the model axis "
                         "head-/column-shards weights, KV pools and the "
                         "paged-attend kernel; default is a (devices, 1) "
                         "mesh (single-device semantics on 1 device)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="enable request span tracing + the step "
                         "timeline; export Chrome trace-event JSON "
                         "(opens in Perfetto / chrome://tracing) into "
                         "DIR when the run ends")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace "
                         "(TensorBoard format) written to DIR")
    ap.add_argument("--kernel-stats", action="store_true",
                    help="per-(op, backend, bitwidth) kernel-time "
                         "attribution, printed after an offline run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(
        update_format=LNSFormat(bits=args.serve_bits, gamma=8))
    if args.mesh:
        try:
            data, model = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh expects 'DATA,MODEL', got {args.mesh!r}")
        mesh = make_host_mesh(data=data, model=model)
    else:
        mesh = make_host_mesh(data=jax.device_count())

    with shard_ctx(mesh, get_rules(args.arch)):
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, mcfg)
        bytes_w = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(state.params))
        print(f"arch={cfg.name} serve weights {bytes_w / 2**20:.1f} MiB "
              f"(packed {args.serve_bits}-bit LNS codes + scales)")

        lengths = args.lengths or ("uniform" if args.mixed else "fixed")
        max_len = args.max_len or max_trace_len(args.prompt_len,
                                                args.gen_len, lengths)
        observer = None
        if args.trace_dir:
            from repro.obs import EngineObserver
            observer = EngineObserver()
        if args.kernel_stats:
            from repro.obs import kernel_stats
            kernel_stats.enable()
        engine = Engine(cfg, qcfg, mcfg, state.params,
                        num_slots=args.slots, max_len=max_len,
                        page_size=args.page_size, num_pages=args.num_pages,
                        prefix_cache=not args.no_prefix_cache,
                        alloc_policy=args.alloc_policy,
                        speculate_k=args.speculate_k,
                        draft_bitwidth=args.draft_bitwidth,
                        spec_autotune=args.spec_autotune,
                        mesh=mesh if mesh.devices.size > 1 else None,
                        observer=observer,
                        checkpoint_id=f"{cfg.name}-seed{args.seed}-init")
        if args.http:
            try:
                _serve_http(engine, args.http, cfg.name, args.max_queue)
            finally:
                if observer is not None:
                    print("trace:", observer.export(args.trace_dir,
                                                    tag=cfg.name))
            return
        trace = synthetic_trace(cfg, requests=args.requests,
                                prompt_len=args.prompt_len,
                                gen_len=args.gen_len, lengths=lengths,
                                rate=args.rate, seed=args.seed)
        if args.jax_profile:
            from repro.obs.kernel_stats import profiler_trace
            with profiler_trace(args.jax_profile):
                agg = engine.run(trace)
        else:
            agg = engine.run(trace)

        print(f"slots={args.slots} requests={args.requests} "
              f"decode_steps={engine.decode_steps} "
              f"prefill_compiles={engine.prefill_compiles} "
              f"decode_compiles={engine.decode_compiles}")
        if engine.page_size:
            print(f"paged KV: page_size={engine.page_size} "
                  f"pages={engine.num_pages} "
                  f"alloc_policy={engine.alloc_policy} "
                  f"preemptions={engine.preemptions} "
                  f"prefix_hits={engine.prefix_hits} "
                  f"reused_tokens={engine.prefix_reused_tokens}")
        if engine.spec is not None:
            print(f"speculative: cycles={engine.spec_cycles} "
                  f"k={engine._spec_arm[1]} "
                  f"draft_bits={engine._spec_arm[0]} "
                  f"accept_rate={engine.spec_accept_rate:.3f} "
                  f"emitted={engine.spec_emitted} "
                  f"fallbacks={engine.spec_fallbacks} "
                  f"pages_trimmed={engine.spec_pages_trimmed}")
        print(f"completed {int(agg['completed'])} requests in "
              f"{agg['wall_s']:.2f}s: {agg['tokens_per_s']:.1f} tok/s, "
              f"ttft mean {agg['ttft_mean_s']:.3f}s "
              f"p95 {agg['ttft_p95_s']:.3f}s, "
              f"latency p50 {agg['latency_p50_s']:.3f}s "
              f"p95 {agg['latency_p95_s']:.3f}s")
        for rs in sorted(engine.finished, key=lambda r: r.request.rid)[:4]:
            head = rs.generated[:8]
            print(f"  req {rs.request.rid}: prompt {rs.request.prompt_len} "
                  f"-> {len(rs.generated)} new tokens, sample {head}")
        if observer is not None:
            bd = observer.time_breakdown(agg["wall_s"])
            print(f"time breakdown: prefill {bd.get('prefill_share', 0):.1%} "
                  f"decode {bd.get('decode_share', 0):.1%} "
                  f"spec {bd.get('spec_share', 0):.1%} "
                  f"host {bd.get('host_share', 0):.1%}")
            print("trace:", observer.export(args.trace_dir, tag=cfg.name))
        if args.kernel_stats:
            from repro.obs import kernel_stats
            for name, row in kernel_stats.get().items():
                print(f"  kernel {name}: calls={row['calls']} "
                      f"traces={row['traces']} time={row['time_s']:.4f}s")


if __name__ == "__main__":
    main()
