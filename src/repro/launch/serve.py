"""Batched serving driver: continuous-batching prefill + decode loop.

A minimal production-shaped server: requests arrive with prompts of varying
length, are left-aligned into a fixed batch, prefilled once, then decoded
step by step with the packed-LNS (8-bit) weight format. Reports
tokens/second and per-phase timings.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --prompt-len 32 --gen-len 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_rules, get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.distributed.sharding import shard_ctx
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.training import (build_decode_step, build_prefill_step,
                            init_train_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--serve-bits", type=int, default=8,
                    help="LNS weight bitwidth for serving")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(
        update_format=LNSFormat(bits=args.serve_bits, gamma=8))
    mesh = make_host_mesh(data=jax.device_count())

    with shard_ctx(mesh, get_rules(args.arch)):
        state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
        params = state.params
        bytes_w = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} serve weights {bytes_w / 2**20:.1f} MiB "
              f"(packed {args.serve_bits}-bit LNS codes + scales)")

        B = args.requests
        max_len = args.prompt_len + args.gen_len
        rng = np.random.default_rng(0)
        tshape = ((B, args.prompt_len, cfg.num_codebooks)
                  if cfg.num_codebooks else (B, args.prompt_len))
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, tshape, dtype=np.int32))

        prefill = jax.jit(build_prefill_step(cfg, qcfg, mcfg))
        decode = jax.jit(build_decode_step(cfg, qcfg, mcfg))

        t0 = time.monotonic()
        logits = prefill(params, {"tokens": prompts})
        # replay the prompt through the decode path to build the cache
        caches = init_caches(B, max_len, cfg)
        logits, caches = decode(params, caches, {"tokens": prompts},
                                jnp.asarray(0, jnp.int32))
        jax.block_until_ready(logits)
        t_prefill = time.monotonic() - t0

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.num_codebooks:
            tok = tok.reshape(B, 1, cfg.num_codebooks)
        else:
            tok = tok.reshape(B, 1)
        generated = [tok]
        t0 = time.monotonic()
        for i in range(args.gen_len - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, caches = decode(params, caches, {"tokens": tok}, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = tok.reshape((B, 1, cfg.num_codebooks)
                              if cfg.num_codebooks else (B, 1))
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.monotonic() - t0
        n_tok = B * (args.gen_len - 1)
        print(f"prefill {B}x{args.prompt_len} in {t_prefill:.2f}s; "
              f"decode {n_tok} tokens in {t_decode:.2f}s "
              f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
        out = jnp.concatenate(generated, axis=1)
        print("sample:", np.asarray(out)[0, :10].tolist())


if __name__ == "__main__":
    main()
