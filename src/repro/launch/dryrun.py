import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count at first init, and the production meshes need 512 placeholder host
devices. Smoke tests and benchmarks never import this module, so they see
the real single CPU device.

For each cell the step function (train / prefill / decode per the shape's
kind) is jitted with explicit in_shardings from the logical rules, lowered
against ShapeDtypeStruct stand-ins (no allocation), compiled, and the
compiled artifact is mined for:

  * ``memory_analysis()``  — per-chip bytes: proves the cell fits (or not)
  * ``cost_analysis()``    — per-chip HLO FLOPs / bytes for §Roofline
  * partitioned HLO text   — collective operand bytes for §Roofline

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out experiments/dryrun
"""

import argparse
import json
import math
import subprocess
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config, get_rules, input_specs
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.distributed.params_sharding import (batch_shardings,
                                               cache_logical_axes,
                                               params_logical_axes,
                                               opt_logical_axes,
                                               tree_shardings)
from repro.distributed.sharding import shard_ctx
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models.common import ArchConfig
from repro.models.model import init_caches
from repro.optim.madam import MadamConfig
from repro.training.steps import (build_decode_step, build_prefill_step,
                                  build_train_step, init_train_state)

SERVE_FMT = LNSFormat(bits=8, gamma=8)  # inference weights: packed 8-bit LNS


def _mesh_batch_div(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _memory_dict(compiled) -> Dict[str, float]:
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = float(v)
    out["peak_bytes"] = (out.get("argument_size_in_bytes", 0)
                         + out.get("output_size_in_bytes", 0)
                         + out.get("temp_size_in_bytes", 0)
                         - out.get("alias_size_in_bytes", 0))
    return out


def _lower_compile(cfg, spec, mesh, rules, *, accum_steps=1, scan_unroll,
                   save_hlo=None, remat=True):
    """Lower + compile one step function; return (cost, mem, hlo, times)."""
    t0 = time.monotonic()
    with shard_ctx(mesh, rules):
        batch_specs = input_specs(cfg, spec.name)
        batch_sh = batch_shardings(batch_specs, mesh, rules)
        qcfg = QuantConfig.lns_madam()

        if spec.kind == "train":
            mcfg = MadamConfig(factored=(cfg.family == "moe"))
            state_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, mcfg))
            st_axes = type(state_shape)(
                params=params_logical_axes(state_shape.params),
                opt=opt_logical_axes(state_shape.params, state_shape.opt),
                step=(),
            )
            state_sh = tree_shardings(st_axes, mesh, rules)
            step = build_train_step(cfg, qcfg, mcfg, accum_steps=accum_steps,
                                    scan_unroll=scan_unroll, remat=remat)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_specs)
        elif spec.kind == "prefill":
            mcfg = MadamConfig(update_format=SERVE_FMT)
            state_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, mcfg))
            params_sh = tree_shardings(
                params_logical_axes(state_shape.params), mesh, rules)
            step = build_prefill_step(cfg, qcfg, mcfg,
                                      scan_unroll=scan_unroll)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(state_shape.params, batch_specs)
        else:  # decode
            mcfg = MadamConfig(update_format=SERVE_FMT)
            state_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, mcfg))
            params_sh = tree_shardings(
                params_logical_axes(state_shape.params), mesh, rules)
            cache_shape = jax.eval_shape(
                lambda: init_caches(spec.global_batch, spec.seq_len, cfg))
            cache_sh = tree_shardings(
                cache_logical_axes(cache_shape), mesh, rules)
            step = build_decode_step(cfg, qcfg, mcfg,
                                     scan_unroll=scan_unroll)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step, in_shardings=(
                params_sh, cache_sh, batch_sh, None), donate_argnums=(1,))
            lowered = jitted.lower(state_shape.params, cache_shape,
                                   batch_specs, pos_spec)

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

        cost = dict(compiled.cost_analysis())
        mem = _memory_dict(compiled)
        hlo = compiled.as_text()
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    return cost, mem, hlo, (t_lower, t_compile)


def _with_periods(cfg: ArchConfig, n_periods: int) -> ArchConfig:
    import dataclasses
    prefix, _, period = cfg.layer_pattern()
    return dataclasses.replace(
        cfg, num_layers=len(prefix) + n_periods * len(period))


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             rules_extra: Optional[Dict] = None,
             accum_steps: int = 1,
             save_hlo: Optional[str] = None,
             cost_extrapolate: bool = True,
             cfg_overrides: Optional[Dict] = None,
             remat: bool = True,
             tag: str = "") -> Dict:
    """One dry-run cell, two passes:

    A. full depth, **rolled** scan — the compile-success + memory proof
       (this is the production program; fast to partition even at 61 layers)
    B. reduced-depth **unrolled** lowers at two period counts n1 < n2 —
       XLA's cost analysis counts a while body once, so per-period FLOPs /
       bytes / collective bytes come from the exact linear fit
       C(n) = C(n1) + (n - n1)·(C(n2) - C(n1))/(n2 - n1), evaluated at the
       full depth. Costs are exactly linear in identical periods, so this
       is lossless; validated against a full unroll in the tests.
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    spec = SHAPES[shape]
    rules = get_rules(arch)
    if rules_extra:
        rules.update(rules_extra)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    if spec.global_batch % _mesh_batch_div(mesh) != 0:
        # batch-1 long-context: the DP axes can't shard batch — spread the
        # KV cache sequence over the whole mesh instead (split-KV decode)
        rules["batch"] = None
        rules["kv_seq"] = ("data", "model")

    # ---- pass A: full model, rolled (memory + compile success)
    cost_a, mem, hlo_a, (t_lower, t_compile) = _lower_compile(
        cfg, spec, mesh, rules, accum_steps=accum_steps, scan_unroll=1,
        save_hlo=save_hlo, remat=remat)

    prefix, n_full, period = cfg.layer_pattern()
    cost = dict(cost_a)
    coll_by_kind = dict(roofline.collective_bytes(hlo_a).bytes_by_kind)
    extrapolated = False
    if cost_extrapolate and n_full > 2:
        n2 = max(2, min(4, 16 // max(len(period), 1)))
        n1 = max(1, n2 // 2)
        if n2 > n1 and n_full > n2:
            c1, _, h1, _ = _lower_compile(_with_periods(cfg, n1), spec, mesh,
                                          rules, accum_steps=accum_steps,
                                          scan_unroll=True, remat=remat)
            c2, _, h2, _ = _lower_compile(_with_periods(cfg, n2), spec, mesh,
                                          rules, accum_steps=accum_steps,
                                          scan_unroll=True, remat=remat)
            for k in ("flops", "bytes accessed"):
                per = (c2.get(k, 0.0) - c1.get(k, 0.0)) / (n2 - n1)
                cost[k] = c1.get(k, 0.0) + (n_full - n1) * per
            b1 = roofline.collective_bytes(h1).bytes_by_kind
            b2 = roofline.collective_bytes(h2).bytes_by_kind
            coll_by_kind = {}
            for k in b1:
                per = (b2[k] - b1[k]) / (n2 - n1)
                coll_by_kind[k] = max(0.0, b1[k] + (n_full - n1) * per)
            extrapolated = True

    mf = roofline.model_flops(cfg, spec, spec.kind)
    coll_total = sum(coll_by_kind.values())
    rep = roofline.RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(coll_total),
        t_compute=float(cost.get("flops", 0.0)) / roofline.PEAK_FLOPS,
        t_memory=float(cost.get("bytes accessed", 0.0)) / roofline.HBM_BW,
        t_collective=float(coll_total) / roofline.ICI_BW,
        model_flops_global=mf,
        peak_bytes_per_device=mem.get("peak_bytes"),
        collectives={k: int(v) for k, v in coll_by_kind.items() if v},
    )

    row = rep.row()
    row.update({
        "kind": spec.kind,
        "memory": mem,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo_a),
        "cost_extrapolated": extrapolated,
        "params_total": cfg.params_count(),
        "params_active": cfg.active_params_count(),
        "tag": tag,
    })
    return row


def _print_row(row: Dict):
    mem_gb = (row["memory"].get("peak_bytes") or 0) / 2**30
    print(f"{row['arch']:>18s} {row['shape']:>11s} mesh={row['mesh']:>8s} "
          f"T_comp={row['t_compute_s']:.4f}s T_mem={row['t_memory_s']:.4f}s "
          f"T_coll={row['t_collective_s']:.4f}s dom={row['dominant']:<10s} "
          f"useful={row['useful_fraction']:.2f} "
          f"roofline={row['roofline_fraction']:.3f} peak={mem_gb:.1f}GiB "
          f"(compile {row['compile_s']:.0f}s)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--rules", default=None,
                    help="JSON dict of logical-rule overrides")
    ap.add_argument("--cfg", default=None,
                    help="JSON dict of ArchConfig field overrides")
    ap.add_argument("--accum", dest="accum_steps2", type=int, default=None)
    ap.add_argument("--tag", default="", help="label recorded in the row")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    if args.all:
        ok = fail = 0
        for arch, shape in cells():
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if args.multi_pod:
                cmd.append("--multi-pod")
            if args.no_extrapolate:
                cmd.append("--no-extrapolate")
            if args.out:
                cmd += ["--out", args.out]
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                rc = r.returncode
            except subprocess.TimeoutExpired:
                rc = -1
                print(f"TIMEOUT {arch} {shape}", flush=True)
            ok += rc == 0
            fail += rc != 0
        print(f"dry-run complete: {ok} ok, {fail} failed")
        sys.exit(1 if fail else 0)

    rules_extra = json.loads(args.rules) if args.rules else None
    if rules_extra:
        rules_extra = {k: tuple(v) if isinstance(v, list) else v
                       for k, v in rules_extra.items()}
    cfg_overrides = json.loads(args.cfg) if args.cfg else None
    row = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   accum_steps=args.accum_steps, save_hlo=args.save_hlo,
                   cost_extrapolate=not args.no_extrapolate,
                   rules_extra=rules_extra, cfg_overrides=cfg_overrides,
                   remat=not args.no_remat, tag=args.tag)
    _print_row(row)
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
