"""Production meshes.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod adds an outer
    2-pod data-parallel axis (2x16x16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / smoke runs).

    Raises when the requested ``(data, model)`` shape asks for more devices
    than the platform exposes — a mesh test that silently collapsed to
    ``(n, 1)`` would pass vacuously on one device, which is exactly what CI
    mesh legs must not do. Start with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake devices.
    """
    n = jax.device_count()
    if data * model > n:
        raise ValueError(
            f"make_host_mesh: requested mesh (data={data}, model={model}) "
            f"needs {data * model} devices but only {n} are available; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{data * model} (before importing jax) or shrink the mesh")
    return jax.make_mesh((data, model), ("data", "model"))
