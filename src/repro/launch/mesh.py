"""Production meshes.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod adds an outer
    2-pod data-parallel axis (2x16x16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / smoke runs)."""
    n = jax.device_count()
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
