"""Render dry-run JSONL results as the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun/singlepod.jsonl
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.1f}"


def load(path):
    rows = []
    with open(path) as f:
        for line in f:
            if line.strip():
                rows.append(json.loads(line))
    # keep the last entry per (arch, shape, mesh)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def table(rows):
    out = []
    out.append("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | "
               "dominant | useful | roofline | peak GiB/chip | note |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        mem = r.get("memory", {}).get("peak_bytes")
        note = ""
        if mem and mem > 16 * 2**30:
            note = "exceeds v5e 16 GiB"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {fmt_bytes(mem)} | {note} |")
    return "\n".join(out)


def main():
    rows = load(sys.argv[1])
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    print(table(rows))
    print(f"\n{len(rows)} cells.")


if __name__ == "__main__":
    main()
