"""gemma3-12b — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*-pt] 48L d_model=3840 16H (GQA kv=8, head_dim=256)
d_ff=15360 vocab=262144; sliding window 1024 on local layers; qk-norm;
rope theta 10k local / 1M global; embeddings scaled by sqrt(d).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    local_global_ratio=5,
    sliding_window=1024,
    qk_norm=True,
    rope_theta=1e4,
    rope_theta_global=1e6,
    embed_scale=True,
    act_fn="gelu",
)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense", num_layers=6, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=160, vocab_size=512,
    local_global_ratio=5, sliding_window=8, qk_norm=True,
    rope_theta_global=1e6, embed_scale=True, act_fn="gelu", dtype="float32",
)

RULES = {}
