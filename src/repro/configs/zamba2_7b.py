"""zamba2-7b — Mamba2 trunk + one shared attention block (hybrid).

[arXiv:2411.15242] 81L d_model=3584 32H (kv=32, head_dim=112) d_ff=14336
vocab=32000, ssm_state=64; the shared transformer block recurs every 6
layers with a per-occurrence LoRA on its fused QKV projection.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    shared_block_lora_rank=128,
    ssm_chunk=64,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid", num_layers=6, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=512,
    ssm_state_dim=16, ssm_head_dim=16, shared_attn_every=3,
    shared_block_lora_rank=8, ssm_chunk=8, dtype="float32",
)

RULES = {}
