"""granite-8b — llama-architecture code model, tied embeddings.

[arXiv:2405.04324] 36L d_model=4096 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=49152.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="granite-smoke", family="dense", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=1, head_dim=16, d_ff=160, vocab_size=512,
    tie_embeddings=True, dtype="float32",
)

RULES = {}
