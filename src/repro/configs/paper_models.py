"""Paper-benchmark model shapes (not part of the assigned pool).

``bert_base`` / ``bert_large`` shaped configs back the paper's Table-4/8
energy+accuracy rows (MAC counts / CPU-scale trend runs); ``tiny_lm`` is the
few-M-parameter LM used by the accuracy-trend benchmarks (Tables 4-6,
Fig. 7) that actually *trains* on CPU in this container.
"""
from repro.models.common import ArchConfig

BERT_BASE = ArchConfig(
    name="bert_base", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=30522,
    mlp_gated=False, act_fn="gelu",
)

BERT_LARGE = ArchConfig(
    name="bert_large", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=30522,
    mlp_gated=False, act_fn="gelu",
)

TINY_LM = ArchConfig(
    name="tiny_lm", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
    tie_embeddings=True, dtype="float32",
)
