"""smollm-135m — small llama-architecture model.

[hf:HuggingFaceTB/SmolLM-135M] 30L d_model=576 9H (GQA kv=3, head_dim=64)
d_ff=1536 vocab=49152, tied embeddings. 9 heads -> sequence-parallel
attention on a 16-way model axis.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="smollm-smoke", family="dense", num_layers=3, d_model=48,
    num_heads=3, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    tie_embeddings=True, dtype="float32",
)

# §Perf-adopted config: a 135M model has no business being 16-way tensor
# parallel — pure data parallelism over the whole mesh drops the collective
# term 132x and lifts the roofline fraction 3.4x (see EXPERIMENTS.md §Perf).
RULES = {
    "batch": ("pod", "data", "model"),
    "mlp": None, "heads": None, "qkv_out": None, "vocab": None,
    "act_ff": None, "act_heads": None, "seq_shard": None,
    # batch occupies the whole mesh, so the decode cache's split-KV axis
    # must stay unsharded or its PartitionSpec double-books "model"
    "kv_seq": None,
}
