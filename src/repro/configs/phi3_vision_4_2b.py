"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct] 32L d_model=3072 32H (kv=32,
head_dim=96) d_ff=8192 vocab=32064. The vision tower is a stub providing
576 precomputed patch embeddings per image (assignment: backbone only).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="phi3v-smoke", family="vlm", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=512,
    num_patches=8, dtype="float32",
)

RULES = {}
