"""rwkv6-1.6b — Finch, attention-free, data-dependent decay.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536; 64-wide WKV heads.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # d_model / ssm_head_dim (WKV heads)
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    ssm_head_dim=64,
    rwkv_chunk=16,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=512,
    ssm_head_dim=16, rwkv_chunk=8, dtype="float32",
)

RULES = {}
