"""musicgen-medium — decoder-only over EnCodec RVQ tokens.

[arXiv:2306.05284] 48L d_model=1536 24H (kv=24, head_dim=64) d_ff=6144
vocab=2048 per codebook, 4 codebooks with the delay interleaving pattern
(applied by the data-pipeline stub). Non-gated GELU FFN. 24 heads ->
sequence-parallel attention on a 16-way model axis.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    mlp_gated=False,
    act_fn="gelu",
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="musicgen-smoke", family="audio", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=64,
    num_codebooks=4, mlp_gated=False, act_fn="gelu", dtype="float32",
)

RULES = {}
