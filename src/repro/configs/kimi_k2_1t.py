"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[arXiv: Kimi K2] 61L d_model=7168 64H (GQA kv=8, head_dim=128)
expert d_ff=2048 vocab=163840, 384 routed experts top-8 + 1 shared,
first layer dense. ~1.03T total / ~32B active parameters.

Scale notes (DESIGN.md §8): expert tensors shard over experts->model AND
d_ff->data (FSDP) so packed 16-bit LNS codes come to ~8 GB/chip on the
single-pod mesh; the second moment is Adafactor-factored (beyond-paper
scaling feature, see optim.madam factored mode).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,             # dense-layer / shared-expert width (assignment)
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    num_dense_layers=1,
    moe_dispatch="sort",
    rope_theta=5e4,
)

SMOKE = ArchConfig(
    name="kimi-smoke", family="moe", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512,
    num_experts=8, experts_per_token=2, num_shared_experts=1, moe_d_ff=96,
    num_dense_layers=1, moe_dispatch="sort", dtype="float32",
)

RULES = {"moe_ff": "data"}  # FSDP the expert d_ff axis
