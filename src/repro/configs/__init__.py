"""Architecture registry + input-shape sets + dry-run input specs.

``get_config(arch)`` / ``get_smoke_config(arch)`` / ``get_rules(arch)``
resolve the ten assigned architectures; ``SHAPES`` holds the four assigned
input-shape sets; ``input_specs(cfg, shape)`` builds the ShapeDtypeStruct
stand-ins the dry-run lowers against (weak-type-correct, shardable, no
device allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config", "get_rules",
           "input_specs", "cells", "runs_shape"]

# arch id -> module name
ARCHS: Dict[str, str] = {
    "rwkv6-1.6b": "rwkv6_1_6b",
    "gemma3-12b": "gemma3_12b",
    "qwen2.5-32b": "qwen2_5_32b",
    "granite-8b": "granite_8b",
    "smollm-135m": "smollm_135m",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "zamba2-7b": "zamba2_7b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "musicgen-medium": "musicgen_medium",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; one of {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _module(arch).SMOKE


def get_rules(arch: str) -> Dict:
    return dict(getattr(_module(arch), "RULES", {}))


def runs_shape(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape == "long_500k":
        return cfg.is_subquadratic
    return True


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped long_500k cells optional."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if runs_shape(cfg, shape) or include_skipped:
                out.append((arch, shape))
    return out


def input_specs(cfg: ArchConfig, shape: str | ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the step function's batch argument.

    train:   tokens+labels over the full sequence
    prefill: tokens over the full sequence
    decode:  one new token (the KV cache of ``seq_len`` is built separately
             by ``launch.dryrun``; ``seq_len`` here sizes that cache)
    """
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    tok_shape = (B, S)
    if cfg.num_codebooks:
        tok_shape = (B, S, cfg.num_codebooks)

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if spec.kind == "decode":
        dec_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1)
        out["tokens"] = jax.ShapeDtypeStruct(dec_shape, i32)
        return out

    if cfg.num_patches:  # phi3v: patches + text fill the sequence budget
        s_text = S - cfg.num_patches
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
        out["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        if spec.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return out

    out["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
    if spec.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct(tok_shape, i32)
    return out
