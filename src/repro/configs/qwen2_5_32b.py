"""qwen2.5-32b — GQA with QKV bias.

[hf:Qwen/Qwen2.5-*] 64L d_model=5120 40H (GQA kv=8, head_dim=128)
d_ff=27648 vocab=152064. 40 heads don't divide a 16-way model axis, so
attention runs sequence-parallel (DESIGN.md §6).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2.5-smoke", family="dense", num_layers=3, d_model=64,
    num_heads=5, num_kv_heads=1, head_dim=16, d_ff=160, vocab_size=512,
    qkv_bias=True, rope_theta=1e6, dtype="float32",
)

RULES = {}
