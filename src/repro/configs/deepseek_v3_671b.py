"""deepseek-v3-671b — MLA + 256-expert MoE + multi-token prediction.

[arXiv:2412.19437] 61L d_model=7168 128H, MLA (q_lora=1536, kv_lora=512,
nope=128, rope=64, v=128), expert d_ff=2048 vocab=129280, 1 shared + 256
routed top-8, first 3 layers dense (d_ff=18432 — hf config), MTP depth 1.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,      # MLA: effectively MHA over latent cache
    head_dim=128,
    d_ff=18432,            # dense-layer width (first 3 layers)
    vocab_size=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    num_dense_layers=3,
    mtp_depth=1,
    moe_dispatch="sort",
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="deepseek-smoke", family="moe", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=192, vocab_size=512,
    use_mla=True, q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, num_experts=8, experts_per_token=2,
    num_shared_experts=1, moe_d_ff=96, num_dense_layers=1, mtp_depth=1,
    moe_dispatch="sort", dtype="float32",
)

RULES = {"moe_ff": "data"}
