"""Multi-base logarithmic number system (LNS) — paper §2.

A value is represented as ``sign * s * 2**(-e/gamma)`` where

* ``e`` is an unsigned integer exponent code in ``[0, 2**(bits-1) - 1]``,
* ``gamma = 2**b`` is the *base factor* (the paper's multi-base knob),
* ``s`` is a power-of-two scale shared by a group of numbers (per tensor or
  per channel), chosen to match the group's absmax (paper §3).

The paper writes the representation as ``2**(x~/gamma)`` with dynamic range
``(0, (2**(B-1)-1)/gamma)``; because every value is pre-scaled so that
``|x|/s <= 1``, the stored integer is the magnitude of a *negative* exponent.
We store exactly that magnitude (e == 0 is the largest representable value,
``e == e_max`` the smallest).

Everything here is pure jnp and shape-polymorphic; the Pallas kernels in
``repro.kernels`` implement the same semantics and are tested against these
functions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LNSFormat",
    "LNSWeight",
    "is_lns_weight",
    "pow2_scale",
    "compute_scale",
    "lns_encode",
    "lns_decode",
    "lns_quantize",
    "lns_pack",
    "lns_unpack",
    "lns_word_dtype",
    "lns_decode_packed",
    "lns_requant_packed",
    "lns_weight_encode",
    "quantization_gap",
]


@dataclasses.dataclass(frozen=True)
class LNSFormat:
    """A multi-base LNS format (paper §2.1).

    Attributes:
      bits: total bitwidth B (1 sign bit + (B-1) exponent bits).
      gamma: base factor, must be a power of two. The representable
        magnitudes relative to the scale are ``2**(-e/gamma)`` for integer
        ``e in [0, 2**(bits-1)-1]``.
      stochastic: use stochastic rounding for the exponent (theory mode /
        Q_U option). Deterministic round-to-nearest otherwise (deployed path).
      flush_zero: decode the largest exponent code to exactly 0. Off by
        default (the hardware datapath has no zero flag).
    """

    bits: int = 8
    gamma: int = 8
    stochastic: bool = False
    flush_zero: bool = False

    def __post_init__(self):
        if self.bits < 2 or self.bits > 32:
            raise ValueError(f"bits must be in [2,32], got {self.bits}")
        if self.gamma < 1 or (self.gamma & (self.gamma - 1)) != 0:
            raise ValueError(f"gamma must be a power of two, got {self.gamma}")

    @property
    def exponent_bits(self) -> int:
        return self.bits - 1

    @property
    def max_code(self) -> int:
        """Largest exponent code 2**(B-1) - 1 (paper's clamp ceiling)."""
        return (1 << (self.bits - 1)) - 1

    @property
    def dynamic_range(self) -> float:
        """The paper's (0, (2**(B-1)-1)/gamma) exponent range width."""
        return self.max_code / self.gamma

    @property
    def code_dtype(self):
        return jnp.int8 if self.bits <= 8 else (jnp.int16 if self.bits <= 16 else jnp.int32)

    def with_bits(self, bits: int, keep_range: bool = True) -> "LNSFormat":
        """Derive a format at a different bitwidth.

        With ``keep_range`` the base factor scales as gamma' = gamma *
        2**(bits-B) so the dynamic range (0, max_code/gamma) is preserved —
        this is exactly the paper's §6.1.1 prescription for widening Q_U.
        """
        gamma = self.gamma * (1 << max(bits - self.bits, 0)) if keep_range else self.gamma
        if keep_range and bits < self.bits:
            gamma = max(1, self.gamma >> (self.bits - bits))
        return dataclasses.replace(self, bits=bits, gamma=gamma)


def pow2_scale(absmax: jax.Array) -> jax.Array:
    """Snap a positive scale to the next power of two (>= absmax).

    Power-of-two scales keep Q_log a pure shift in the exponent domain and
    match the hardware's scale-by-shift post-processing unit.
    """
    absmax = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny)
    return jnp.exp2(jnp.ceil(jnp.log2(absmax.astype(jnp.float32))))


def compute_scale(x: jax.Array, axis=None) -> jax.Array:
    """Absmax scale, per tensor (axis=None) or per channel, snapped to 2**k.

    ``axis`` is the channel axis (or tuple of axes) that KEEPS resolution
    (the reduction runs over all other axes), matching the paper's
    per-channel / per-feature scaling. The result broadcasts against ``x``.
    """
    xf = jnp.abs(x.astype(jnp.float32))
    if axis is None:
        amax = jnp.max(xf)
    else:
        keep = {a % x.ndim for a in ((axis,) if isinstance(axis, int) else axis)}
        reduce_axes = tuple(i for i in range(x.ndim) if i not in keep)
        amax = jnp.max(xf, axis=reduce_axes, keepdims=True)
    return pow2_scale(amax)


def _round(x: jax.Array, stochastic: bool, key: Optional[jax.Array]) -> jax.Array:
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        floor = jnp.floor(x)
        p = jax.random.uniform(key, x.shape, dtype=x.dtype)
        return floor + (p <= (x - floor)).astype(x.dtype)
    # round-to-nearest, ties away from zero (cheap in HW; jnp.round is
    # ties-to-even — the tie set has measure ~0 for log2 outputs, but we fix
    # the convention so kernels and oracle agree bit-exactly).
    return jnp.floor(x + 0.5)


def lns_encode(
    x: jax.Array,
    fmt: LNSFormat,
    scale: jax.Array,
    key: Optional[jax.Array] = None,
):
    """Encode real values into (sign, exponent-code) LNS pairs.

    Returns ``(sign, code)`` with ``sign in {-1, +1}`` (int8) and
    ``code = clamp(round(-log2(|x|/s) * gamma), 0, max_code)`` stored in the
    narrowest integer dtype that fits.
    """
    xf = x.astype(jnp.float32)
    sign = jnp.where(xf < 0, -1, 1).astype(jnp.int8)
    mag = jnp.abs(xf) / scale
    # |x| == 0 -> log2 = -inf -> e = +inf -> clamps to max_code (smallest
    # representable magnitude), reproducing the zero-flag-free hardware.
    e = -jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)) * fmt.gamma
    e = _round(e, fmt.stochastic, key)
    e = jnp.clip(e, 0, fmt.max_code)
    return sign, e.astype(fmt.code_dtype)


def lns_decode(
    sign: jax.Array,
    code: jax.Array,
    fmt: LNSFormat,
    scale: jax.Array,
    dtype=jnp.float32,
) -> jax.Array:
    """Decode (sign, code) LNS pairs back to real values."""
    mag = jnp.exp2(-code.astype(jnp.float32) / fmt.gamma)
    if fmt.flush_zero:
        mag = jnp.where(code == fmt.max_code, 0.0, mag)
    return (sign.astype(jnp.float32) * mag * scale).astype(dtype)


def lns_quantize(
    x: jax.Array,
    fmt: LNSFormat,
    scale_axis: Optional[int] = None,
    scale: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """The paper's Q_log (Eq. 3): fake-quantize ``x`` onto the LNS grid.

    Encode + decode in one call; the returned array has ``x.dtype`` and lies
    exactly on the representable grid ``{±s·2^(-e/γ)}``.
    """
    if scale is None:
        scale = compute_scale(x, axis=scale_axis)
    sign, code = lns_encode(x, fmt, scale, key=key)
    return lns_decode(sign, code, fmt, scale, dtype=x.dtype)


def lns_word_dtype(fmt: LNSFormat):
    """Narrowest unsigned container for one packed ``fmt.bits``-bit word."""
    return jnp.uint8 if fmt.bits <= 8 else (
        jnp.uint16 if fmt.bits <= 16 else jnp.uint32)


def lns_pack(sign: jax.Array, code: jax.Array, fmt: LNSFormat) -> jax.Array:
    """Pack (sign, code) into the hardware wire format: one unsigned word of
    ``fmt.bits`` bits, MSB = sign, low ``bits-1`` bits = exponent code.

    This is the storage dtype the TPU path reads from HBM — B=8 LNS weights
    are exactly 1 byte/element (the 2x bandwidth win vs bf16).
    """
    neg = (sign.astype(jnp.int32) < 0).astype(jnp.uint32)
    word = (neg << (fmt.bits - 1)) | code.astype(jnp.uint32)
    return word.astype(lns_word_dtype(fmt))


def lns_unpack(packed: jax.Array, fmt: LNSFormat):
    """Unpack wire words into (sign in {-1,+1} int8, code)."""
    w = packed.astype(jnp.uint32)
    sign_bit = (w >> (fmt.bits - 1)) & 1
    code = w & jnp.uint32(fmt.max_code)
    sign = (1 - 2 * sign_bit.astype(jnp.int32)).astype(jnp.int8)
    return sign, code.astype(fmt.code_dtype)


def lns_decode_packed(word: jax.Array, fmt: LNSFormat,
                      dtype=jnp.float32) -> jax.Array:
    """Decode packed wire words to *unscaled* reals ``±2^(-code/γ)``.

    The single definition of the packed-word decode: the Pallas qmatmul
    kernel prologue, the jnp reference backend, and the kernel oracles in
    ``repro.kernels.ref`` all call this, so kernel and oracle cannot drift
    (DESIGN.md §4). Pure jnp bit-slicing — traceable inside a kernel body.
    """
    w = word.astype(jnp.int32)
    code = w & fmt.max_code
    sign = (1 - 2 * ((w >> (fmt.bits - 1)) & 1)).astype(jnp.float32)
    mag = jnp.exp2(-code.astype(jnp.float32) / fmt.gamma)
    if fmt.flush_zero:
        mag = jnp.where(code == fmt.max_code, 0.0, mag)
    return (sign * mag).astype(dtype)


def lns_requant_packed(packed: jax.Array, src: LNSFormat,
                       dst: LNSFormat) -> jax.Array:
    """Re-grid packed words ``src`` -> ``dst`` with integer-only arithmetic.

    ``code_dst = round(code_src * γ_dst/γ_src)`` is a shift-round when both
    base factors are powers of two — this is how the 16-bit update store
    feeds the 8-bit forward datapath without ever leaving the log domain
    (paper §4's "no integer↔LNS conversion", DESIGN.md §3). Matches
    decode→re-encode at the same scale (round-to-nearest, ties away from
    zero, clamped to ``dst.max_code``) everywhere except *exact* grid
    ties (``code_src·γ_dst ≡ γ_src/2 mod γ_src``, ~1/2^(B_src-B_dst) of
    codes): there the integer path rounds deterministically away from
    zero while the float path lands on whichever side f32 log2/exp2
    roundoff puts it — one code step of dither on values that sit exactly
    between two representable magnitudes.
    """
    w = packed.astype(jnp.int32)
    sign_bit = (w >> (src.bits - 1)) & 1
    code = w & src.max_code
    if dst.gamma >= src.gamma:
        code = code * (dst.gamma // src.gamma)
    else:
        r = src.gamma // dst.gamma
        code = (code + r // 2) // r  # floor(c/r + 1/2): ties away, c >= 0
    code = jnp.clip(code, 0, dst.max_code)
    return ((sign_bit << (dst.bits - 1)) | code).astype(lns_word_dtype(dst))


@jax.tree_util.register_pytree_with_keys_class
class LNSWeight:
    """A weight tensor stored natively in the packed LNS wire format.

    This is the single parameter representation shared by training state,
    checkpoints, and the serving engine (DESIGN.md §3):

    * ``packed`` — ``lns_pack`` words (MSB sign, low bits exponent code):
      1 byte/element at B<=8, the exact bytes the TPU kernels read from HBM.
    * ``scale``  — power-of-two per-channel scale, broadcastable against the
      decoded tensor.
    * ``delta``  — optional zero-valued dense tangent carrier. Training
      differentiates w.r.t. ``delta`` instead of a dense master copy; its
      gradient IS dL/dW at W = decode(packed). ``None`` outside of a loss.
    * ``fmt``    — the static :class:`LNSFormat` of the words (pytree aux
      data, so it travels with the leaf through jit/scan/checkpoint trees).
    """

    __slots__ = ("packed", "scale", "delta", "fmt")

    def __init__(self, packed, scale, delta=None, fmt: Optional[LNSFormat] = None):
        self.packed = packed
        self.scale = scale
        self.delta = delta
        self.fmt = fmt

    # -- pytree protocol (fmt is static aux data) ---------------------------
    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return (((k("packed"), self.packed), (k("scale"), self.scale),
                 (k("delta"), self.delta)), self.fmt)

    @classmethod
    def tree_unflatten(cls, fmt, children):
        return cls(children[0], children[1], children[2], fmt)

    def replace(self, **kw) -> "LNSWeight":
        d = {"packed": self.packed, "scale": self.scale, "delta": self.delta,
             "fmt": self.fmt}
        d.update(kw)
        return LNSWeight(**d)

    def requant(self, bits: int) -> "LNSWeight":
        """A *view* of this weight at another wire bitwidth: the packed
        words are re-gridded with :func:`lns_requant_packed` (integer-only,
        range-preserving — ``fmt.with_bits``) while the scale tensor is
        shared by reference. This is how a low-bitwidth draft model falls
        out of the number system for free (no second checkpoint): B=6/7
        serving weights are the same 8-bit codes on a coarser exponent
        grid. ``bits == fmt.bits`` returns ``self`` unchanged. The delta
        carrier (training-only) is dropped — a requant view is a forward
        datapath artifact."""
        if self.fmt is None:
            raise ValueError("LNSWeight.requant requires fmt")
        dst = self.fmt.with_bits(bits)
        if dst == self.fmt:
            return self
        return LNSWeight(lns_requant_packed(self.packed, self.fmt, dst),
                         self.scale, None, dst)

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self):
        return self.packed.shape

    @property
    def ndim(self):
        return self.packed.ndim

    @property
    def sign(self):
        return lns_unpack(self.packed, self.fmt)[0]

    @property
    def code(self):
        return lns_unpack(self.packed, self.fmt)[1]

    def decode(self, dtype=jnp.float32) -> jax.Array:
        """Dense view ``±s·2^(-code/γ) (+ delta)`` in ``dtype``."""
        if self.fmt is None:
            raise ValueError("LNSWeight.decode requires fmt")
        y = (lns_decode_packed(self.packed, self.fmt, jnp.float32)
             * self.scale).astype(dtype)
        if self.delta is not None:
            y = y + self.delta.astype(dtype)
        return y

    def __repr__(self):
        return (f"LNSWeight(packed={getattr(self.packed, 'shape', self.packed)}, "
                f"scale={getattr(self.scale, 'shape', self.scale)}, "
                f"delta={'None' if self.delta is None else 'dense'}, "
                f"fmt={self.fmt})")


def is_lns_weight(leaf) -> bool:
    return isinstance(leaf, LNSWeight)


def lns_weight_encode(x: jax.Array, fmt: LNSFormat, scale_axis=None,
                      scale: Optional[jax.Array] = None,
                      key: Optional[jax.Array] = None) -> LNSWeight:
    """Encode a dense tensor into a packed :class:`LNSWeight`."""
    if scale is None:
        scale = compute_scale(x, axis=scale_axis)
    sign, code = lns_encode(x, fmt, scale, key=key)
    return LNSWeight(packed=lns_pack(sign, code, fmt), scale=scale, fmt=fmt)


def quantization_gap(x: jax.Array, fmt: LNSFormat) -> jax.Array:
    """Distance to the next representable value above |x| (diagnostic).

    Grows as ``|x|·(2^(1/γ)-1)`` — the exponential gap growth that breaks GD
    (paper Fig. 1).
    """
    return jnp.abs(x) * (2.0 ** (1.0 / fmt.gamma) - 1.0)
