"""Multi-base logarithmic number system (LNS) — paper §2.

A value is represented as ``sign * s * 2**(-e/gamma)`` where

* ``e`` is an unsigned integer exponent code in ``[0, 2**(bits-1) - 1]``,
* ``gamma = 2**b`` is the *base factor* (the paper's multi-base knob),
* ``s`` is a power-of-two scale shared by a group of numbers (per tensor or
  per channel), chosen to match the group's absmax (paper §3).

The paper writes the representation as ``2**(x~/gamma)`` with dynamic range
``(0, (2**(B-1)-1)/gamma)``; because every value is pre-scaled so that
``|x|/s <= 1``, the stored integer is the magnitude of a *negative* exponent.
We store exactly that magnitude (e == 0 is the largest representable value,
``e == e_max`` the smallest).

Everything here is pure jnp and shape-polymorphic; the Pallas kernels in
``repro.kernels`` implement the same semantics and are tested against these
functions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LNSFormat",
    "pow2_scale",
    "compute_scale",
    "lns_encode",
    "lns_decode",
    "lns_quantize",
    "lns_pack",
    "lns_unpack",
    "quantization_gap",
]


@dataclasses.dataclass(frozen=True)
class LNSFormat:
    """A multi-base LNS format (paper §2.1).

    Attributes:
      bits: total bitwidth B (1 sign bit + (B-1) exponent bits).
      gamma: base factor, must be a power of two. The representable
        magnitudes relative to the scale are ``2**(-e/gamma)`` for integer
        ``e in [0, 2**(bits-1)-1]``.
      stochastic: use stochastic rounding for the exponent (theory mode /
        Q_U option). Deterministic round-to-nearest otherwise (deployed path).
      flush_zero: decode the largest exponent code to exactly 0. Off by
        default (the hardware datapath has no zero flag).
    """

    bits: int = 8
    gamma: int = 8
    stochastic: bool = False
    flush_zero: bool = False

    def __post_init__(self):
        if self.bits < 2 or self.bits > 32:
            raise ValueError(f"bits must be in [2,32], got {self.bits}")
        if self.gamma < 1 or (self.gamma & (self.gamma - 1)) != 0:
            raise ValueError(f"gamma must be a power of two, got {self.gamma}")

    @property
    def exponent_bits(self) -> int:
        return self.bits - 1

    @property
    def max_code(self) -> int:
        """Largest exponent code 2**(B-1) - 1 (paper's clamp ceiling)."""
        return (1 << (self.bits - 1)) - 1

    @property
    def dynamic_range(self) -> float:
        """The paper's (0, (2**(B-1)-1)/gamma) exponent range width."""
        return self.max_code / self.gamma

    @property
    def code_dtype(self):
        return jnp.int8 if self.bits <= 8 else (jnp.int16 if self.bits <= 16 else jnp.int32)

    def with_bits(self, bits: int, keep_range: bool = True) -> "LNSFormat":
        """Derive a format at a different bitwidth.

        With ``keep_range`` the base factor scales as gamma' = gamma *
        2**(bits-B) so the dynamic range (0, max_code/gamma) is preserved —
        this is exactly the paper's §6.1.1 prescription for widening Q_U.
        """
        gamma = self.gamma * (1 << max(bits - self.bits, 0)) if keep_range else self.gamma
        if keep_range and bits < self.bits:
            gamma = max(1, self.gamma >> (self.bits - bits))
        return dataclasses.replace(self, bits=bits, gamma=gamma)


def pow2_scale(absmax: jax.Array) -> jax.Array:
    """Snap a positive scale to the next power of two (>= absmax).

    Power-of-two scales keep Q_log a pure shift in the exponent domain and
    match the hardware's scale-by-shift post-processing unit.
    """
    absmax = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny)
    return jnp.exp2(jnp.ceil(jnp.log2(absmax.astype(jnp.float32))))


def compute_scale(x: jax.Array, axis=None) -> jax.Array:
    """Absmax scale, per tensor (axis=None) or per channel, snapped to 2**k.

    ``axis`` is the channel axis (or tuple of axes) that KEEPS resolution
    (the reduction runs over all other axes), matching the paper's
    per-channel / per-feature scaling. The result broadcasts against ``x``.
    """
    xf = jnp.abs(x.astype(jnp.float32))
    if axis is None:
        amax = jnp.max(xf)
    else:
        keep = {a % x.ndim for a in ((axis,) if isinstance(axis, int) else axis)}
        reduce_axes = tuple(i for i in range(x.ndim) if i not in keep)
        amax = jnp.max(xf, axis=reduce_axes, keepdims=True)
    return pow2_scale(amax)


def _round(x: jax.Array, stochastic: bool, key: Optional[jax.Array]) -> jax.Array:
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        floor = jnp.floor(x)
        p = jax.random.uniform(key, x.shape, dtype=x.dtype)
        return floor + (p <= (x - floor)).astype(x.dtype)
    # round-to-nearest, ties away from zero (cheap in HW; jnp.round is
    # ties-to-even — the tie set has measure ~0 for log2 outputs, but we fix
    # the convention so kernels and oracle agree bit-exactly).
    return jnp.floor(x + 0.5)


def lns_encode(
    x: jax.Array,
    fmt: LNSFormat,
    scale: jax.Array,
    key: Optional[jax.Array] = None,
):
    """Encode real values into (sign, exponent-code) LNS pairs.

    Returns ``(sign, code)`` with ``sign in {-1, +1}`` (int8) and
    ``code = clamp(round(-log2(|x|/s) * gamma), 0, max_code)`` stored in the
    narrowest integer dtype that fits.
    """
    xf = x.astype(jnp.float32)
    sign = jnp.where(xf < 0, -1, 1).astype(jnp.int8)
    mag = jnp.abs(xf) / scale
    # |x| == 0 -> log2 = -inf -> e = +inf -> clamps to max_code (smallest
    # representable magnitude), reproducing the zero-flag-free hardware.
    e = -jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)) * fmt.gamma
    e = _round(e, fmt.stochastic, key)
    e = jnp.clip(e, 0, fmt.max_code)
    return sign, e.astype(fmt.code_dtype)


def lns_decode(
    sign: jax.Array,
    code: jax.Array,
    fmt: LNSFormat,
    scale: jax.Array,
    dtype=jnp.float32,
) -> jax.Array:
    """Decode (sign, code) LNS pairs back to real values."""
    mag = jnp.exp2(-code.astype(jnp.float32) / fmt.gamma)
    if fmt.flush_zero:
        mag = jnp.where(code == fmt.max_code, 0.0, mag)
    return (sign.astype(jnp.float32) * mag * scale).astype(dtype)


def lns_quantize(
    x: jax.Array,
    fmt: LNSFormat,
    scale_axis: Optional[int] = None,
    scale: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """The paper's Q_log (Eq. 3): fake-quantize ``x`` onto the LNS grid.

    Encode + decode in one call; the returned array has ``x.dtype`` and lies
    exactly on the representable grid ``{±s·2^(-e/γ)}``.
    """
    if scale is None:
        scale = compute_scale(x, axis=scale_axis)
    sign, code = lns_encode(x, fmt, scale, key=key)
    return lns_decode(sign, code, fmt, scale, dtype=x.dtype)


def lns_pack(sign: jax.Array, code: jax.Array, fmt: LNSFormat) -> jax.Array:
    """Pack (sign, code) into the hardware wire format: one unsigned word of
    ``fmt.bits`` bits, MSB = sign, low ``bits-1`` bits = exponent code.

    This is the storage dtype the TPU path reads from HBM — B=8 LNS weights
    are exactly 1 byte/element (the 2x bandwidth win vs bf16).
    """
    dt = jnp.uint8 if fmt.bits <= 8 else (jnp.uint16 if fmt.bits <= 16 else jnp.uint32)
    neg = (sign.astype(jnp.int32) < 0).astype(jnp.uint32)
    word = (neg << (fmt.bits - 1)) | code.astype(jnp.uint32)
    return word.astype(dt)


def lns_unpack(packed: jax.Array, fmt: LNSFormat):
    """Unpack wire words into (sign in {-1,+1} int8, code)."""
    w = packed.astype(jnp.uint32)
    sign_bit = (w >> (fmt.bits - 1)) & 1
    code = w & jnp.uint32(fmt.max_code)
    sign = (1 - 2 * sign_bit.astype(jnp.int32)).astype(jnp.int8)
    return sign, code.astype(fmt.code_dtype)


def quantization_gap(x: jax.Array, fmt: LNSFormat) -> jax.Array:
    """Distance to the next representable value above |x| (diagnostic).

    Grows as ``|x|·(2^(1/γ)-1)`` — the exponential gap growth that breaks GD
    (paper Fig. 1).
    """
    return jnp.abs(x) * (2.0 ** (1.0 / fmt.gamma) - 1.0)
