"""Approximation-aware quantized GEMMs (paper App. B / §.4, Table 10).

Simulates the *hybrid conversion approximation* inside a dot product: every
product term ``2**(p/γ)`` is decoded with the Mitchell/LUT approximation
before accumulation. Since the approximation's multiplicative error depends
only on the product-exponent remainder ``r = p mod γ``, the dot product is
decomposed into γ exact GEMMs bucketed by the weight-code remainder, with the
activation operand pre-multiplied by the bin's error factor:

    y = Σ_j einsum( x·δ((p_x + j) mod γ), w·[p_w mod γ == j] )

This is a *bit-faithful* simulation of the approximate datapath at γ× the
GEMM cost — used by the Table-10 benchmark and approximation-aware training;
the production path uses exact accumulation (fp32 MXU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import conversion
from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_decode

__all__ = ["approx_qeinsum", "approx_product_values"]


def _positive_codes(code, fmt: LNSFormat):
    """Bottom-anchored positive codes, the hardware's storage convention.

    value = s · 2**(-e/γ) = (s·2**(-max_code/γ)) · 2**((max_code-e)/γ).
    """
    return fmt.max_code - code.astype(jnp.int32)


def approx_product_values(ex, ew, fmt: LNSFormat, lut_entries: int):
    """Decode a product of two positive codes with the hybrid approximation.

    Returns the approximate linear value of ``2**((ex+ew)/γ)`` — reference
    path used by tests (elementwise, no bucketing).
    """
    p = ex.astype(jnp.int32) + ew.astype(jnp.int32)
    return conversion.exp2_hybrid(p, fmt.gamma, lut_entries)


def approx_qeinsum(eq: str, x: jax.Array, w: jax.Array, cfg) -> jax.Array:
    """Quantized einsum with approximate LNS accumulation (forward) and an
    exact-fake-quant STE backward (approximation-aware training, App. §.4).
    """
    fmt: LNSFormat = cfg.weight
    afmt: LNSFormat = cfg.act or fmt
    lut = cfg.approx_lut
    gamma = fmt.gamma

    sx_scale = compute_scale(x, axis=cfg.act_scale_axis)
    sw_scale = compute_scale(w, axis=cfg.weight_scale_axis)
    sx, ex = lns_encode(x, afmt, sx_scale)
    sw, ew = lns_encode(w, fmt, sw_scale)

    xq = lns_decode(sx, ex, afmt, sx_scale, dtype=jnp.float32)
    wq = lns_decode(sw, ew, fmt, sw_scale, dtype=jnp.float32)

    # positive (bottom-anchored) codes; product remainder r=(px+pw) mod γ.
    px = _positive_codes(ex, afmt)
    pw = _positive_codes(ew, fmt)
    rw = pw % gamma

    y_approx = jnp.zeros(())
    for j in range(gamma):
        delta = conversion.approx_decode_factor((px + j) % gamma, gamma, lut)
        term = jnp.einsum(eq, xq * delta, jnp.where(rw == j, wq, 0.0))
        y_approx = y_approx + term

    # STE: the backward pass sees the exact fake-quantized GEMM (the
    # approximators are deterministic nonlinearities learned through).
    y_exact = jnp.einsum(eq, xq, wq)
    y = y_exact + jax.lax.stop_gradient(y_approx - y_exact)
    return y.astype(x.dtype)
