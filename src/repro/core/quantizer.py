"""Quantized forward/backward propagation on LNS — paper §3, Fig. 3.

Quantization-aware training with straight-through estimators:

* ``Q_W`` (weights) and ``Q_A`` (activations) are applied *before* each GEMM
  in the forward pass, with STE so gradients flow through the rounding.
* ``Q_E`` (activation gradients) is applied to the cotangent arriving at each
  GEMM output — this is the tensor the hardware stores in BufferB for both
  backward passes (Table 2), so one quantizer at the output covers both
  dL/dX and dL/dW GEMMs.
* ``Q_G`` (weight gradients) is applied to the final weight gradient in the
  train step (:func:`quantize_grads`), matching Fig. 3's dataflow.

``qeinsum`` is the single entry point all model layers use; swapping the
:class:`QuantConfig` switches a model between fp32/bf16, LNS, and FP8
training without touching model code.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat, compute_scale, lns_quantize
from repro.numerics.fp import FPFormat, fp_quantize

__all__ = [
    "QuantConfig",
    "ste_quantize",
    "backward_quantize",
    "cot_boundary",
    "qeinsum",
    "quantize_grads",
]

Format = Union[LNSFormat, FPFormat]


def _apply_format(x: jax.Array, fmt: Format, scale_axis: Optional[int]) -> jax.Array:
    if isinstance(fmt, LNSFormat):
        return lns_quantize(x, fmt, scale_axis=scale_axis)
    return fp_quantize(x, fmt, scale_axis=scale_axis)


def ste_quantize(x: jax.Array, fmt: Optional[Format], scale_axis: Optional[int] = None) -> jax.Array:
    """Forward: quantize onto the format grid. Backward: identity (STE)."""
    if fmt is None:
        return x
    q = _apply_format(x, fmt, scale_axis)
    return x + jax.lax.stop_gradient(q - x)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def backward_quantize(x: jax.Array, fmt: Optional[Format],
                      scale_axis: Optional[int] = None,
                      cot_dtype: Optional[Any] = None):
    """Forward: identity. Backward: quantize the cotangent (the paper's Q_E)
    and store it in ``cot_dtype`` (bf16 in the deployed path — the cotangent
    is on the 8-bit LNS grid anyway, and f32 containers would double every
    backward collective/HBM byte; see EXPERIMENTS.md §Perf)."""
    return x


def _bq_fwd(x, fmt, scale_axis, cot_dtype):
    return x, None


def _bq_bwd(fmt, scale_axis, cot_dtype, _res, g):
    if fmt is not None:
        g = _apply_format(g, fmt, scale_axis)
    if cot_dtype is not None:
        g = g.astype(cot_dtype)
    return (g,)


backward_quantize.defvjp(_bq_fwd, _bq_bwd)


def cot_boundary(x: jax.Array) -> jax.Array:
    """Pin the cotangent of ``x`` to ``x.dtype``.

    Every fp32 island (norms, router, softmax/xent, rope) otherwise promotes
    the residual stream's backward to f32 — doubling every backward HBM and
    collective byte. Production mixed-precision discipline: bf16 network,
    f32 islands, cast at the boundary. Forward identity, zero cost.
    """
    return backward_quantize(x, None, None, x.dtype)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Formats + scaling policy for one training run.

    ``None`` for a field disables that quantizer (full-precision path).
    Scale axes: ``None`` = per-tensor; an int = that axis keeps resolution
    (per-channel / per-feature, paper §6.1.2).
    """

    weight: Optional[Format] = None      # Q_W
    act: Optional[Format] = None         # Q_A
    err: Optional[Format] = None         # Q_E
    grad: Optional[Format] = None        # Q_G
    update: Optional[Format] = None      # Q_U (consumed by the optimizer)
    weight_scale_axis: Optional[int] = -1
    act_scale_axis: Optional[int] = None
    err_scale_axis: Optional[int] = None
    grad_scale_axis: Optional[int] = None
    # Hybrid conversion-approximation simulation (paper App. B / Table 10):
    # number of LUT entries; None = exact accumulation.
    approx_lut: Optional[int] = None

    @classmethod
    def lns_madam(cls, bits: int = 8, gamma: int = 8, update_bits: int = 16,
                  approx_lut: Optional[int] = None) -> "QuantConfig":
        """The paper's deployed setting: B=8, γ=8 everywhere; Q_U at
        ``update_bits`` with γ_U widened to keep the (0,15.875) range
        (§6.1.1)."""
        fmt = LNSFormat(bits=bits, gamma=gamma)
        return cls(weight=fmt, act=fmt, err=fmt, grad=fmt,
                   update=fmt.with_bits(update_bits), approx_lut=approx_lut)

    @classmethod
    def fp8(cls) -> "QuantConfig":
        """The paper's FP8 baseline: e4m3 fwd/bwd, 16-bit update via SR."""
        fmt = FPFormat(exp_bits=4, man_bits=3)
        return cls(weight=fmt, act=fmt, err=fmt, grad=fmt,
                   update=FPFormat(exp_bits=5, man_bits=10))

    @classmethod
    def full_precision(cls) -> "QuantConfig":
        return cls()

    @property
    def is_quantized(self) -> bool:
        return any(f is not None for f in (self.weight, self.act, self.err, self.grad))


def qeinsum(eq: str, x: jax.Array, w: jax.Array, cfg: Optional[QuantConfig],
            w_channel_axis: Optional[int] = -1) -> jax.Array:
    """Quantized GEMM: ``einsum(eq, Q_A(x), Q_W(w))`` with Q_E on the
    output cotangent. This is the layer every model projection routes
    through.

    ``w_channel_axis``: the weight axis that keeps per-channel scale
    resolution (output features). ``None`` forces per-tensor weight scale.
    """
    # NOTE on accumulation dtype: the TPU MXU always accumulates bf16
    # matmuls in fp32 *inside* the unit (the native analogue of the paper's
    # 24-bit accumulation collector). Forcing preferred_element_type=f32 at
    # the HLO level would make every backward cotangent f32 (the vjp of the
    # f32 dot), doubling backward HBM + collective bytes — so GEMMs emit the
    # compute dtype and Q_E re-grids the cotangent at each boundary.
    if cfg is None or not cfg.is_quantized:
        y = jnp.einsum(eq, x, w)
        return backward_quantize(y, None, None, x.dtype)
    if cfg.approx_lut is not None and isinstance(cfg.weight, LNSFormat):
        from repro.core.quant_training import approx_qeinsum  # cycle-free lazy import
        y = approx_qeinsum(eq, x, w, cfg)
    else:
        xq = ste_quantize(x, cfg.act, cfg.act_scale_axis)
        w_axis = cfg.weight_scale_axis if w_channel_axis == -1 else w_channel_axis
        wq = ste_quantize(w, cfg.weight, w_axis)
        y = jnp.einsum(eq, xq, wq)
    return backward_quantize(y, cfg.err, cfg.err_scale_axis, x.dtype)


def quantize_grads(grads, cfg: Optional[QuantConfig]):
    """Apply Q_G to a gradient pytree (per-tensor scales).

    Called by the train step after ``jax.grad`` and before the optimizer /
    data-parallel reduction — quantizing *before* the all-reduce is also what
    makes the LNS-compressed collective (optim/compression.py) exact.
    """
    if cfg is None or cfg.grad is None:
        return grads
    return jax.tree.map(
        lambda g: _apply_format(g, cfg.grad, cfg.grad_scale_axis), grads)
