"""Quantized forward/backward propagation on LNS — paper §3, Fig. 3.

Quantization-aware training with straight-through estimators:

* ``Q_W`` (weights) and ``Q_A`` (activations) are applied *before* each GEMM
  in the forward pass, with STE so gradients flow through the rounding.
* ``Q_E`` (activation gradients) is applied to the cotangent arriving at each
  GEMM output — this is the tensor the hardware stores in BufferB for both
  backward passes (Table 2), so one quantizer at the output covers both
  dL/dX and dL/dW GEMMs.
* ``Q_G`` (weight gradients) is applied to the final weight gradient in the
  train step (:func:`quantize_grads`), matching Fig. 3's dataflow.

``qeinsum`` is the single entry point all model layers use; swapping the
:class:`QuantConfig` switches a model between fp32/bf16, LNS, and FP8
training without touching model code.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lns import (LNSFormat, LNSWeight, compute_scale,
                            is_lns_weight, lns_decode_packed, lns_quantize,
                            lns_requant_packed)
from repro.kernels import dispatch
from repro.numerics.fp import FPFormat, fp_quantize

__all__ = [
    "QuantConfig",
    "ste_quantize",
    "backward_quantize",
    "cot_boundary",
    "qeinsum",
    "quantize_grads",
]

Format = Union[LNSFormat, FPFormat]


def _apply_format(x: jax.Array, fmt: Format, scale_axis: Optional[int]) -> jax.Array:
    if isinstance(fmt, LNSFormat):
        return lns_quantize(x, fmt, scale_axis=scale_axis)
    return fp_quantize(x, fmt, scale_axis=scale_axis)


def ste_quantize(x: jax.Array, fmt: Optional[Format], scale_axis: Optional[int] = None) -> jax.Array:
    """Forward: quantize onto the format grid. Backward: identity (STE)."""
    if fmt is None:
        return x
    q = _apply_format(x, fmt, scale_axis)
    return x + jax.lax.stop_gradient(q - x)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def backward_quantize(x: jax.Array, fmt: Optional[Format],
                      scale_axis: Optional[int] = None,
                      cot_dtype: Optional[Any] = None):
    """Forward: identity. Backward: quantize the cotangent (the paper's Q_E)
    and store it in ``cot_dtype`` (bf16 in the deployed path — the cotangent
    is on the 8-bit LNS grid anyway, and f32 containers would double every
    backward collective/HBM byte; see EXPERIMENTS.md §Perf)."""
    return x


def _bq_fwd(x, fmt, scale_axis, cot_dtype):
    return x, None


def _bq_bwd(fmt, scale_axis, cot_dtype, _res, g):
    if fmt is not None:
        g = _apply_format(g, fmt, scale_axis)
    if cot_dtype is not None:
        g = g.astype(cot_dtype)
    return (g,)


backward_quantize.defvjp(_bq_fwd, _bq_bwd)


def cot_boundary(x: jax.Array) -> jax.Array:
    """Pin the cotangent of ``x`` to ``x.dtype``.

    Every fp32 island (norms, router, softmax/xent, rope) otherwise promotes
    the residual stream's backward to f32 — doubling every backward HBM and
    collective byte. Production mixed-precision discipline: bf16 network,
    f32 islands, cast at the boundary. Forward identity, zero cost.
    """
    return backward_quantize(x, None, None, x.dtype)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Formats + scaling policy for one training run.

    ``None`` for a field disables that quantizer (full-precision path).
    Scale axes: ``None`` = per-tensor; an int = that axis keeps resolution
    (per-channel / per-feature, paper §6.1.2).
    """

    weight: Optional[Format] = None      # Q_W
    act: Optional[Format] = None         # Q_A
    err: Optional[Format] = None         # Q_E
    grad: Optional[Format] = None        # Q_G
    update: Optional[Format] = None      # Q_U (consumed by the optimizer)
    weight_scale_axis: Optional[int] = -1
    act_scale_axis: Optional[int] = None
    err_scale_axis: Optional[int] = None
    grad_scale_axis: Optional[int] = None
    # Hybrid conversion-approximation simulation (paper App. B / Table 10):
    # number of LUT entries; None = exact accumulation.
    approx_lut: Optional[int] = None

    # The ``backend`` field (deprecated PR 6) is gone: kernel backend
    # selection lives in ``repro.kernels.dispatch.configure()`` /
    # ``configured()`` (one process-level knob) or the per-call
    # ``backend=`` argument of the dispatched ops themselves.
    @property
    def backend(self):
        raise AttributeError(
            "QuantConfig.backend was removed: select the kernel backend "
            "with repro.kernels.dispatch.configure(backend=...) or the "
            "configured(...) context manager")

    @classmethod
    def lns_madam(cls, bits: int = 8, gamma: int = 8, update_bits: int = 16,
                  approx_lut: Optional[int] = None) -> "QuantConfig":
        """The paper's deployed setting: B=8, γ=8 everywhere; Q_U at
        ``update_bits`` with γ_U widened to keep the (0,15.875) range
        (§6.1.1)."""
        fmt = LNSFormat(bits=bits, gamma=gamma)
        return cls(weight=fmt, act=fmt, err=fmt, grad=fmt,
                   update=fmt.with_bits(update_bits), approx_lut=approx_lut)

    @classmethod
    def fp8(cls) -> "QuantConfig":
        """The paper's FP8 baseline: e4m3 fwd/bwd, 16-bit update via SR."""
        fmt = FPFormat(exp_bits=4, man_bits=3)
        return cls(weight=fmt, act=fmt, err=fmt, grad=fmt,
                   update=FPFormat(exp_bits=5, man_bits=10))

    @classmethod
    def full_precision(cls) -> "QuantConfig":
        return cls()

    @property
    def is_quantized(self) -> bool:
        return any(f is not None for f in (self.weight, self.act, self.err, self.grad))


def _reject_backend_kwarg(cls):
    """Turn ``Config(backend=...)`` into an actionable error (the field was
    removed; the generated TypeError would not say where the knob went)."""
    orig = cls.__init__

    def __init__(self, *args, **kwargs):
        if "backend" in kwargs:
            raise TypeError(
                f"{cls.__name__}.backend was removed: select the kernel "
                f"backend with repro.kernels.dispatch.configure"
                f"(backend=...) or the configured(...) context manager")
        orig(self, *args, **kwargs)

    cls.__init__ = __init__
    return cls


_reject_backend_kwarg(QuantConfig)


# ---------------------------------------------------------------------------
# packed-LNS routing: GEMMs whose weight is a packed LNSWeight skip the
# materialize + fake-quant round-trip and feed the wire words straight to
# the dispatch layer (DESIGN.md §4).


def _route_plan(eq: str) -> bool:
    """True for a plain 2-D contraction ``...k,kn->...n`` (single shared
    index, weight contributes exactly its output axis)."""
    try:
        lhs, out = eq.replace(" ", "").split("->")
        xs, ws = lhs.split(",")
    except ValueError:
        return False
    return (len(ws) == 2 and xs[-1] == ws[0] and out == xs[:-1] + ws[1]
            and len(set(xs)) == len(xs) and ws[1] not in xs)


def _routable(eq: str, w: LNSWeight, cfg: Optional[QuantConfig]) -> bool:
    """Can this GEMM go through the packed kernel path?

    Requires: LNS forward formats for both operands on one grid (the kernel
    decodes both tiles with a single (bits, γ)), per-tensor activation
    scale, a 2-D weight whose per-channel scale is constant along the
    contraction axis (so it factors into the f32 epilogue), and no
    conversion-approximation simulation.
    """
    if cfg is None or cfg.approx_lut is not None:
        return False
    if not (isinstance(cfg.weight, LNSFormat) and isinstance(cfg.act, LNSFormat)):
        return False
    if (cfg.weight.bits, cfg.weight.gamma) != (cfg.act.bits, cfg.act.gamma):
        return False
    if cfg.weight.stochastic or cfg.act.stochastic:
        return False
    if cfg.act_scale_axis is not None:
        return False
    if w.ndim != 2 or w.fmt is None:
        return False
    s = w.scale
    if hasattr(s, "ndim") and s.ndim not in (0, 2):
        return False
    if getattr(s, "ndim", 0) == 2 and s.shape[0] != 1:
        return False  # scale varies along the contraction axis
    return _route_plan(eq)


def _forward_packed(w: LNSWeight, ffmt: LNSFormat):
    """Weight words on the forward grid: integer re-grid when the storage
    format (B_U) is wider than the forward format (B_W) — a shift-round,
    never a decode."""
    if w.fmt is not None and (w.fmt.bits, w.fmt.gamma) == (ffmt.bits, ffmt.gamma):
        return w.packed
    return lns_requant_packed(w.packed, w.fmt, ffmt)


def _routed_impl(fmt: LNSFormat, backend: Optional[str], x: jax.Array,
                 pw: jax.Array, wscale: jax.Array):
    """y = decode(Q_A(x)) @ decode(pw) * s_x * s_w via the dispatch layer.

    Returns ``(y, px, sx)`` — the packed activation + scale double as the
    custom-vjp residuals.
    """
    K = x.shape[-1]
    xm = x.reshape(-1, K)
    px, sx = dispatch.encode_pack(xm, fmt, scale_axis=None, backend=backend)
    y = dispatch.qmatmul(px, pw, fmt, scale_a=sx,
                         scale_b=wscale.reshape(1, -1),
                         compute_dtype=x.dtype, backend=backend)
    return y.reshape(x.shape[:-1] + (pw.shape[1],)).astype(x.dtype), px, sx


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _routed_matmul(fmt: LNSFormat, backend: Optional[str], x: jax.Array,
                   delta: jax.Array, pw: jax.Array, wscale: jax.Array):
    """Routed GEMM with STE gradients.

    ``delta`` is the weight's zero tangent carrier: the primal ignores it
    (no extra FLOPs), the backward returns dL/dW into it — exactly the
    straight-through gradient of ``einsum(Q_A(x), Q_W(W))`` w.r.t. W.
    """
    return _routed_impl(fmt, backend, x, pw, wscale)[0]


def _routed_fwd(fmt, backend, x, delta, pw, wscale):
    y, px, sx = _routed_impl(fmt, backend, x, pw, wscale)
    # residuals are the packed operands: 1 B/elem instead of a dense bf16
    # activation save — the LNS bandwidth win applies to remat too (the
    # zero-size tokens carry x/delta dtypes through the residual pytree)
    return y, (px, sx, pw, wscale, jnp.zeros((0,), x.dtype),
               jnp.zeros((0,), delta.dtype))


def _routed_bwd(fmt, backend, res, dy):
    px, sx, pw, wscale, x_tok, d_tok = res
    x_dtype, d_dtype = x_tok.dtype, d_tok.dtype
    dym = dy.reshape(-1, dy.shape[-1]).astype(x_dtype)
    # STE: d/dx treats Q_A as identity -> dy @ Wq^T; d/dW -> Q_A(x)^T @ dy
    wq = (lns_decode_packed(pw, fmt, jnp.float32)
          * wscale.reshape(1, -1)).astype(x_dtype)
    xq = (lns_decode_packed(px, fmt, jnp.float32) * sx).astype(x_dtype)
    dx = (dym @ wq.T).reshape(dy.shape[:-1] + (pw.shape[0],)).astype(x_dtype)
    ddelta = (xq.T @ dym).astype(d_dtype)
    return (dx, ddelta, np.zeros(pw.shape, jax.dtypes.float0),
            jnp.zeros_like(wscale))


_routed_matmul.defvjp(_routed_fwd, _routed_bwd)


def _routed_qeinsum(eq: str, x: jax.Array, w: LNSWeight,
                    cfg: QuantConfig) -> jax.Array:
    ffmt = cfg.weight
    pw = _forward_packed(w, ffmt)
    if w.delta is None:  # inference: no tangent carrier, no vjp machinery
        return _routed_impl(ffmt, None, x, pw, w.scale)[0]
    return _routed_matmul(ffmt, None, x, w.delta, pw, w.scale)


def qeinsum(eq: str, x: jax.Array, w, cfg: Optional[QuantConfig],
            w_channel_axis: Optional[int] = -1) -> jax.Array:
    """Quantized GEMM: ``einsum(eq, Q_A(x), Q_W(w))`` with Q_E on the
    output cotangent. This is the layer every model projection routes
    through.

    ``w`` may be a dense array or a packed :class:`LNSWeight`. Packed 2-D
    contractions route through ``kernels/dispatch`` (tile-local decode,
    per-channel scale epilogue — no dense weight copy); packed weights
    that cannot route (3-D expert stacks, approx-LUT simulation, non-LNS
    formats) decode per leaf at the use site and take the fake-quant path.

    ``w_channel_axis``: the weight axis that keeps per-channel scale
    resolution (output features). ``None`` forces per-tensor weight scale.
    """
    # NOTE on accumulation dtype: the TPU MXU always accumulates bf16
    # matmuls in fp32 *inside* the unit (the native analogue of the paper's
    # 24-bit accumulation collector). Forcing preferred_element_type=f32 at
    # the HLO level would make every backward cotangent f32 (the vjp of the
    # f32 dot), doubling backward HBM + collective bytes — so GEMMs emit the
    # compute dtype and Q_E re-grids the cotangent at each boundary.
    if is_lns_weight(w):
        if _routable(eq, w, cfg):
            y = _routed_qeinsum(eq, x, w, cfg)
            return backward_quantize(y, cfg.err, cfg.err_scale_axis, x.dtype)
        w = w.decode(x.dtype)  # per-leaf fallback (delta keeps grads flowing)
    if cfg is None or not cfg.is_quantized:
        y = jnp.einsum(eq, x, w)
        return backward_quantize(y, None, None, x.dtype)
    if cfg.approx_lut is not None and isinstance(cfg.weight, LNSFormat):
        from repro.core.quant_training import approx_qeinsum  # cycle-free lazy import
        y = approx_qeinsum(eq, x, w, cfg)
    else:
        xq = ste_quantize(x, cfg.act, cfg.act_scale_axis)
        w_axis = cfg.weight_scale_axis if w_channel_axis == -1 else w_channel_axis
        wq = ste_quantize(w, cfg.weight, w_axis)
        y = jnp.einsum(eq, xq, wq)
    return backward_quantize(y, cfg.err, cfg.err_scale_axis, x.dtype)


def quantize_grads(grads, cfg: Optional[QuantConfig]):
    """Apply Q_G to a gradient pytree (per-tensor scales).

    Called by the train step after ``jax.grad`` and before the optimizer /
    data-parallel reduction — quantizing *before* the all-reduce is also what
    makes the LNS-compressed collective (optim/compression.py) exact.
    """
    if cfg is None or cfg.grad is None:
        return grads
    return jax.tree.map(
        lambda g: _apply_format(g, cfg.grad, cfg.grad_scale_axis), grads)
