"""Analytical energy model — paper §6.2 (Tables 8, 10; Figs. 2, 8, 9, 10).

No RTL flow exists in this container, so the paper's energy results are
reproduced through an analytical model calibrated to its published numbers:

* **Per-op datapath energy** (fJ per MAC-equivalent op) comes from Table 10's
  measured LNS row (12.29 / 14.71 / 17.24 / 19.02 fJ/op for LUT = 1/2/4/8)
  and the §6.2 PE-level ratios (LNS : FP8 : FP16 : FP32 = 1 : 2.2 : 4.6 : 11).
* **A single system-overhead factor κ** (buffers, accumulation collector,
  PPU — the non-datapath slices of Fig. 8) is calibrated once against the
  Table-8 ResNet-50 row. With κ = 4.23 the model reproduces all eight
  Table-8 cells within ~20% (see ``benchmarks/energy.py`` which prints the
  side-by-side table).

Per-iteration energy = κ · 3 · MACs_fwd · e_op(format): one forward plus two
backward GEMM passes (Table 2's three computation passes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = [
    "DATAPATH_FJ_PER_OP",
    "SYSTEM_OVERHEAD",
    "per_iteration_energy_mj",
    "paper_table8",
    "gpt_scaling",
]

# fJ per MAC-equivalent op, calibrated as documented above.
_LNS_EXACT = 19.02  # Table 10, LUT=8 (exact conversion for γ=8)
DATAPATH_FJ_PER_OP: Dict[str, float] = {
    "lns8_lut1": 12.29,          # Table 10
    "lns8_lut2": 14.71,
    "lns8_lut4": 17.24,
    "lns8_lut8": _LNS_EXACT,
    "lns8": _LNS_EXACT,
    "fp8": _LNS_EXACT * 2.2,     # §6.2 PE ratios
    "fp16": _LNS_EXACT * 4.6,
    "fp32": _LNS_EXACT * 11.0,
}

SYSTEM_OVERHEAD = 4.23  # κ, calibrated on Table 8 ResNet-50 / LNS = 0.99 mJ

# fwd-pass GEMM MACs for the paper's models (per iteration, paper settings).
PAPER_MODEL_MACS: Dict[str, float] = {
    "resnet18": 1.82e9,    # 224x224 ImageNet single image
    "resnet50": 4.09e9,
    "bert_base": 3.61e10,  # seq 384: 86.1e6 GEMM params ·384 + attn 2.7e9
    "bert_large": 1.24e11, # seq 384: 303e6 ·384 + attn 7.2e9
}

PAPER_TABLE8_MJ = {  # the paper's measured numbers, for the benchmark diff
    "resnet18": {"lns8": 0.54, "fp8": 1.22, "fp16": 2.50, "fp32": 5.99},
    "resnet50": {"lns8": 0.99, "fp8": 2.25, "fp16": 4.59, "fp32": 11.03},
    "bert_base": {"lns8": 7.99, "fp8": 18.23, "fp16": 37.21, "fp32": 89.35},
    "bert_large": {"lns8": 27.85, "fp8": 63.58, "fp16": 129.74, "fp32": 311.58},
}


def per_iteration_energy_mj(macs_fwd: float, fmt: str = "lns8") -> float:
    """Energy (mJ) for one train iteration: fwd + bwd(input) + bwd(weight)."""
    if fmt not in DATAPATH_FJ_PER_OP:
        raise KeyError(f"unknown format {fmt!r}; one of {sorted(DATAPATH_FJ_PER_OP)}")
    return SYSTEM_OVERHEAD * 3.0 * macs_fwd * DATAPATH_FJ_PER_OP[fmt] * 1e-15 * 1e3


def paper_table8() -> Dict[str, Dict[str, float]]:
    """Model predictions laid out like Table 8 (mJ per iteration)."""
    return {
        model: {fmt: per_iteration_energy_mj(macs, fmt) for fmt in ("lns8", "fp8", "fp16", "fp32")}
        for model, macs in PAPER_MODEL_MACS.items()
    }


def gpt_scaling(tokens_per_iter: float = 2048.0) -> Dict[str, Dict[str, float]]:
    """Fig. 10: per-iteration energy for GPT models 1B → 1T parameters.

    fwd MACs ≈ N params per token (2N flops); per-iteration uses
    ``tokens_per_iter`` tokens (batch 1 × seq 2048 by default, stated
    assumption — the paper does not publish its batch).
    """
    sizes = {"gpt-1b": 1e9, "gpt-13b": 13e9, "gpt-175b": 175e9, "gpt-530b": 530e9, "gpt-1t": 1e12}
    return {
        name: {fmt: per_iteration_energy_mj(n * tokens_per_iter, fmt)
               for fmt in ("lns8", "fp8", "fp16", "fp32")}
        for name, n in sizes.items()
    }
