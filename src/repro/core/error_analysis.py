"""Weight-update quantization-error analysis — paper §4.2, Fig. 4, App. A.

Implements the simplified quantizer of Eq. 11 (no scale, no clamp,
stochastic rounding) and the four learning rules (GD, MUL, signMUL, Madam),
measuring  r_t = || log2|W_q| − log2|W_new| ||²  together with the
theoretical bounds of Theorems 1/2 and Lemma 1. Used by
``benchmarks/quant_error.py`` (Fig. 4) and ``tests/test_theory.py``.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "simplified_qlog",
    "update_gd",
    "update_mul",
    "update_signmul",
    "update_madam",
    "quant_error",
    "theoretical_bounds",
    "snap_to_grid",
    "measure_all",
]


def simplified_qlog(key: jax.Array, x: jax.Array, gamma: float) -> jax.Array:
    """Eq. 11: Q(x) = sign(x)·2**(SR(γ·log2|x|)/γ) — no scale, no clamp."""
    mag = jnp.maximum(jnp.abs(x), jnp.finfo(jnp.float32).tiny)
    e = gamma * jnp.log2(mag)
    floor = jnp.floor(e)
    p = jax.random.uniform(key, e.shape, dtype=e.dtype)
    e_sr = floor + (p <= (e - floor)).astype(e.dtype)
    return jnp.sign(x) * jnp.exp2(e_sr / gamma)


def update_gd(w, g, eta):
    """U_GD = W − η∇W."""
    return w - eta * g


def update_mul(w, g, eta):
    """U_MUL (Eq. 6): sign(W) ⊙ 2**(W̃ − η ∇W ⊙ sign(W))."""
    wt = jnp.log2(jnp.maximum(jnp.abs(w), jnp.finfo(jnp.float32).tiny))
    return jnp.sign(w) * jnp.exp2(wt - eta * g * jnp.sign(w))


def update_signmul(w, g, eta):
    """U_signMUL (Lemma 1): only the sign of the gradient."""
    wt = jnp.log2(jnp.maximum(jnp.abs(w), jnp.finfo(jnp.float32).tiny))
    return jnp.sign(w) * jnp.exp2(wt - eta * jnp.sign(g) * jnp.sign(w))


def update_madam(w, g, g2, eta, beta=0.999):
    """Madam (Eq. 9) with second-moment normalization. Returns (w', g2')."""
    g2 = (1.0 - beta) * g * g + beta * g2
    gstar = g * jax.lax.rsqrt(g2 + 1e-30)
    wt = jnp.log2(jnp.maximum(jnp.abs(w), jnp.finfo(jnp.float32).tiny))
    return jnp.sign(w) * jnp.exp2(wt - eta * gstar * jnp.sign(w)), g2


def quant_error(w_new: jax.Array, w_q: jax.Array) -> jax.Array:
    """r_t = ||log2|W_q| − log2|W_new|||² (the paper's §4.2 objective)."""
    tiny = jnp.finfo(jnp.float32).tiny
    d = jnp.log2(jnp.maximum(jnp.abs(w_q), tiny)) - jnp.log2(jnp.maximum(jnp.abs(w_new), tiny))
    return jnp.sum(d * d)


def theoretical_bounds(w, g, eta, gamma) -> Dict[str, jax.Array]:
    """Upper bounds of Theorems 1/2 and Lemma 1 for the given state."""
    d = w.size
    sqrt_d = jnp.sqrt(jnp.asarray(d, jnp.float32))
    tiny = jnp.finfo(jnp.float32).tiny
    gd_inner = jnp.maximum(jnp.abs(w - eta * g), tiny)
    return {
        "gd": sqrt_d / gamma * jnp.linalg.norm(jnp.log2(gd_inner).ravel()),
        "mul": sqrt_d * eta / gamma * jnp.linalg.norm(g.ravel()),
        "signmul": d * eta / gamma,
    }


def snap_to_grid(w: jax.Array, gamma: float) -> jax.Array:
    """Round weights onto the γ log-grid (deterministic)."""
    mag = jnp.maximum(jnp.abs(w), jnp.finfo(jnp.float32).tiny)
    return jnp.sign(w) * jnp.exp2(jnp.round(gamma * jnp.log2(mag)) / gamma)


def measure_all(key: jax.Array, w: jax.Array, g: jax.Array, eta: float,
                gamma: float, g2: jax.Array | None = None) -> Dict[str, jax.Array]:
    """One Fig.-4 measurement: r_t for each rule under Eq.-11 quantization.

    ``w`` is first snapped onto the LNS grid — in real quantized training
    the current weights *are* grid points. That is what separates the
    rules: multiplicative updates move integer exponents by a small known
    fraction (error ∝ η‖∇‖/γ, Thm. 2) while GD's ``W − η∇`` lands at a
    generic point whose log has a uniform fractional part (error grows with
    ‖log₂|W−η∇|‖, Thm. 1).
    """
    w = snap_to_grid(w, gamma)
    if g2 is None:
        g2 = jnp.ones_like(w)
    keys = jax.random.split(key, 4)
    out = {}
    for name, w_new in (
        ("gd", update_gd(w, g, eta)),
        ("mul", update_mul(w, g, eta)),
        ("signmul", update_signmul(w, g, eta)),
        ("madam", update_madam(w, g, g2, eta)[0]),
    ):
        k = keys[("gd", "mul", "signmul", "madam").index(name)]
        out[name] = quant_error(w_new, simplified_qlog(k, w_new, gamma))
    return out
