"""LNS-Madam core: the paper's contribution as composable JAX modules."""
from repro.core.lns import LNSFormat, compute_scale, lns_decode, lns_encode, lns_quantize
from repro.core.quantizer import QuantConfig, backward_quantize, qeinsum, quantize_grads, ste_quantize

__all__ = [
    "LNSFormat",
    "QuantConfig",
    "compute_scale",
    "lns_encode",
    "lns_decode",
    "lns_quantize",
    "qeinsum",
    "ste_quantize",
    "backward_quantize",
    "quantize_grads",
]
