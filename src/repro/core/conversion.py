"""LNS <-> integer (linear) conversion — paper §2.2, §2.3, Appendix B.

The expensive step of an LNS dot product is converting the product exponent
``2**(p/γ)`` to linear form for accumulation. The paper decomposes

    2**(p/γ) = 2**q · 2**(r/γ),   q = p >> b,  r = p & (γ-1),  γ = 2**b

so the conversion is a shift (quotient) plus a γ-entry lookup (remainder),
optionally shrunk further by the *hybrid* Mitchell approximation (App. B):

    2**(r/γ) = 2**(r_M/γ) · 2**(r_L/γ) ≈ 2**(r_M/γ) · (1 + r_L/γ)

with the remainder split into ``b_m`` MSBs (LUT of 2**b_m entries) and
``b_l = b - b_m`` LSBs (Mitchell). ``lut_entries = 2**b_m``; ``lut_entries ==
γ`` recovers the exact conversion and ``lut_entries == 1`` is pure Mitchell.

These functions use the *positive-exponent* convention of the hardware
(value = 2**(+p/γ)); the storage format in :mod:`repro.core.lns` negates
exponents, so call sites offset by the maximum code (offset-binary), exactly
like the RTL datapath.

Both float and bit-exact integer fixed-point flavours are provided; the
Pallas kernels mirror the integer flavour.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "remainder_lut",
    "remainder_lut_int",
    "remainder_lut_neg",
    "remainder_lut_neg_int",
    "remainder_lut_neg_shifted_int",
    "exp2_exact",
    "exp2_hybrid",
    "exp2_exact_fixed",
    "exp2_hybrid_fixed",
    "exp2_neg_exact_fixed",
    "exp2_neg_hybrid_fixed",
    "approx_decode_factor",
]


def _check(gamma: int, lut_entries: int | None = None) -> int:
    if gamma < 1 or gamma & (gamma - 1):
        raise ValueError(f"gamma must be a power of two, got {gamma}")
    b = int(gamma).bit_length() - 1
    if lut_entries is not None:
        if lut_entries < 1 or lut_entries & (lut_entries - 1) or lut_entries > gamma:
            raise ValueError(
                f"lut_entries must be a power of two in [1, gamma], got {lut_entries}"
            )
    return b


def remainder_lut(gamma: int, lut_entries: int | None = None) -> np.ndarray:
    """The γ (or 2**b_m) remainder constants ``2**(i·step/γ)``.

    With ``lut_entries == gamma`` these are the paper's §2.2 constants
    ``2**(i/γ), i in [0, γ)``; with fewer entries they cover the remainder
    MSBs (step = γ / lut_entries).
    """
    b = _check(gamma, lut_entries)
    n = gamma if lut_entries is None else lut_entries
    step = gamma // n
    return np.exp2(np.arange(n) * step / gamma).astype(np.float32)


def remainder_lut_int(gamma: int, frac_bits: int, lut_entries: int | None = None) -> np.ndarray:
    """Fixed-point LUT: ``round(2**(i·step/γ) · 2**frac_bits)`` (int32)."""
    return np.round(remainder_lut(gamma, lut_entries) * (1 << frac_bits)).astype(np.int32)


def exp2_exact(p: jax.Array, gamma: int) -> jax.Array:
    """Exact conversion 2**(p/γ) via quotient shift + remainder LUT (float).

    ``p`` is a non-negative integer exponent array.
    """
    b = _check(gamma)
    p = p.astype(jnp.int32)
    q = p >> b
    r = p & (gamma - 1)
    lut = jnp.asarray(remainder_lut(gamma))
    return jnp.exp2(q.astype(jnp.float32)) * lut[r]


def exp2_hybrid(p: jax.Array, gamma: int, lut_entries: int) -> jax.Array:
    """Hybrid Mitchell/LUT conversion (paper Eq. 16), float flavour.

    2**(p/γ) ≈ 2**q · LUT[r_M] · (1 + r_L/γ).
    """
    b = _check(gamma, lut_entries)
    p = p.astype(jnp.int32)
    q = p >> b
    r = p & (gamma - 1)
    b_l = b - (int(lut_entries).bit_length() - 1)
    r_m = r >> b_l
    r_l = r & ((1 << b_l) - 1)
    lut = jnp.asarray(remainder_lut(gamma, lut_entries))
    mitchell = 1.0 + r_l.astype(jnp.float32) / gamma
    return jnp.exp2(q.astype(jnp.float32)) * lut[r_m] * mitchell


def exp2_exact_fixed(p: jax.Array, gamma: int, frac_bits: int = 16) -> jax.Array:
    """Bit-exact integer datapath: ``(LUT_int[r] << q)`` (int32).

    Mirrors the Fig. 6 shift-then-LUT-multiply order used by the Pallas
    kernel. Result is the linear value in ``frac_bits`` fixed point; callers
    must keep ``q + frac_bits + log2(max LUT) < 31``.
    """
    b = _check(gamma)
    p = p.astype(jnp.int32)
    q = p >> b
    r = p & (gamma - 1)
    lut = jnp.asarray(remainder_lut_int(gamma, frac_bits))
    return jax.lax.shift_left(lut[r], q)


def exp2_hybrid_fixed(p: jax.Array, gamma: int, lut_entries: int, frac_bits: int = 16) -> jax.Array:
    """Bit-exact hybrid datapath (App. B): shift + small LUT + Mitchell add.

    2**(p/γ)·2**F ≈ ((LUT_int[r_M]·(γ + r_L)) >> b) << q — the Mitchell term
    (1 + r_L/γ) is an integer multiply-add followed by the base-factor shift.
    """
    b = _check(gamma, lut_entries)
    p = p.astype(jnp.int32)
    q = p >> b
    r = p & (gamma - 1)
    b_l = b - (int(lut_entries).bit_length() - 1)
    r_m = r >> b_l
    r_l = r & ((1 << b_l) - 1)
    lut = jnp.asarray(remainder_lut_int(gamma, frac_bits, lut_entries))
    v = lut[r_m] * (gamma + r_l)  # frac_bits + b fixed point
    v = jax.lax.shift_right_logical(v, b)
    return jax.lax.shift_left(v, q)


def remainder_lut_neg(gamma: int, lut_entries: int | None = None) -> np.ndarray:
    """Negative-convention constants ``2**(-i·step/γ)`` in (0.5, 1].

    The storage format keeps negated exponents (value = s·2**(-e/γ)), so the
    datapath kernels use these constants with a *right* shift by the
    quotient — the offset-binary mirror of the RTL's left shift.
    """
    b = _check(gamma, lut_entries)
    n = gamma if lut_entries is None else lut_entries
    step = gamma // n
    return np.exp2(-np.arange(n) * step / gamma).astype(np.float32)


def remainder_lut_neg_int(gamma: int, frac_bits: int, lut_entries: int | None = None) -> np.ndarray:
    """Fixed-point negative LUT: ``round(2**(-i·step/γ) · 2**frac_bits)``."""
    return np.round(remainder_lut_neg(gamma, lut_entries) * (1 << frac_bits)).astype(np.int32)


def exp2_neg_exact_fixed(m: jax.Array, gamma: int, frac_bits: int = 16) -> jax.Array:
    """Bit-exact negative-exponent datapath: ``LUTneg_int[r] >> q`` (int32).

    ``m`` is the non-negative *negated* product exponent (value 2**(-m/γ)).
    The result is the linear value in ``frac_bits`` fixed point; quotients
    beyond ``frac_bits`` underflow to 0 exactly like a fixed-point RTL
    datapath drops sub-LSB products.
    """
    b = _check(gamma)
    m = m.astype(jnp.int32)
    q = jnp.minimum(m >> b, 31)
    r = m & (gamma - 1)
    lut = jnp.asarray(remainder_lut_neg_int(gamma, frac_bits))
    return jax.lax.shift_right_logical(lut[r], q)


def remainder_lut_neg_shifted_int(gamma: int, frac_bits: int,
                                  lut_entries: int) -> np.ndarray:
    """Offset LUT for the negative-convention hybrid: entry i holds
    ``round(2**(-(i+1)·step/γ) · 2**frac_bits)`` — one LSB-interval beyond
    the plain negative LUT, so Mitchell applies to a *positive* fraction."""
    b = _check(gamma, lut_entries)
    step = gamma // lut_entries
    return np.round(
        np.exp2(-(np.arange(lut_entries) + 1.0) * step / gamma)
        * (1 << frac_bits)).astype(np.int32)


def exp2_neg_hybrid_fixed(m: jax.Array, gamma: int, lut_entries: int, frac_bits: int = 16) -> jax.Array:
    """Bit-exact hybrid (App. B) in the negative convention.

    Mitchell's ``2**t ≈ 1+t`` only holds for t in [0,1), so the negated LSB
    remainder is rewritten through its complement:

        2**(-r_L/γ) = 2**(-2^b_l/γ) · 2**((2^b_l - r_L)/γ)
                    ≈ 2**(-2^b_l/γ) · (1 + (2^b_l - r_L)/γ)

    The constant folds into a one-interval-shifted LUT; the datapath is an
    integer multiply-add, base-factor shift, then the quotient right-shift —
    the exact mirror of the RTL's positive-convention datapath, same <=6.2%
    worst-case Mitchell error.
    """
    b = _check(gamma, lut_entries)
    m = m.astype(jnp.int32)
    q = jnp.minimum(m >> b, 31)
    r = m & (gamma - 1)
    b_l = b - (int(lut_entries).bit_length() - 1)
    r_m = r >> b_l
    r_l = r & ((1 << b_l) - 1)
    lut = jnp.asarray(remainder_lut_neg_shifted_int(gamma, frac_bits, lut_entries))
    v = lut[r_m] * (gamma + (1 << b_l) - r_l)  # frac_bits + b fixed point
    v = jax.lax.shift_right_logical(v, b)
    return jax.lax.shift_right_logical(v, q)


def approx_decode_factor(r: jax.Array, gamma: int, lut_entries: int) -> jax.Array:
    """Multiplicative error factor of the hybrid conversion per remainder bin.

    Returns ``approx(2**(r/γ)) / 2**(r/γ)`` for remainder ``r`` — used by the
    approximation-aware-training simulation, which groups dot-product terms
    by remainder bin and applies the bin's error factor (App. §.4).
    """
    b = _check(gamma, lut_entries)
    r = r.astype(jnp.int32)
    b_l = b - (int(lut_entries).bit_length() - 1)
    r_m = r >> b_l
    r_l = r & ((1 << b_l) - 1)
    lut = jnp.asarray(remainder_lut(gamma, lut_entries))
    approx = lut[r_m] * (1.0 + r_l.astype(jnp.float32) / gamma)
    exact = jnp.exp2(r.astype(jnp.float32) / gamma)
    return approx / exact
