"""Logical axis assignment for parameter / optimizer / cache pytrees.

Maps each leaf (by its tree path) to a tuple of logical axis names, then
resolves them against the active mesh + rules into NamedShardings. Stacked
(scanned) period parameters get a leading "stack" axis; LNSWeight leaves
shard the packed words like the dense weight and the scale with its size-1
axis unsharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.core.lns import LNSWeight, is_lns_weight
from repro.distributed.sharding import logical_sharding, spec_for

__all__ = ["params_logical_axes", "params_shardings", "batch_shardings",
           "cache_logical_axes", "tree_shardings", "opt_logical_axes"]

# leaf-name (with optional parent context) -> logical axes of the 2D core
_BY_NAME: Dict[str, Tuple[Optional[str], ...]] = {
    "tok": ("vocab", "embed"),
    "head": ("embed", "vocab"),
    "wq": ("embed", "qkv_out"),
    "wk": ("embed", "qkv_out"),
    "wv": ("embed", "qkv_out"),
    "wo": ("qkv_out", "embed"),
    "bq": ("qkv_out",),
    "bk": ("qkv_out",),
    "bv": ("qkv_out",),
    "q_down": ("embed", None),
    "q_up": (None, "qkv_out"),
    "kv_down": ("embed", None),
    "kv_up": (None, "qkv_out"),
    "up": ("embed", "mlp"),
    "gate": ("embed", "mlp"),
    "down": ("mlp", "embed"),
    "router": ("embed", None),
    "w_up": ("experts", "embed", "moe_ff"),
    "w_gate": ("experts", "embed", "moe_ff"),
    "w_down": ("experts", "moe_ff", "embed"),
    "z_proj": ("embed", "ssm_inner"),
    "x_proj": ("embed", "ssm_inner"),
    "b_proj": ("embed", None),
    "c_proj": ("embed", None),
    "dt_proj": ("embed", None),
    "out_proj": ("ssm_inner", "embed"),
    "conv_wx": (None, "ssm_inner"),
    "norm": ("ssm_inner",),
    "wr": ("embed", "ssm_inner"),
    "wg": ("embed", "ssm_inner"),
    "ck": ("embed", "mlp"),
    "cv": ("mlp", "embed"),
    "cr": ("embed", None),
    "lora_a": ("embed", "lora"),
    "lora_b": ("lora", "qkv_out"),
    "proj": (None, "embed"),        # mtp combiner
}

# rwkv overrides (wk/wv/wo collide with attention names)
_RWKV_NAMES = {
    "wk": ("embed", "ssm_inner"),
    "wv": ("embed", "ssm_inner"),
    "wo": ("ssm_inner", "embed"),
}

# serving-forward overrides: the second GEMM of each column-parallel pair
# keeps its contraction dim replicated so the contraction never psums over a
# shard (psum reorders accumulation and breaks token-for-token equality with
# the single-device engine; the all-gather epilogue on the activation side is
# the serving rules' job — see ``sharding.serving_rules``).
_SERVING_NAMES = {
    "wo": (None, "embed"),
    "down": (None, "embed"),
    "out_proj": (None, "embed"),
    "cv": (None, "embed"),
}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(f"[{k.idx}]")
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return tuple(out)


def _leaf_axes(path_names: Tuple[str, ...], ndim: int,
               serving: bool = False) -> Tuple[Optional[str], ...]:
    name = path_names[-1] if path_names else ""
    in_rwkv = "rwkv" in path_names
    table = dict(_BY_NAME)
    if in_rwkv:
        table.update(_RWKV_NAMES)
    if serving:
        table.update(_SERVING_NAMES)
    axes = table.get(name)
    if axes is None:
        axes = (None,) * ndim  # norms / scalars / unknown -> replicated
    # stacked (scanned) leading axis
    if ndim > len(axes):
        axes = ("stack",) * (ndim - len(axes)) + tuple(axes)
    elif ndim < len(axes):
        axes = tuple(axes[-ndim:]) if ndim else ()
    return tuple(axes)


def params_logical_axes(params, serving: bool = False) -> Any:
    """Tree of logical-axes tuples matching ``params`` (LNSWeight-aware).

    ``serving=True`` applies the serving-forward per-leaf overrides (second
    GEMMs keep their contraction dim replicated — see ``_SERVING_NAMES``)."""

    def visit(path, leaf):
        names = _path_names(path)
        if is_lns_weight(leaf):
            axes = _leaf_axes(names, leaf.packed.ndim, serving)
            scale_axes = tuple(a if leaf.scale.shape[i] != 1 else None
                               for i, a in enumerate(axes)) \
                if leaf.scale.ndim == leaf.packed.ndim else (None,) * leaf.scale.ndim
            # keep the leaf's fmt aux so the axes/shardings tree structure
            # matches the params tree exactly (jit in_shardings prefix match)
            return LNSWeight(packed=axes, scale=scale_axes, fmt=leaf.fmt)
        return _leaf_axes(names, getattr(leaf, "ndim", 0), serving)

    return jax.tree_util.tree_map_with_path(visit, params,
                                            is_leaf=is_lns_weight)


def tree_shardings(axes_tree, mesh: Mesh, rules=None):
    """Resolve a logical-axes tree into NamedShardings."""
    def one(axes):
        return logical_sharding(axes, mesh, rules) or NamedSharding(
            mesh, spec_for((), mesh, rules))

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(one, axes_tree, is_leaf=is_axes_leaf)


def params_shardings(params, mesh: Mesh, rules=None, serving: bool = False):
    return tree_shardings(params_logical_axes(params, serving), mesh, rules)


def batch_shardings(batch, mesh: Mesh, rules=None):
    """Batch tensors shard over ("pod","data") on the batch axis."""
    def one(leaf):
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        return logical_sharding(axes, mesh, rules)
    return jax.tree.map(one, batch)


def opt_logical_axes(params, opt_state):
    """Axes for a MadamState: g2 mirrors the weight (factored leaves get the
    row/col marginals of the weight's axes); count replicated."""
    p_axes = params_logical_axes(params)

    def leaf_axes(axes, g2_leaf):
        code_axes = axes.packed if isinstance(axes, LNSWeight) else axes
        if isinstance(g2_leaf, dict):  # factored {r, c}
            return {"r": tuple(code_axes[:-1]),
                    "c": tuple(code_axes[:-2]) + tuple(code_axes[-1:])}
        return tuple(code_axes)

    flat_axes, treedef = jax.tree_util.tree_flatten(
        p_axes, is_leaf=lambda x: isinstance(x, LNSWeight) or (
            isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                         for a in x)))
    flat_g2 = treedef.flatten_up_to(opt_state.g2)
    g2_axes = jax.tree_util.tree_unflatten(
        treedef, [leaf_axes(a, g) for a, g in zip(flat_axes, flat_g2)])
    return type(opt_state)(g2=g2_axes, count=())


# decode-cache leaves by name. k/v carry both "kv_seq" and "kv_heads": under
# the default (training) rules both map to "model" and spec_for's first-wins
# dedup keeps the split-KV layout; serving rules set kv_seq -> None so the
# same annotation becomes head-sharded (pools likewise, minus the batch dim).
_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "k_scale": ("batch", "kv_seq", "kv_heads", None),
    "v_scale": ("batch", "kv_seq", "kv_heads", None),
    "kp": (None, None, "kv_heads", None),
    "vp": (None, None, "kv_heads", None),
    "kp_scale": (None, None, "kv_heads", None),
    "vp_scale": (None, None, "kv_heads", None),
    "c_kv": ("batch", "kv_seq", None),
    "k_rope": ("batch", "kv_seq", None),
    "ssm": ("batch", "act_heads", None, None),
    "S": ("batch", "act_heads", None, None),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_b": ("batch", None, None),
    "conv_c": ("batch", None, None),
    "shift_tm": ("batch", None),
    "shift_cm": ("batch", None),
    "idx": ("batch",),  # per-slot length cursor rides with its cache rows
}


def cache_logical_axes(caches) -> Any:
    def visit(path, leaf):
        names = _path_names(path)
        axes = _CACHE_AXES.get(names[-1], (None,) * leaf.ndim)
        if leaf.ndim > len(axes):
            axes = ("stack",) * (leaf.ndim - len(axes)) + tuple(axes)
        return tuple(axes)
    return jax.tree_util.tree_map_with_path(visit, caches)
