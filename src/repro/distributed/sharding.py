"""Logical-axis sharding (MaxText-style named rules).

Model code annotates tensors with *logical* axis names
(``shard(x, "batch", "seq", "embed")``); a rules table maps logical names to
mesh axes. The same model lowers on the single-pod ``(data, model)`` mesh,
the multi-pod ``(pod, data, model)`` mesh, or no mesh at all (rules become
no-ops) — the per-arch configs only override rule entries, never model code.

GSPMD inserts the collectives implied by constraint changes (all-gather for
FSDP'd weights entering a layer, all-to-all for resharded activations), so
these rules are also the lever the §Perf hillclimb turns.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["LOGICAL_RULES", "shard_ctx", "shard", "logical_sharding",
           "current_mesh", "spec_for", "serving_rules", "model_axis_size"]

AxisVal = Union[None, str, Tuple[str, ...]]

# Default logical->mesh rules. Tuples mean "shard over these axes jointly";
# axes not present in the active mesh are dropped at resolve time, so the
# same table serves the single-pod and multi-pod meshes.
LOGICAL_RULES: Dict[str, AxisVal] = {
    # activations
    "batch": ("pod", "data"),
    "batch_full": ("pod", "data", "model"),  # attention batch reshard for
                                             # non-divisible head counts
    "seq": None,                 # seq replicated by default
    "seq_shard": "model",        # sequence-parallel attention (non-/16 heads)
    "kv_seq": "model",           # split-KV decode; batch-1 long-context
                                 # cells override to ("data","model")
    "attn_out": "model",         # attention output entering wo: head-sharded
                                 # (row-parallel) in training; serving rules
                                 # override to None (all-gather epilogue) so
                                 # the replicated wo contraction stays bitwise
                                 # equal to single-device
    "embed": None,
    "act_ff": "model",
    "act_heads": "model",
    # weights
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv_out": "model",
    "ff_fsdp": "data",           # FSDP axis for huge (MoE) weight tensors
    "experts": "model",
    "moe_ff": None,
    "lora": None,
    "ssm_inner": "model",
    "stack": None,               # scanned-layer axis
    "replicated": None,
}

_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def _current_rules() -> Dict[str, AxisVal]:
    return getattr(_ctx, "rules", LOGICAL_RULES)


@contextlib.contextmanager
def shard_ctx(mesh: Optional[Mesh], overrides: Optional[Dict[str, AxisVal]] = None):
    """Activate a mesh + rule overrides for ``shard`` calls in scope."""
    prev_mesh = getattr(_ctx, "mesh", None)
    prev_rules = getattr(_ctx, "rules", LOGICAL_RULES)
    _ctx.mesh = mesh
    rules = dict(LOGICAL_RULES)
    if overrides:
        rules.update(overrides)
    _ctx.rules = rules
    try:
        yield
    finally:
        _ctx.mesh = prev_mesh
        _ctx.rules = prev_rules


def _resolve(name: Optional[str], mesh: Mesh, rules: Dict[str, AxisVal]):
    if name is None:
        return None
    if name not in rules:
        raise KeyError(f"unknown logical axis {name!r}")
    val = rules[name]
    if val is None:
        return None
    axes = (val,) if isinstance(val, str) else tuple(val)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def model_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Size of the ``model`` mesh axis (1 without a mesh / without the axis)."""
    mesh = mesh or current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def serving_rules(cfg, mesh: Optional[Mesh] = None) -> Dict[str, AxisVal]:
    """Logical-rule overrides for the mesh-native serving forward.

    Training shards contraction dims and eats the psum reorderings; serving
    must stay *token-for-token equal* to the single-device engine, so the
    table only keeps layouts whose collectives are bitwise exact on the
    host platform:

    - activations replicate on batch (continuous-batching slots are small
      and ``device_put`` rejects uneven batch shards) and on sequence;
    - column-parallel weights (output dim sharded) are kept only where the
      dim divides the ``model`` axis; the paired second GEMM contracts over
      a replicated axis — ``attn_out``/``act_ff`` resolve to ``None`` so the
      constraint *is* the explicit all-gather epilogue, and the per-leaf
      serving overrides in ``params_sharding`` replicate wo/down rows;
    - contraction over a sharded dim (psum) never appears on the forward.

    ``cfg`` is duck-typed (any object with ``num_heads`` / ``num_kv_heads``
    / ``d_ff`` / ``num_experts``), keeping this module import-light.
    """
    m = model_axis_size(mesh)
    heads_ok = m > 1 and getattr(cfg, "num_heads", 0) % m == 0
    kv_ok = heads_ok and getattr(cfg, "num_kv_heads", 0) % m == 0
    mlp_ok = m > 1 and getattr(cfg, "d_ff", 0) % m == 0
    moe_ok = m > 1 and getattr(cfg, "num_experts", 0) % m == 0
    on = lambda ok: "model" if ok else None
    return {
        "batch": None, "batch_full": None, "seq": None, "seq_shard": None,
        "kv_seq": None, "act_ff": None, "attn_out": None, "vocab": None,
        "ff_fsdp": None, "ssm_inner": None, "moe_ff": None,
        "heads": on(heads_ok),
        "act_heads": on(kv_ok),
        "kv_heads": on(kv_ok),
        "qkv_out": on(kv_ok),
        "mlp": on(mlp_ok),
        "experts": on(moe_ok),
    }


def spec_for(names: Sequence[Optional[str]], mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, AxisVal]] = None) -> P:
    """PartitionSpec for a tuple of logical axis names.

    ``rules`` (if given) are *overrides* merged over the defaults/context.
    A mesh axis may appear in at most one PartitionSpec entry; when two
    logical names resolve to the same mesh axis the earlier dimension wins
    and the later one drops the duplicate (first-wins), so composite
    annotations like ``("batch", "kv_seq", "kv_heads")`` stay valid under
    rule tables that map several names onto ``model``.
    """
    mesh = mesh or current_mesh()
    if rules is not None:
        rules = {**_current_rules(), **rules}
    else:
        rules = _current_rules()
    if mesh is None:
        return P()
    used: set = set()
    entries = []
    for n in names:
        axes = _resolve(n, mesh, rules)
        if axes is None:
            entries.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            entries.append(None)
        else:
            entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def logical_sharding(names: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[Dict[str, AxisVal]] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(names, mesh, rules))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the logical axes ``names`` (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(names, mesh)))
