from repro.distributed.sharding import (LOGICAL_RULES, shard, shard_ctx,
                                        logical_sharding, current_mesh)

__all__ = ["LOGICAL_RULES", "shard", "shard_ctx", "logical_sharding",
           "current_mesh"]
