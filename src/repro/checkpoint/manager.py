"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step::

    <dir>/step_00000042/
        manifest.json      # step, mesh shape, data cursor, leaf index
        <leaf-key>.npy     # one file per pytree leaf (per-host shards on a
                           # real cluster; whole arrays on this single host)
    <dir>/LATEST           # atomic pointer, written last

Properties exercised by the tests:
  * atomic commit — a crash mid-save never corrupts LATEST (tmp dir +
    rename, pointer written after the payload)
  * async — ``save`` returns immediately; ``wait()`` joins the writer
  * keep-k garbage collection
  * **elastic restore** — arrays are placed with whatever shardings the
    *new* mesh prescribes, so a job restarted on a different device count
    resumes from the same manifest (DESIGN.md §8); sharded leaves go
    straight from the mmap'd file into their NamedSharding, one slice per
    shard, with no host-gathered intermediate
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, data_cursor: int = 0,
             extra: Optional[Dict[str, Any]] = None, async_: bool = True):
        """Snapshot ``state`` (device_get happens before returning so the
        caller may mutate/donate buffers; file IO runs in the background)."""
        self.wait()
        flat = _flatten(state)
        manifest = {
            "step": int(step),
            "data_cursor": int(data_cursor),
            "keys": sorted(flat.keys()),
            "extra": extra or {},
            "device_count": jax.device_count(),
        }

        def write():
            try:
                final = os.path.join(self.dir, f"step_{step:08d}")
                tmp = final + ".tmp"
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                for k, v in flat.items():
                    np.save(os.path.join(tmp, k.replace("/", "_") + ".npy"),
                            v, allow_pickle=False)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)  # atomic commit
                latest_tmp = os.path.join(self.dir, "LATEST.tmp")
                with open(latest_tmp, "w") as f:
                    f.write(os.path.basename(final))
                os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip().split("_")[-1])

    def manifest(self, step: int) -> Dict[str, Any]:
        with open(os.path.join(self.dir, f"step_{step:08d}",
                               "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, like, *,
                shardings=None):
        """Rebuild a pytree shaped like ``like`` from the snapshot.

        ``shardings``: optional matching tree of NamedShardings for the
        *current* mesh — this is the elastic path: the saved arrays are
        placed onto whatever device topology is alive now.
        """
        self.wait()
        d = os.path.join(self.dir, f"step_{step:08d}")
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        out = []
        for (path, leaf), sh in zip(paths, shard_leaves):
            key = _SEP.join(
                str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                for k in path).replace("/", "_")
            fname = os.path.join(d, key + ".npy")
            if sh is not None:
                # straight-to-shard placement: mmap the file and let each
                # addressable shard slice (and cast) only its own window —
                # the host-gathered full-size intermediate never exists, so
                # a packed LNSWeight pool lands in its NamedSharding at
                # shard-local memory cost even when the logical array is
                # the whole flagship layer
                arr = np.load(fname, mmap_mode="r")
                dt = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
                out.append(jax.make_array_from_callback(
                    arr.shape, sh,
                    lambda idx, a=arr, t=dt: np.asarray(a[idx], t)))
            else:
                arr = np.load(fname)
                if hasattr(leaf, "dtype"):
                    arr = arr.astype(leaf.dtype)
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like, **kw):
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        return step, self.restore(step, like, **kw)
