"""Server-sent-events framing: encode on the gateway side, incremental
parse on the client side (load generator, CI smoke, tests).

Only the ``data:`` field is used — one JSON payload per event, terminated
by a blank line, with the OpenAI-style ``data: [DONE]`` sentinel closing a
completion stream.
"""
from __future__ import annotations

from typing import List, Optional

__all__ = ["DONE", "encode_event", "SSEParser"]

DONE = "[DONE]"


def encode_event(payload: str) -> bytes:
    """One SSE frame carrying ``payload`` as its data field."""
    return f"data: {payload}\n\n".encode("utf-8")


class SSEParser:
    """Incremental SSE decoder: feed raw socket bytes, get back the
    completed ``data:`` payloads (multi-line data fields joined per the
    spec; comment/id/event fields ignored).

    Line-based per the spec — a line ends at CRLF, LF, or CR, and a
    blank line dispatches the event — so mixed framing from a foreign
    server (``--target``) parses correctly; a naive double-newline
    search would merge adjacently-framed events or stall on LF + CRLF."""

    def __init__(self):
        self._buf = b""
        self._data: List[str] = []

    def feed(self, chunk: bytes) -> List[str]:
        self._buf += chunk
        out: List[str] = []
        while True:
            line = self._next_line()
            if line is None:
                return out
            if not line:                       # blank line: dispatch
                if self._data:
                    out.append("\n".join(self._data))
                    self._data = []
                continue
            text = line.decode("utf-8", "replace")
            if text.startswith("data:"):
                self._data.append(text[5:].lstrip(" "))

    def _next_line(self) -> Optional[bytes]:
        """Pop one complete line (terminator stripped); None if the
        buffer holds no full line yet."""
        i_n, i_r = self._buf.find(b"\n"), self._buf.find(b"\r")
        if i_r >= 0 and (i_n < 0 or i_r < i_n):
            if i_r == len(self._buf) - 1:
                return None  # CR at the edge: CRLF may be split mid-chunk
            end = i_r + 2 if self._buf[i_r + 1] == 0x0A else i_r + 1
            line, self._buf = self._buf[:i_r], self._buf[end:]
            return line
        if i_n >= 0:
            line, self._buf = self._buf[:i_n], self._buf[i_n + 1:]
            return line
        return None
