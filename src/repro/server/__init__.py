"""Online serving gateway over the continuous-batching engine.

Modules
-------
sampling  — ``SamplingParams`` + the on-device batch sampler the engine
            fuses into its jitted decode step
protocol  — OpenAI-style JSON request/response schema for the HTTP API
sse       — server-sent-events framing (encode + incremental parser)
driver    — ``EngineDriver``: the thread that owns the engine, with a
            thread-safe submit/abort mailbox and admission control
app       — the asyncio HTTP front-end (``Gateway``)

``driver`` and ``app`` are imported lazily: ``serving.engine`` imports
``repro.server.sampling`` for the sampler, and an eager import here
would close the cycle back through ``driver -> serving``.
"""
from repro.server.sampling import GREEDY, SamplingParams, sample_logits

__all__ = ["GREEDY", "SamplingParams", "sample_logits",
           "EngineDriver", "Gateway"]

_LAZY = {"EngineDriver": "repro.server.driver", "Gateway": "repro.server.app"}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
