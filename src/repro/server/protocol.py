"""OpenAI-style JSON schema for the gateway's completion API.

The reproduction has no tokenizer, so ``prompt`` is a list of token ids
(or a list of per-position id rows for multi-codebook models) and
responses carry ``token_ids`` instead of text. Everything else follows
the ``/v1/completions`` shape: ``max_tokens``, ``temperature`` /
``top_k`` / ``top_p`` / ``seed`` / ``stop`` sampling knobs, ``stream``
for SSE, and per-choice ``finish_reason`` ("stop" / "length" /
"capacity" / "aborted").
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.server.sampling import SamplingParams

__all__ = ["ProtocolError", "CompletionRequest", "parse_completion",
           "completion_body", "chunk_body", "error_body"]


class ProtocolError(ValueError):
    """Client error -> HTTP status (400 unless told otherwise)."""

    def __init__(self, message: str, status: int = 400):
        super().__init__(message)
        self.status = status


@dataclasses.dataclass(frozen=True)
class CompletionRequest:
    prompt: List
    max_tokens: int
    sampling: SamplingParams
    stream: bool = False


def _require_int(obj: Dict, key: str, default, *, lo=None, hi=None):
    val = obj.get(key, default)
    if isinstance(val, bool) or not isinstance(val, (int, float)) \
            or int(val) != val:
        raise ProtocolError(f"{key!r} must be an integer, got {val!r}")
    val = int(val)
    if lo is not None and val < lo:
        raise ProtocolError(f"{key!r} must be >= {lo}, got {val}")
    if hi is not None and val > hi:
        raise ProtocolError(f"{key!r} must be <= {hi}, got {val}")
    return val


def _token_list(val: Any, what: str) -> List:
    if not isinstance(val, list) or not val:
        raise ProtocolError(f"{what} must be a non-empty list of token ids")
    if all(isinstance(t, int) and not isinstance(t, bool) for t in val):
        return val
    # multi-codebook prompts: one row of ids per position
    if all(isinstance(row, list) and row
           and all(isinstance(t, int) and not isinstance(t, bool)
                   for t in row) for row in val):
        width = len(val[0])
        if any(len(row) != width for row in val):
            raise ProtocolError(f"{what} codebook rows must share one width")
        return val
    raise ProtocolError(f"{what} must hold token ids (ints or int rows)")


def parse_completion(body: bytes) -> CompletionRequest:
    """Validate a ``POST /v1/completions`` body; raises ProtocolError."""
    try:
        obj = json.loads(body or b"")
    except json.JSONDecodeError as e:
        raise ProtocolError(f"request body is not valid JSON: {e}") from None
    if not isinstance(obj, dict):
        raise ProtocolError("request body must be a JSON object")

    prompt = _token_list(obj.get("prompt"), "'prompt'")
    max_tokens = _require_int(obj, "max_tokens", 16, lo=1)
    temperature = obj.get("temperature", 0.0)
    top_p = obj.get("top_p", 1.0)
    if not isinstance(temperature, (int, float)) or isinstance(temperature, bool):
        raise ProtocolError(f"'temperature' must be a number, got {temperature!r}")
    if not isinstance(top_p, (int, float)) or isinstance(top_p, bool):
        raise ProtocolError(f"'top_p' must be a number, got {top_p!r}")
    top_k = _require_int(obj, "top_k", 0, lo=0)
    seed = _require_int(obj, "seed", 0)
    stop = obj.get("stop", [])
    if stop is None:
        stop = []
    if isinstance(stop, int) and not isinstance(stop, bool):
        stop = [stop]
    if not isinstance(stop, list) or any(
            isinstance(t, bool) or not isinstance(t, int) for t in stop):
        raise ProtocolError("'stop' must be a token id or list of token ids")
    stream = obj.get("stream", False)
    if not isinstance(stream, bool):
        raise ProtocolError(f"'stream' must be a boolean, got {stream!r}")
    try:
        sampling = SamplingParams(temperature=float(temperature),
                                  top_k=top_k, top_p=float(top_p),
                                  seed=seed, stop=frozenset(stop))
    except ValueError as e:
        raise ProtocolError(str(e)) from None
    return CompletionRequest(prompt=prompt, max_tokens=max_tokens,
                             sampling=sampling, stream=stream)


# ---------------------------------------------------------------------------
# response bodies


def _choice(token_ids: List, finish_reason: Optional[str],
            delta: bool) -> Dict:
    key = "delta" if delta else "token_ids"
    val = {"token_ids": token_ids} if delta else token_ids
    return {"index": 0, key: val, "finish_reason": finish_reason}


def completion_body(rid: int, model: str, prompt_tokens: int,
                    token_ids: List, finish_reason: str,
                    spec: Optional[dict] = None) -> str:
    """Terminal unary body. ``spec`` (when the engine speculated for this
    request) lands under ``usage.speculation`` — cycles the request rode,
    draft tokens scored for it, and how many the verify pass accepted."""
    usage = {"prompt_tokens": prompt_tokens,
             "completion_tokens": len(token_ids),
             "total_tokens": prompt_tokens + len(token_ids)}
    if spec is not None:
        usage["speculation"] = spec
    return json.dumps({
        "id": f"cmpl-{rid}", "object": "text_completion", "model": model,
        "choices": [_choice(token_ids, finish_reason, delta=False)],
        "usage": usage,
    })


def chunk_body(rid: int, model: str, token_ids: List,
               finish_reason: Optional[str] = None) -> str:
    """One SSE chunk: the freshly produced token(s), finish_reason on the
    terminal chunk only."""
    return json.dumps({
        "id": f"cmpl-{rid}", "object": "text_completion.chunk",
        "model": model,
        "choices": [_choice(token_ids, finish_reason, delta=True)],
    })


def error_body(message: str, status: int) -> str:
    kind = {429: "rate_limit_exceeded", 503: "server_unavailable",
            404: "not_found"}.get(status, "invalid_request_error")
    return json.dumps({"error": {"message": message, "type": kind,
                                 "code": status}})
