"""Engine driver thread: the bridge between the asyncio gateway and the
synchronous continuous-batching engine.

The engine is single-threaded by construction (jit caches, host-side slot
mirrors), so exactly one thread may touch it. ``EngineDriver`` owns that
thread and exposes a thread-safe surface:

- ``submit()`` / ``abort()`` post commands to a FIFO **mailbox**; the
  driver drains it between engine steps, so commands land at step
  granularity (an abort can catch a request mid-queue, mid-prefill —
  admitted but not yet decoded — or mid-decode).
- **admission control**: at most ``max_inflight`` requests may be live
  (queued + running). ``submit()`` refuses above that watermark and the
  gateway answers 429 — the mailbox never becomes an unbounded buffer in
  front of the bounded engine queue.
- **streaming**: the engine's ``token_sink`` / ``finish_sink`` fire inside
  the driver thread; the driver routes them to the per-request ``sink``
  callables handed to ``submit()``. Sinks must be thread-safe (the
  gateway uses ``loop.call_soon_threadsafe`` into per-request asyncio
  queues) and fast — they run on the decode hot path.
- ``stats()`` returns a snapshot (occupancy counters + the rolling
  latency summary) refreshed once per loop iteration.

Events a sink receives: ``("token", tok)`` per generated token and one
terminal ``("finish", reason, token_list | None[, spec_dict])`` with
reason in ``{"stop", "length", "capacity", "aborted", "error"}``. The
optional 4th element carries the request's speculative-decoding usage
(cycles/drafted/accepted) when the engine speculated for it; consumers
index it defensively (``event[3] if len(event) > 3 else None``) —
internal error paths still emit bare 3-tuples.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Optional, Sequence

from repro.kernels import dispatch
from repro.obs.prom import Histogram, render_prometheus
from repro.serving.engine import Engine
from repro.serving.metrics import summarize
from repro.serving.request import Request
from repro.server.sampling import SamplingParams

__all__ = ["EngineDriver"]

Sink = Callable[[tuple], None]


class EngineDriver:
    def __init__(self, engine: Engine, *, max_inflight: int = 64,
                 poll_s: float = 0.02, metrics_window: int = 4096):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self._engine = engine
        self._max_inflight = max_inflight
        self._poll_s = poll_s
        self._mail: "queue.Queue[tuple]" = queue.Queue()
        self._sinks: Dict[int, Sink] = {}      # driver thread only
        self._lock = threading.Lock()
        self._rids = itertools.count()
        self._inflight = 0
        self._aborted_total = 0
        self._completed_total = 0
        self._errors = 0
        self._metrics = deque(maxlen=metrics_window)
        # lifetime latency histograms for the Prometheus exposition —
        # fed per *finished request* (off the decode hot path), never
        # windowed, so scrape deltas are monotone
        self._hists = {
            "ttft_seconds": Histogram(
                "ttft_seconds", "Time to first token (arrival -> first "
                "token, queueing included)."),
            "tpot_seconds": Histogram(
                "tpot_seconds", "Time per output token over the decode "
                "phase."),
            "queue_wait_seconds": Histogram(
                "queue_wait_seconds", "Arrival -> slot admission."),
        }
        self._stats: Dict[str, Any] = {}
        self._t_start = time.monotonic()
        self._stopping = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="engine-driver", daemon=True)
        engine.token_sink = self._on_token
        engine.finish_sink = self._on_finish
        # seed the snapshot so stats() is complete before the loop's
        # first iteration (a /metrics probe can land that early)
        self._refresh_stats()

    # ------------------------------------------------------------------
    # public surface (any thread)

    def start(self) -> "EngineDriver":
        self._thread.start()
        return self

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._stopping.is_set()

    def submit(self, prompt: Sequence, max_new_tokens: int, *,
               sampling: Optional[SamplingParams] = None,
               eos_id=None, sink: Sink) -> Optional[int]:
        """Enqueue a request; returns its rid, or None when the inflight
        watermark is hit (gateway backpressure — answer 429).

        Raises ValueError for requests the engine can never host (prompt
        longer than the cache / page pool, or a prompt whose rank / row
        width doesn't fit the model) — a 400, not backpressure."""
        eng = self._engine
        eng.validate(prompt, max_new_tokens)
        if not self.alive:
            return None
        with self._lock:
            if self._inflight >= self._max_inflight:
                return None
            self._inflight += 1
            rid = next(self._rids)
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      arrival=eng.now(), eos_id=eos_id, sampling=sampling)
        req._prevalidated = True  # validated above; skip the re-scan
        self._mail.put(("submit", req, sink))
        if not self.alive:
            # raced death: the put may have landed after the loop's (or
            # shutdown's) final drain — on both the shutdown() path and
            # the fatal-step path _stopping is set before that drain, so
            # re-checking alive here catches every ordering. Nobody else
            # will read the mailbox now; fail the submit rather than
            # hang the connection (idempotent: queue.get is atomic, so
            # whichever drain got the command first fires the sink)
            self._fail_pending()
        return rid

    def abort(self, rid: int) -> None:
        """Request cancellation; resolved in the driver thread at the next
        step boundary (idempotent, unknown rids ignored)."""
        self._mail.put(("abort", rid))

    def stats(self) -> Dict[str, Any]:
        """Latest per-loop snapshot + rolling latency summary. The
        summary covers the metrics *window*, so its rate denominators use
        the window's own span (engine clock) — dividing window tokens by
        process lifetime would decay tokens_per_s toward zero on a
        long-running server. Lifetime totals are separate counters."""
        with self._lock:
            out = dict(self._stats)
            mets = list(self._metrics)
            out["inflight"] = self._inflight
            out["aborted_total"] = self._aborted_total
            out["completed_total"] = self._completed_total
            out["errors"] = self._errors
        if mets:
            wall = (max(m.t_finish for m in mets)
                    - min(m.arrival for m in mets))
        else:
            wall = time.monotonic() - self._t_start
        out.update(summarize(mets, wall))
        return out

    def health(self) -> Dict[str, Any]:
        """Readiness context for ``GET /health``: what this node is
        actually serving with — kernel backend, mesh shape, KV layout
        policy, spec config, and the loaded checkpoint identity."""
        eng = self._engine
        out: Dict[str, Any] = {
            "status": "ok" if self.alive else "stopping",
            "backend": dispatch.resolve_backend(None),
            "interpret": dispatch.resolve_interpret(None),
            "arch": getattr(eng.cfg, "name", None),
            "checkpoint_id": eng.checkpoint_id,
            "num_slots": eng.num_slots,
            "max_len": eng.max_len,
            "max_inflight": self._max_inflight,
            "paged": bool(eng.page_size),
        }
        if eng.page_size:
            out["page_size"] = eng.page_size
            out["num_pages"] = eng.num_pages
            out["alloc_policy"] = eng.alloc_policy
            out["prefix_cache"] = eng._prefix_ok
        mesh = getattr(eng, "_mesh", None)
        if mesh is not None:
            out["mesh"] = dict(zip(mesh.axis_names,
                                   (int(s) for s in mesh.devices.shape)))
        if eng.spec is not None:
            out["spec"] = {"k": eng.spec.k,
                           "draft_bits": eng.spec.draft_bits,
                           "autotune": eng.spec.autotune}
        # live-weights readiness (DESIGN.md §14): code-rail occupancy of
        # the serving tree + re-grid error of every built draft view
        out["numerics"] = eng.numerics_snapshot()
        return out

    def prom_text(self) -> str:
        """The Prometheus text exposition for ``GET /metrics``: the
        stats snapshot flattened to counters/gauges plus the lifetime
        latency histograms. Histograms render under the driver lock so
        a scrape never sees a bucket row torn across an observe()."""
        stats = self.stats()
        health = self.health()
        info = {"arch": health.get("arch"), "backend": health["backend"],
                "checkpoint_id": health.get("checkpoint_id"),
                "alloc_policy": health.get("alloc_policy"),
                "mesh": ",".join(f"{k}={v}" for k, v in
                                 health.get("mesh", {}).items()) or None}
        with self._lock:
            return render_prometheus(stats, self._hists.values(), info)

    def shutdown(self, timeout: float = 10.0) -> None:
        """Stop the loop: live requests are aborted (sinks get their
        terminal event), then the thread exits."""
        if not self._thread.is_alive():
            return
        self._stopping.set()
        self._mail.put(("stop",))
        self._thread.join(timeout)
        # a submit() that passed the alive check concurrently with the
        # stop may have mailed after the loop's final drain — fail it
        # here (the thread is dead, nobody else reads the mailbox)
        self._fail_pending()

    def _fail_pending(self) -> None:
        """Drain the mailbox, terminating any un-processed submits so no
        connection hangs on a request that will never run."""
        while True:
            try:
                cmd = self._mail.get_nowait()
            except queue.Empty:
                return
            if cmd[0] == "submit":
                _, req, sink = cmd
                with self._lock:
                    self._inflight -= 1
                    self._errors += 1
                sink(("finish", "error", None))

    # ------------------------------------------------------------------
    # engine callbacks (driver thread)

    def _on_token(self, rid: int, tok) -> None:
        sink = self._sinks.get(rid)
        if sink is not None:
            sink(("token", tok))

    def _on_finish(self, rid: int, reason: str, rs) -> None:
        sink = self._sinks.pop(rid, None)
        with self._lock:
            self._inflight -= 1
            if reason == "aborted":
                self._aborted_total += 1
            elif reason == "error":
                self._errors += 1
        if sink is not None:
            spec = None
            if rs is not None and rs.spec_cycles:
                spec = {"cycles": rs.spec_cycles,
                        "drafted": rs.spec_drafted,
                        "accepted": rs.spec_accepted}
            sink(("finish", reason, list(rs.generated) if rs else None,
                  spec))

    # ------------------------------------------------------------------
    # driver thread

    def _handle(self, cmd: tuple) -> None:
        kind = cmd[0]
        if kind == "submit":
            _, req, sink = cmd
            self._sinks[req.rid] = sink
            try:
                self._engine.submit(req)
            except Exception as e:  # safety net — submit() prevalidates
                self._sinks.pop(req.rid, None)
                with self._lock:
                    self._inflight -= 1
                    self._errors += 1
                sink(("finish", "error", None))
                _ = e
        elif kind == "abort":
            self._engine.abort(cmd[1])

    def _loop(self) -> None:
        eng = self._engine
        while True:
            busy = bool(eng.scheduler.running) or bool(eng.queue)
            cmds = []
            try:
                if not busy:  # idle: sleep on the mailbox
                    cmds.append(self._mail.get(timeout=self._poll_s))
                while True:
                    cmds.append(self._mail.get_nowait())
            except queue.Empty:
                pass
            stop = any(c[0] == "stop" for c in cmds)
            for cmd in cmds:
                if cmd[0] != "stop":
                    self._handle(cmd)
            if stop or self._stopping.is_set():
                self._stopping.set()
                for rid in list(self._sinks):
                    eng.abort(rid)
                self._fail_pending()
                self._refresh_stats()
                return
            try:
                eng.step()
            except Exception:
                # a dying engine must not leave streams hanging: every
                # live sink gets a terminal event, /health flips to 503
                self._stopping.set()
                for rid, sink in list(self._sinks.items()):
                    sink(("finish", "error", None))
                    self._sinks.pop(rid, None)
                    with self._lock:
                        self._inflight -= 1
                        self._errors += 1
                self._fail_pending()  # submits mailed during the fatal step
                self._refresh_stats()
                raise
            # archive completions and keep the engine's retained state
            # bounded (token lists already reached the sinks)
            if eng.completed:
                with self._lock:
                    self._metrics.extend(eng.completed)
                    self._completed_total += len(eng.completed)
                    for m in eng.completed:
                        self._hists["ttft_seconds"].observe(m.ttft)
                        self._hists["queue_wait_seconds"].observe(
                            m.queued_s)
                        if m.tpot is not None:
                            self._hists["tpot_seconds"].observe(m.tpot)
            if eng.finished or eng.aborted:
                eng.drain_finished()
            self._refresh_stats()

    def _refresh_stats(self) -> None:
        eng = self._engine
        snap = {
            "running": len(eng.scheduler.running),
            "queued": len(eng.queue),
            "free_slots": eng.scheduler.free_slots,
            "num_slots": eng.num_slots,
            "max_inflight": self._max_inflight,
            "decode_steps": eng.decode_steps,
            "prefills": eng.prefills,
            "admit_failures": eng.admit_failures,
            "decode_compiles": eng.decode_compiles,
            "prefill_compiles": eng.prefill_compiles,
        }
        if eng.page_size:
            snap["kv_pages_available"] = eng.allocator.available
            snap["kv_pages_total"] = eng.num_pages
            snap["prefix_hits"] = eng.prefix_hits
        mesh = getattr(eng, "_mesh", None)
        if mesh is not None:
            # mesh-native engine: surface the shape so /metrics tells a
            # sharded deployment from a single-device one at a glance
            snap["mesh"] = dict(zip(mesh.axis_names,
                                    (int(s) for s in mesh.devices.shape)))
        spec = eng.spec_snapshot()
        if spec is not None:
            snap.update(spec)
        # flattened numerics gauges (cached inside the engine — this is a
        # dict walk, not a tree reduction, per refresh)
        for scope, stats in eng.numerics_snapshot().items():
            for k, v in stats.items():
                if isinstance(v, dict):
                    for k2, v2 in v.items():
                        if isinstance(v2, (int, float)):
                            snap[f"numerics_{scope}_{k}_{k2}"] = v2
                elif isinstance(v, (int, float)):
                    snap[f"numerics_{scope}_{k}"] = v
        with self._lock:
            self._stats = snap
