"""Asyncio HTTP/SSE gateway over the engine driver (stdlib only).

A deliberately small HTTP/1.1 server (``asyncio.start_server`` + hand
parsing — the container bakes no web framework) exposing:

  POST   /v1/completions     token-id completions; ``"stream": true``
                             switches to SSE with one frame per token
                             flushed as it is produced (TTFT, not
                             completion time) and a ``data: [DONE]``
                             terminator
  DELETE /v1/requests/{id}   cancel a live request mid-flight
  GET    /health             readiness + serving context (backend, mesh,
                             alloc policy, spec config, checkpoint id;
                             503 once the driver stops)
  GET    /metrics            Prometheus text exposition (counters,
                             gauges, TTFT/TPOT/queue-wait histograms) —
                             scrapeable by stock Prometheus
  GET    /metrics.json       the JSON snapshot + rolling latency summary
                             (the pre-Prometheus /metrics payload)

Backpressure: the driver's inflight watermark maps to **429**, a dead
driver to **503**. A streaming client that disconnects (curl ^C, browser
tab close) is detected by EOF on its socket and the request is aborted —
its decode slot and KV pages free mid-flight without perturbing
co-batched requests. Responses close the connection (``Connection:
close``); per-request connections keep cancellation semantics trivial.
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional, Tuple

from repro.server import protocol, sse
from repro.server.driver import EngineDriver

__all__ = ["Gateway"]

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            413: "Payload Too Large", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable"}

# request-parse hard limits: the prompt is token ids, so even generous
# bodies are small — anything bigger is a client bug or abuse, refused
# before it is buffered
_MAX_BODY_BYTES = 8 << 20
_MAX_HEADERS = 128
# response-phase bounds: a client that stops reading (zero TCP window)
# must not pin writer.drain() — and with it the handler task, socket,
# and request — forever; and the disconnect watcher must not sink an
# endless post-body byte stream at full socket speed
_DRAIN_TIMEOUT_S = 60.0
_MAX_TRAILING_BYTES = 64 << 10


async def _drain(writer) -> None:
    await asyncio.wait_for(writer.drain(), timeout=_DRAIN_TIMEOUT_S)


class _BadRequest(Exception):
    """Malformed request head/body -> an HTTP error, not a dropped task."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


def _http_head(status: int, content_type: str,
               length: Optional[int] = None) -> bytes:
    head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}",
            f"Content-Type: {content_type}",
            "Connection: close"]
    if length is not None:
        head.append(f"Content-Length: {length}")
    return ("\r\n".join(head) + "\r\n\r\n").encode()


class _AsyncSink:
    """Thread-safe bridge: driver-thread events -> an asyncio queue."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self.queue: "asyncio.Queue[tuple]" = asyncio.Queue()
        self._loop = loop

    def __call__(self, event: tuple) -> None:
        try:
            self._loop.call_soon_threadsafe(self.queue.put_nowait, event)
        except RuntimeError:
            pass  # loop already closed (shutdown) — the client is gone


class Gateway:
    def __init__(self, driver: EngineDriver, *, host: str = "127.0.0.1",
                 port: int = 8000, model: str = "lns-madam"):
        self._driver = driver
        self._host, self._port = host, port
        self._model = model
        self._server: Optional[asyncio.AbstractServer] = None

    @property
    def address(self) -> Tuple[str, int]:
        """Actual (host, port) — resolves port 0 after ``start()``."""
        assert self._server is not None, "gateway not started"
        return self._server.sockets[0].getsockname()[:2]

    async def start(self) -> "Gateway":
        self._server = await asyncio.start_server(
            self._handle_conn, self._host, self._port)
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    # ------------------------------------------------------------------
    # connection handling

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            try:
                # one deadline over the whole request parse — a half-sent
                # head or short body must not pin the connection forever
                method, path, body = await asyncio.wait_for(
                    self._read_request(reader), timeout=30.0)
            except _BadRequest as e:
                await self._error(writer, e.status, str(e))
                await self._discard(reader)
                return
            except ValueError:  # StreamReader limit: oversized line
                await self._error(writer, 400, "request line too long")
                await self._discard(reader)
                return
            if method is None:
                return
            await self._route(method, path, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.TimeoutError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _discard(reader) -> None:
        """Bounded drain of request bytes still in flight after a
        refusal: closing with unread bytes in the kernel buffer sends
        RST and can discard the queued 4xx before the client reads it.
        A short per-read grace plus one overall deadline — a headers-only
        refusal costs one idle read, an actively-streaming body drains up
        to the trailing budget, and a byte-at-a-time trickler cannot pin
        the handler task past the deadline."""
        async def drain() -> None:
            budget = _MAX_TRAILING_BYTES
            while budget > 0:
                chunk = await asyncio.wait_for(reader.read(4096),
                                               timeout=0.25)
                if not chunk:
                    return
                budget -= len(chunk)
        try:
            await asyncio.wait_for(drain(), timeout=2.0)
        except (asyncio.TimeoutError, ConnectionError, OSError):
            pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        """Parse request line, headers, and Content-Length body; returns
        (None, None, None) on a malformed request line."""
        head = await reader.readline()
        parts = head.decode("latin-1").split()
        if len(parts) < 2:
            return None, None, None
        method, path = parts[0].upper(), parts[1]
        headers, header_lines = {}, 0
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            header_lines += 1  # count lines, not names: duplicate-name
            if header_lines > _MAX_HEADERS:  # headers must not bypass
                raise _BadRequest(400, "too many headers")
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        body = b""
        raw_n = headers.get("content-length", "0") or "0"
        try:
            n = int(raw_n)
        except ValueError:
            raise _BadRequest(
                400, f"malformed Content-Length {raw_n!r}") from None
        if n < 0:
            raise _BadRequest(400, f"negative Content-Length {n}")
        if n > _MAX_BODY_BYTES:
            raise _BadRequest(413, f"body of {n} bytes exceeds the "
                                   f"{_MAX_BODY_BYTES}-byte limit")
        if n:
            body = await reader.readexactly(n)
        return method, path, body

    async def _route(self, method: str, path: str, body: bytes,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        path = path.split("?", 1)[0]
        if method == "GET" and path == "/health":
            ok = self._driver.alive
            await self._json(writer, 200 if ok else 503,
                             self._driver.health())
        elif method == "GET" and path == "/metrics":
            payload = self._driver.prom_text().encode()
            writer.write(_http_head(
                status=200,
                content_type="text/plain; version=0.0.4; charset=utf-8",
                length=len(payload)))
            writer.write(payload)
            await _drain(writer)
        elif method == "GET" and path == "/metrics.json":
            await self._json(writer, 200, self._driver.stats())
        elif method == "DELETE" and path.startswith("/v1/requests/"):
            tail = path.rsplit("/", 1)[-1].removeprefix("cmpl-")
            if not tail.isdigit():
                await self._error(writer, 404, f"unknown request id {tail!r}")
                return
            self._driver.abort(int(tail))
            await self._json(writer, 200, {"id": f"cmpl-{tail}",
                                           "aborting": True})
        elif method == "POST" and path == "/v1/completions":
            await self._completions(body, reader, writer)
        else:
            await self._error(writer, 404, f"no route for {method} {path}")

    async def _json(self, writer, status: int, obj) -> None:
        # percentiles are NaN until the first completion; bare NaN is not
        # RFC-8259 JSON and breaks strict parsers (jq, fetch().json())
        obj = {k: (None if isinstance(v, float) and v != v else v)
               for k, v in obj.items()} if isinstance(obj, dict) else obj
        payload = json.dumps(obj, allow_nan=False).encode()
        writer.write(_http_head(status, "application/json", len(payload)))
        writer.write(payload)
        await _drain(writer)

    async def _error(self, writer, status: int, message: str) -> None:
        payload = protocol.error_body(message, status).encode()
        writer.write(_http_head(status, "application/json", len(payload)))
        writer.write(payload)
        await _drain(writer)

    # ------------------------------------------------------------------
    # completions

    async def _completions(self, body: bytes, reader, writer) -> None:
        try:
            creq = protocol.parse_completion(body)
        except protocol.ProtocolError as e:
            await self._error(writer, e.status, str(e))
            return
        if not self._driver.alive:
            await self._error(writer, 503, "server is shutting down")
            return
        sink = _AsyncSink(asyncio.get_running_loop())
        try:
            rid = self._driver.submit(creq.prompt, creq.max_tokens,
                                      sampling=creq.sampling, sink=sink)
        except ValueError as e:
            await self._error(writer, 400, str(e))
            return
        if rid is None:
            await self._error(writer, 429,
                              "engine at capacity, retry with backoff")
            return
        if creq.stream:
            await self._stream(rid, creq, sink, reader, writer)
        else:
            await self._unary(rid, creq, sink, reader, writer)

    @staticmethod
    async def _watch_eof(reader) -> None:
        """Resolve only on EOF. Stray bytes after the body (a pipelined
        request, a trailing CRLF) are drained and ignored — treating any
        readable bytes as a disconnect would silently abort a healthy
        request. A client that floods more than ``_MAX_TRAILING_BYTES``
        is treated as gone instead: we will not sink an arbitrary byte
        stream for the lifetime of the request."""
        budget = _MAX_TRAILING_BYTES
        while budget > 0:
            chunk = await reader.read(4096)
            if not chunk:
                return
            budget -= len(chunk)

    async def _events(self, rid: int, sink: _AsyncSink, reader):
        """Yield the request's sink events; EOF on the request socket
        (client went away) aborts the request and ends the iteration —
        both response modes must free the slot and KV pages mid-flight."""
        disconnect = asyncio.ensure_future(self._watch_eof(reader))
        try:
            while True:
                getter = asyncio.ensure_future(sink.queue.get())
                done, _ = await asyncio.wait(
                    {getter, disconnect},
                    return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:
                    getter.cancel()
                    self._driver.abort(rid)
                    return
                event = getter.result()
                yield event
                if event[0] != "token":
                    return
        finally:
            if not disconnect.done():
                disconnect.cancel()
            elif not disconnect.cancelled():
                # a hard reset (RST, not FIN) parks an exception on the
                # watch future; retrieve it or asyncio logs a warning
                disconnect.exception()

    async def _unary(self, rid: int, creq, sink: _AsyncSink,
                     reader, writer) -> None:
        tokens, reason, spec = [], None, None
        async for event in self._events(rid, sink, reader):
            if event[0] == "token":
                tokens.append(event[1])
            else:
                reason = event[1]
                if event[2] is not None:
                    tokens = event[2]
                # internal error paths still emit bare 3-tuples
                spec = event[3] if len(event) > 3 else None
        if reason is None:
            return  # client disconnected; request aborted, nothing to say
        status = 500 if reason == "error" else 200
        payload = protocol.completion_body(
            rid, self._model, len(creq.prompt), tokens, reason,
            spec=spec).encode()
        writer.write(_http_head(status, "application/json", len(payload)))
        writer.write(payload)
        await _drain(writer)

    async def _stream(self, rid: int, creq, sink: _AsyncSink,
                      reader, writer) -> None:
        try:
            # head write inside the guard: a client that resets before
            # the head flushes must abort the request, not leak it to
            # run its full token budget against a gone socket
            writer.write(_http_head(200, "text/event-stream"))
            await _drain(writer)
            async for event in self._events(rid, sink, reader):
                if event[0] == "token":
                    writer.write(sse.encode_event(protocol.chunk_body(
                        rid, self._model, [event[1]])))
                    await _drain(writer)
                else:
                    writer.write(sse.encode_event(protocol.chunk_body(
                        rid, self._model, [], finish_reason=event[1])))
                    writer.write(sse.encode_event(sse.DONE))
                    await _drain(writer)
        except (ConnectionError, OSError, asyncio.TimeoutError):
            # broken socket, or a reader that stalled past the drain
            # deadline — either way the client is gone
            self._driver.abort(rid)
