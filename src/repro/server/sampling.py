"""Per-request sampling parameters and the on-device batch sampler.

``SamplingParams`` is the wire-level contract of the gateway (temperature /
top-k / top-p / seed / stop-token set); ``sample_logits`` is the jit-safe
sampler the engine fuses into its decode step. Every parameter is a
**per-slot batch input** — a ``(B,)`` array, never a Python constant baked
into the trace — so a request with new sampling settings reuses the
compiled decode step instead of triggering a recompile.

Determinism: the PRNG key for a sample event is
``fold_in(fold_in(PRNGKey(seed), step), codebook)`` where ``step`` counts
the tokens the request has produced so far (prefill sample = step 0).
The chain depends only on the request's seed and its own progress — not
on the slot it landed in, the co-batched requests, or wall-clock time —
so a seeded request replays token-for-token on any engine.

``temperature == 0`` is exact greedy (argmax over the raw logits, first
maximum wins — bit-identical to the host-side ``np.argmax`` the engine
used before sampling moved on device).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch

__all__ = ["SamplingParams", "GREEDY", "sample_logits", "sampling_rows"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into a token.

    temperature: 0 => greedy argmax; > 0 => softmax sampling.
    top_k:       keep the k highest logits (0 => disabled).
    top_p:       keep the smallest prefix of the sorted distribution with
                 cumulative probability >= top_p (1.0 => disabled).
    seed:        per-request PRNG seed (folded with the token index, so
                 equal seeds replay token-for-token).
    stop:        extra stop-token ids, unioned with ``Request.eos_id``.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop: FrozenSet[int] = frozenset()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if not isinstance(self.stop, frozenset):
            object.__setattr__(self, "stop", frozenset(int(t) for t in self.stop))
        # PRNGKey consumes 32 bits; normalize so any int seed round-trips
        object.__setattr__(self, "seed", int(self.seed) & 0xFFFFFFFF)

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()

# dtype layout of the per-slot parameter arrays the engine feeds the
# jitted sampler ("step" is the per-request sample-event counter)
ROW_DTYPES = {"temp": np.float32, "top_k": np.int32, "top_p": np.float32,
              "seed": np.uint32, "step": np.int32}


def sampling_rows(batch: int) -> Dict[str, np.ndarray]:
    """Host-side per-slot sampling state, initialized to greedy."""
    rows = {k: np.zeros((batch,), dt) for k, dt in ROW_DTYPES.items()}
    rows["top_p"][:] = 1.0
    return rows


def set_row(rows: Dict[str, np.ndarray], slot: int,
            sp: Optional[SamplingParams]) -> None:
    """Bind slot ``slot`` to ``sp`` (None => greedy), step reset to 0."""
    sp = sp or GREEDY
    rows["temp"][slot] = sp.temperature
    rows["top_k"][slot] = sp.top_k
    rows["top_p"][slot] = sp.top_p
    rows["seed"][slot] = sp.seed
    rows["step"][slot] = 0


def _mask_sample(scaled: jax.Array, top_k: jax.Array, top_p: jax.Array,
                 gumbel: jax.Array) -> jax.Array:
    """Top-k / top-p masked gumbel-argmax for one row ``(V,)``. The
    gumbel noise is indexed by *token id* (gathered through the sort
    order), so a row with ``k=0, p=1`` draws exactly what the sort-free
    path would — a request's tokens never depend on whether a neighbour
    in the batch forced the masked branch."""
    v = scaled.shape[-1]
    order = jnp.argsort(-scaled)                 # descending, stable
    ranked = scaled[order]
    k_eff = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v))
    ranked = jnp.where(jnp.arange(v) < k_eff, ranked, -jnp.inf)
    probs = jax.nn.softmax(ranked)
    # nucleus: keep ranks whose *exclusive* cumulative mass is < top_p —
    # at least the top token always survives
    keep_p = (jnp.cumsum(probs) - probs) < top_p
    ranked = jnp.where(keep_p, ranked, -jnp.inf)
    return order[jnp.argmax(ranked + gumbel[order])].astype(jnp.int32)


def _row_key(seed: jax.Array, step: jax.Array, codebook) -> jax.Array:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.fold_in(key, codebook)


def _batch_sample(lg, temp, top_k, top_p, seed, step, codebook,
                  backend=None) -> jax.Array:
    """Sample one batch of rows ``(B, V)`` -> ``(B,)`` int32.

    Layered fast paths (``lax.cond`` on runtime params, shapes fixed, so
    none of this recompiles): an all-greedy batch pays one fused argmax
    and never touches the PRNG; a temperature-only batch adds gumbel noise
    but skips the sort (XLA's CPU sort is ~15x an argmax); only batches
    with an active top-k / top-p row pay for the per-row sort. The greedy
    and temperature-only legs are the ``dispatch.fused_sample`` epilogue
    (one Pallas launch on the kernel backend); gumbel noise stays a
    ``jax.random`` input either way, so seeded replay is backend-exact."""
    v = lg.shape[-1]
    lg = lg.astype(jnp.float32)

    def greedy():
        return dispatch.fused_sample(lg, None, None, backend=backend)

    def sampled():
        keys = jax.vmap(lambda s, st: _row_key(s, st, codebook))(seed, step)
        gumbel = jax.vmap(
            lambda k: jax.random.gumbel(k, (v,), jnp.float32))(keys)

        def masked():
            scaled = lg / jnp.maximum(temp, 1e-6)[:, None]
            toks = jax.vmap(_mask_sample)(scaled, top_k, top_p, gumbel)
            return jnp.where(temp > 0.0, toks, greedy())

        return jax.lax.cond(
            jnp.any((top_k > 0) | (top_p < 1.0)),
            masked,
            lambda: dispatch.fused_sample(lg, gumbel, temp, backend=backend))

    return jax.lax.cond(jnp.any(temp > 0.0), sampled, greedy)


def sample_logits(logits: jax.Array, rows: Dict[str, jax.Array], *,
                  num_codebooks: int = 0,
                  vocab_size: Optional[int] = None,
                  backend: Optional[str] = None,
                  step_offset=None) -> jax.Array:
    """Batch sampler: ``logits (B, V)`` (or ``(B, K*V)`` for codebook
    stacks) + per-slot parameter arrays -> token ids ``(B,)`` / ``(B, K)``.

    ``backend`` picks the fused-epilogue implementation (None resolves
    through ``kernels.dispatch`` — ``configure()``, env, then platform
    auto). Safe to run over idle slots (the engine resets
    them to greedy); only shapes are traced, so admissions never recompile
    the decode step.

    ``step_offset`` (scalar or ``(B,)``) shifts the fold counter without
    mutating ``rows``: the speculative verify pass scores position ``j`` of
    its k-token suffix with ``step + j``, reproducing exactly the key the
    baseline engine would fold for that token. Rollback is then free — the
    host simply advances its step counter by the accepted count.
    """
    temp, top_k = rows["temp"], rows["top_k"]
    top_p, seed, step = rows["top_p"], rows["seed"], rows["step"]
    if step_offset is not None:
        step = step + step_offset
    if num_codebooks:
        b = logits.shape[0]
        lg = logits.reshape(b, num_codebooks, vocab_size)
        # static python loop: each codebook keeps its own lax.cond fast
        # path (a vmap over the batch would lower cond to select and
        # make every batch pay the masked-sort branch)
        cols = [_batch_sample(lg[:, j], temp, top_k, top_p, seed, step, j,
                              backend=backend)
                for j in range(num_codebooks)]
        return jnp.stack(cols, axis=1)
    return _batch_sample(logits, temp, top_k, top_p, seed, step, 0,
                         backend=backend)
