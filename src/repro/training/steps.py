"""Step builders: LNS-native train step, prefill step, decode step.

The train step is the paper's full pipeline (Fig. 3), with the weights
never leaving the packed wire format (DESIGN.md §3-4):

  1. params stay packed LNS words end to end — routed GEMMs decode
     tile-locally inside the kernel, fallback leaves decode per leaf
     inside the scan body; there is NO whole-tree materialize and no
     fp master copy anywhere
  2. forward/backward with Q_A/Q_E quantization; gradients are taken
     w.r.t. zero delta carriers (``grad_proxies``) whose cotangent is
     exactly dL/dW at W = decode(packed)
  3. Q_G on the final weight gradients
  4. fused Madam update directly on the packed exponent words (one HBM
     pass per leaf through ``kernels/dispatch``)

Gradient microbatching (``accum_steps``) accumulates quantized microbatch
gradients — XLA overlaps each microbatch's backward with the previous
all-reduce (latency-hiding scheduler flags set in ``launch.train``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig, quantize_grads
from repro.models.common import ArchConfig
from repro.models.model import decode_step as model_decode_step
from repro.models.model import forward, lm_loss
from repro.obs.numerics import grad_encode_stats
from repro.optim.madam import (MadamConfig, MadamState, attach_proxies,
                               grad_proxies, init_lns_params, madam_lns)

__all__ = ["TrainState", "init_train_state", "build_train_step",
           "build_prefill_step", "build_decode_step"]


class TrainState(NamedTuple):
    params: Any          # mixed LNSWeight / fp pytree
    opt: MadamState
    step: jax.Array


def init_train_state(key, cfg: ArchConfig, mcfg: MadamConfig) -> TrainState:
    """Initialize params directly in LNS (jit/eval_shape friendly)."""
    from repro.models.model import init_params
    dense = init_params(key, cfg)
    params = init_lns_params(dense, mcfg, scale_axis="auto")
    init_opt, _ = madam_lns(mcfg)
    return TrainState(params=params, opt=init_opt(params),
                      step=jnp.zeros((), jnp.int32))


def build_train_step(
    cfg: ArchConfig,
    qcfg: Optional[QuantConfig],
    mcfg: MadamConfig,
    *,
    accum_steps: int = 1,
    remat: bool = True,
    scan_unroll: int | bool = 1,
    numerics: bool = False,
) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``numerics=True`` adds a ``metrics["numerics"]`` aux pytree of
    per-layer LNS health scalars (DESIGN.md §14): update-site stats ride
    the fused Madam kernel's epilogue, encode-site rail stats fuse into
    the gradient quantizer's pass — all in-graph, one host sync per step
    (the loss the loop already blocks on).
    """
    _, opt_update = madam_lns(mcfg)
    # the forward re-grid target for the requant clip stat: the B_U-grid
    # weights are re-gridded to the (coarser) B_W forward format each GEMM
    fwd_fmt = getattr(qcfg, "weight", None) if numerics else None
    if not isinstance(fwd_fmt, LNSFormat):
        fwd_fmt = None

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params  # packed LNSWeight / fp leaves, never dense

        def loss_fn(diff, mb):
            # diff: fp leaves + zero delta carriers for the packed leaves;
            # dL/ddelta == dL/dW — no dense master copy is differentiated
            return lm_loss(attach_proxies(params, diff), mb, cfg, qcfg,
                           remat=remat, scan_unroll=scan_unroll)

        def one_microbatch(diff, mb):
            loss, grads = jax.value_and_grad(loss_fn)(diff, mb)
            # encode-site stats read the RAW gradients — the same tensors
            # quantize_grads is about to push through the LNS grid, so XLA
            # CSEs the scale/log2 work with the encode itself (measuring
            # the quantized output instead would double the reductions and
            # see an already-clamped tensor)
            enc = grad_encode_stats(grads, qcfg) if numerics else {}
            return loss, quantize_grads(grads, qcfg), enc

        # zeros fold to a broadcast constant inside jit: the carriers cost
        # no HBM; only the gradient outputs are dense
        diff0 = grad_proxies(params, cfg.compute_dtype)

        if accum_steps == 1:
            loss, grads, enc_stats = one_microbatch(diff0, batch)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g, enc = one_microbatch(diff0, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                # enc rides as a scan output: stacked per microbatch,
                # averaged below (no zero-init tree needed)
                return (loss_acc + loss, g_acc), enc

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), diff0)
            (loss, grads), enc_stack = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            enc_stats = jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                     enc_stack)

        if numerics:
            new_params, new_opt, upd_stats = opt_update(
                grads, state.opt, state.params, with_stats=True,
                requant_fmt=fwd_fmt)
        else:
            new_params, new_opt = opt_update(grads, state.opt, state.params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step.astype(jnp.float32)}
        if numerics:
            metrics["numerics"] = {
                "update": upd_stats,
                "grad_encode": enc_stats,
            }
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def build_prefill_step(cfg: ArchConfig, qcfg: Optional[QuantConfig],
                       mcfg: Optional[MadamConfig] = None, *,
                       scan_unroll: int | bool = 1) -> Callable:
    """``prefill(params, batch) -> last-position logits``.

    Consumes packed ``LNSWeight`` leaves directly — routed GEMMs through
    ``kernels/dispatch``, per-leaf decode otherwise; there is no up-front
    materialize (``mcfg`` is accepted for signature compatibility). Runs
    the flash (training) attention path over the full prompt; the KV
    write-back is modeled by the decode cache in serving proper — its bytes
    are negligible next to prefill compute (DESIGN.md §Deviations).
    """
    del mcfg  # packed params are consumed as-is

    def prefill_step(params, batch):
        out = forward(params, batch["tokens"], cfg, qcfg,
                      patches=batch.get("patches"), remat=False,
                      scan_unroll=scan_unroll)
        return out.logits[:, -1]

    return prefill_step


def build_decode_step(cfg: ArchConfig, qcfg: Optional[QuantConfig],
                      mcfg: Optional[MadamConfig] = None, *,
                      scan_unroll: int | bool = 1) -> Callable:
    """``decode(params, caches, batch, pos) -> (logits, caches)``.

    Packed params are consumed as-is (see :func:`build_prefill_step`).
    """
    del mcfg

    def serve_step(params, caches, batch, pos):
        return model_decode_step(params, caches, batch["tokens"], cfg, qcfg,
                                 pos_offset=pos,
                                 block_tables=batch.get("block_tables"),
                                 scan_unroll=scan_unroll)

    return serve_step
