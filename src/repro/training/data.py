"""Deterministic synthetic data pipeline (resumable, shardable).

Batches are a pure function of (seed, cursor): restart-from-checkpoint
resumes the stream exactly (the checkpoint manifest records the cursor).
Real deployments swap this for a tokenized corpus reader with the same
cursor contract.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs import ShapeSpec
from repro.models.common import ArchConfig

__all__ = ["SyntheticLM"]


class SyntheticLM:
    """Markov-ish synthetic token stream with next-token structure.

    Tokens follow t_{i+1} = (a·t_i + noise) mod V so models can actually
    reduce loss on it (needed by the accuracy-trend benchmarks), while every
    batch remains reproducible from its cursor.
    """

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 noise_levels: int = 16):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.noise = noise_levels
        self.cursor = 0

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ cursor)
        V = self.cfg.vocab_size
        shape = (self.batch, self.seq + 1)
        if self.cfg.num_codebooks:
            shape = shape + (self.cfg.num_codebooks,)
        start = rng.integers(0, V, shape[:1] + shape[2:])
        steps = rng.integers(0, self.noise, shape[:1] + (self.seq,) + shape[2:])
        seqs = (start[:, None] * 1 + np.cumsum(steps, axis=1) * 7) % V
        seqs = np.concatenate([start[:, None], seqs], axis=1).astype(np.int32)
        out = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        if self.cfg.num_patches:
            out["patches"] = rng.standard_normal(
                (self.batch, self.cfg.num_patches, self.cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.cursor)
            self.cursor += 1
            yield b

    def seek(self, cursor: int) -> None:
        self.cursor = cursor
