from repro.training.steps import (TrainState, build_train_step,
                                  build_prefill_step, build_decode_step,
                                  init_train_state)

__all__ = ["TrainState", "build_train_step", "build_prefill_step",
           "build_decode_step", "init_train_state"]
