"""Fault-tolerant training supervisor (DESIGN.md §8).

Wraps the jitted step with:
  * periodic async checkpointing (atomic, keep-k)
  * restart-from-checkpoint on step failure (device loss / XLA abort —
    injectable in tests via ``failure_injector``), with the data pipeline
    re-seeked to the manifest's cursor
  * a step-time watchdog: steps slower than ``straggler_factor`` x the
    running median are recorded as straggler events and, under the
    ``"skip"`` policy, their batch is skipped (gradient-accumulation
    renormalization happens naturally since each step is one batch)
  * an ``on_rebuild`` hook for elastic down-shift: on repeated failures the
    supervisor calls it to rebuild the step/state on a smaller mesh
    (exercised in tests with a host-device mesh swap)
  * an ``observer`` hook (:class:`repro.obs.numerics.NumericsObserver`):
    every committed step flows through ``observer.record_step`` —
    structured jsonl step logging, numerics aux collection, trace export.
    Progress printing is the observer's job too, behind ``quiet=False``;
    the supervisor itself never prints.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import CheckpointManager

__all__ = ["SupervisorConfig", "TrainReport", "run_supervised"]


@dataclasses.dataclass
class SupervisorConfig:
    max_steps: int = 100
    save_every: int = 20
    max_retries: int = 3
    straggler_factor: float = 3.0
    straggler_policy: str = "flag"   # "flag" | "skip"
    warmup_timing_steps: int = 3


@dataclasses.dataclass
class TrainReport:
    steps_done: int = 0
    failures_recovered: int = 0
    straggler_events: int = 0
    skipped_batches: int = 0
    rebuilds: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)


def run_supervised(
    step_fn: Callable,
    state,
    data,
    ckpt: CheckpointManager,
    sup: SupervisorConfig,
    *,
    failure_injector: Optional[Callable[[int], None]] = None,
    on_rebuild: Optional[Callable[[Any], Any]] = None,
    device_put_batch: Optional[Callable] = None,
    observer: Optional[Any] = None,
) -> TrainReport:
    report = TrainReport()
    step_times: List[float] = []
    retries = 0
    data_iter = iter(data)
    step = 0

    ckpt.save(0, state, data_cursor=data.cursor, async_=False)

    while step < sup.max_steps:
        batch = next(data_iter)
        if device_put_batch is not None:
            batch = device_put_batch(batch)
        t0 = time.monotonic()
        try:
            if failure_injector is not None:
                failure_injector(step)
            new_state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            if loss != loss:  # NaN — treat as a failed step
                raise FloatingPointError(f"NaN loss at step {step}")
        except Exception:
            report.failures_recovered += 1
            retries += 1
            if retries > sup.max_retries:
                if on_rebuild is not None:
                    state = on_rebuild(state)
                    report.rebuilds += 1
                    retries = 0
                    continue
                raise
            # restore-from-checkpoint path
            last = ckpt.latest_step()
            _, state = ckpt.restore_latest(state)
            man = ckpt.manifest(last)
            data.seek(man["data_cursor"])
            data_iter = iter(data)
            step = man["step"]
            continue

        retries = 0
        dt = time.monotonic() - t0
        if len(step_times) >= sup.warmup_timing_steps:
            med = statistics.median(step_times)
            if dt > sup.straggler_factor * med:
                report.straggler_events += 1
                if sup.straggler_policy == "skip":
                    report.skipped_batches += 1
                    step_times.append(dt)
                    continue  # drop this step's result
        step_times.append(dt)

        state = new_state
        step += 1
        report.steps_done += 1
        report.losses.append(loss)
        if observer is not None:
            observer.record_step(step, metrics, walltime_s=dt)
        if step % sup.save_every == 0:
            ckpt.save(step, state, data_cursor=data.cursor)

    ckpt.save(sup.max_steps, state, data_cursor=data.cursor, async_=False)
    ckpt.wait()
    return report
