"""Property-testing compat layer.

Tests import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. When hypothesis is installed (the ``[test]``
extra pulls it in; CI always has it) the real library is used unchanged.
In hermetic environments without it, a minimal deterministic fallback
generates boundary values plus seeded-random draws, so the suite still
*collects and runs* instead of dying with ``ModuleNotFoundError`` — the
fallback trades hypothesis's shrinking and coverage for availability.

Only the strategy surface the suite uses is implemented: ``integers``,
``floats``, ``sampled_from``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Draws one example per call; boundary cases first."""

        def __init__(self, boundary, draw):
            self._boundary = list(boundary)
            self._draw = draw
            self._i = 0

        def example(self, rng):
            if self._i < len(self._boundary):
                val = self._boundary[self._i]
            else:
                val = self._draw(rng)
            self._i += 1
            return val

    class _st:
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2 ** 31) if min_value is None else min_value
            hi = 2 ** 31 - 1 if max_value is None else max_value
            return lambda: _Strategy(
                [lo, hi] + ([0] if lo < 0 < hi else []),
                lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(min_value=-1e30, max_value=1e30, allow_nan=False,
                   allow_infinity=False, **_):
            del allow_nan, allow_infinity  # fallback never emits them
            boundary = [min_value, max_value,
                        (min_value + max_value) / 2.0]
            for near_zero in (0.0, 1e-6):  # only when inside the range
                if min_value <= near_zero <= max_value:
                    boundary.append(near_zero)
            return lambda: _Strategy(
                boundary, lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return lambda: _Strategy(seq, lambda rng: rng.choice(seq))

    st = _st()

    def settings(max_examples=20, **_):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategy_factories):
        """Each run draws ``max_examples`` tuples deterministically
        (seeded rng) and calls the test once per tuple."""

        def deco(fn):
            def wrapper():
                # read at call time: @settings may sit above OR below
                # @given (both orders are valid with real hypothesis) —
                # above, the attribute lands on this wrapper
                n = min(getattr(wrapper, "_compat_max_examples",
                                getattr(fn, "_compat_max_examples", 20)), 50)
                rng = random.Random(0xC0FFEE)
                strategies = [f() for f in strategy_factories]
                for _ in range(n):
                    fn(*(s.example(rng) for s in strategies))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
