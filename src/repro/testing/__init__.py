from repro.testing.hypothesis_compat import (HAVE_HYPOTHESIS, given,
                                             settings, st)

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
