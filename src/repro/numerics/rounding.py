"""Rounding primitives (paper Eq. 10).

Stochastic rounding is used by the theory benchmarks (Theorems 1/2 assume
``E SR(x) = x``) and optionally by Q_U; the deployed datapath uses
deterministic round-to-nearest (SR needs RNGs that cost energy, §4.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["stochastic_round", "round_nearest"]


def stochastic_round(key: jax.Array, x: jax.Array) -> jax.Array:
    """Unbiased stochastic rounding: floor(x) + Bernoulli(frac(x))."""
    floor = jnp.floor(x)
    p = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return floor + (p <= (x.astype(jnp.float32) - floor)).astype(x.dtype)


def round_nearest(x: jax.Array) -> jax.Array:
    """Round-to-nearest, ties away from zero (matches the kernels)."""
    return jnp.floor(x + 0.5)
