"""Generic low-precision floating-point fake-quantization.

Used for the paper's FP8 (e4m3) / FP16 baselines (Tables 4, 5, 8). Pure-jnp
simulation: clamp to the format's finite range, round the mantissa to
``man_bits`` with round-to-nearest-even, flush subnormals-below-min to the
subnormal grid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["FPFormat", "fp_quantize"]


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """An IEEE-like miniature float: 1 sign, ``exp_bits``, ``man_bits``.

    e4m3 (paper's FP8) keeps the extra exponent value for finite max 448
    like the OCP/NV variant; we use the plain IEEE-style max for simplicity:
    max = 2**(bias+1) * (2 - 2**-man_bits) is close enough for QAT trends.
    """

    exp_bits: int = 4
    man_bits: int = 3

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_value(self) -> float:
        return float(2.0 ** self.bias * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def min_normal(self) -> float:
        return float(2.0 ** (1 - self.bias))


def fp_quantize(x: jax.Array, fmt: FPFormat, scale_axis: Optional[int] = None) -> jax.Array:
    """Fake-quantize onto the miniature-float grid, with absmax scaling.

    The tensor is scaled so its absmax maps to the format's max value
    (mirroring the paper's loss-scaling-free per-group scaling), quantized,
    and scaled back.
    """
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    absmax = (
        jnp.max(jnp.abs(xf))
        if scale_axis is None
        else jnp.max(jnp.abs(xf), axis=tuple(i for i in range(x.ndim) if i != scale_axis % x.ndim), keepdims=True)
    )
    scale = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny) / fmt.max_value
    v = xf / scale
    mag = jnp.abs(v)
    # exponent of the leading bit, clamped to the subnormal floor
    e = jnp.floor(jnp.log2(jnp.maximum(mag, jnp.finfo(jnp.float32).tiny)))
    e = jnp.clip(e, 1 - fmt.bias, fmt.bias)
    ulp = jnp.exp2(e - fmt.man_bits)
    q = jnp.round(v / ulp) * ulp
    q = jnp.clip(q, -fmt.max_value, fmt.max_value)
    return (q * scale).astype(orig_dtype)
