from repro.numerics.fp import FPFormat, fp_quantize
from repro.numerics.rounding import stochastic_round

__all__ = ["FPFormat", "fp_quantize", "stochastic_round"]
