"""Quickstart: the LNS-Madam pipeline end to end in ~60 lines.

1. quantize a tensor onto the multi-base LNS grid (paper Eq. 3)
2. run a quantized GEMM through the STE machinery (paper §3)
3. train a small LM with weights stored natively as LNS integer exponent
   codes and updated multiplicatively (paper §4, Algorithm 1) — no fp32
   master copy anywhere
4. run the bit-exact Fig.-6 datapath kernel in Pallas interpret mode

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat, lns_quantize
from repro.core.quantizer import QuantConfig, qeinsum
from repro.kernels import lns_matmul
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM

key = jax.random.PRNGKey(0)

# --- 1. the multi-base LNS format (B=8 bits, gamma=8 -> range (0, 15.875))
fmt = LNSFormat(bits=8, gamma=8)
x = jax.random.normal(key, (4,))
print("x       ", x)
print("Q_log(x)", lns_quantize(x, fmt), f"(grid step 2^(1/{fmt.gamma}))")

# --- 2. a quantized GEMM: Q_A/Q_W on inputs, Q_E on the backward cotangent
qcfg = QuantConfig.lns_madam()
a = jax.random.normal(jax.random.fold_in(key, 1), (8, 32))
w = jax.random.normal(jax.random.fold_in(key, 2), (32, 16))
y = qeinsum("bi,ij->bj", a, w, qcfg)
print("\nqeinsum max |err| vs fp32:",
      float(jnp.max(jnp.abs(y - a @ w))))

# --- 3. LNS-native training: weights ARE integer exponent codes
cfg = get_smoke_config("granite-8b")
mcfg = MadamConfig(lr=2.0 ** -6)
state = init_train_state(key, cfg, mcfg)
leaf = state.params["period"]["pos0"]["mlp"]["up"]
print(f"\nweight storage: packed {leaf.packed.dtype} "
      f"({leaf.packed.dtype.itemsize} B/elem wire words), "
      f"scale {leaf.scale.shape} — no float weights")
step = jax.jit(build_train_step(cfg, qcfg, mcfg))
data = SyntheticLM(cfg, batch=8, seq=32)
for i, batch in zip(range(10), data):
    state, metrics = step(state, jax.tree.map(jnp.asarray, batch))
    if i % 3 == 0:
        print(f"step {i}: loss {float(metrics['loss']):.4f}")

# --- 4. the bit-exact hardware datapath (Fig. 6) as a Pallas kernel
out = lns_matmul(a, w, fmt)          # integer exponent adds + shift + LUT
print("\nbit-exact datapath max |err| vs fp32:",
      float(jnp.max(jnp.abs(out - a @ w))))
print("\nok")
