"""Reproduce Fig. 4: weight-update quantization error, GD vs multiplicative.

Prints the r_t tables over the learning-rate and base-factor sweeps and the
theoretical bounds of Theorems 1/2 + Lemma 1 next to the measurements.

  PYTHONPATH=src python examples/quant_error_fig4.py
"""
import jax
import jax.numpy as jnp

from repro.core import error_analysis as ea

key = jax.random.PRNGKey(0)
d = 2048
w = jnp.exp2(jax.random.normal(key, (d,)) * 2.0)  # magnitudes over decades
g2 = jnp.full((d,), 0.003 ** 2)

print(f"{'setting':<16s} {'gd':>10s} {'mul':>10s} {'signmul':>10s} "
      f"{'madam':>10s}   bounds(gd/mul/sign)")
for label, eta, gamma in [
    ("eta=2^-8", 2.0 ** -8, 2.0 ** 10),
    ("eta=2^-6", 2.0 ** -6, 2.0 ** 10),
    ("eta=2^-4", 2.0 ** -4, 2.0 ** 10),
    ("gamma=2^6", 2.0 ** -6, 2.0 ** 6),
    ("gamma=2^10", 2.0 ** -6, 2.0 ** 10),
    ("gamma=2^14", 2.0 ** -6, 2.0 ** 14),
]:
    acc = {k: 0.0 for k in ("gd", "mul", "signmul", "madam")}
    trials = 16
    for t in range(trials):
        g = jax.random.normal(jax.random.fold_in(key, t), (d,)) * 0.003
        out = ea.measure_all(jax.random.fold_in(key, 777 + t), w, g, eta,
                             gamma, g2)
        for k in acc:
            acc[k] += float(out[k]) / trials
    g = jax.random.normal(jax.random.fold_in(key, 0), (d,)) * 0.003
    b = ea.theoretical_bounds(w, g, eta, gamma)
    print(f"{label:<16s} {acc['gd']:10.3e} {acc['mul']:10.3e} "
          f"{acc['signmul']:10.3e} {acc['madam']:10.3e}   "
          f"{float(b['gd']):.2e}/{float(b['mul']):.2e}/{float(b['signmul']):.2e}")

print("\nPaper's claim (Fig. 4): multiplicative updates give orders-of-"
      "magnitude lower r_t than GD, and r_t shrinks with smaller eta / "
      "larger gamma.")
