"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
LNS-Madam, under the fault-tolerant supervisor with async checkpointing.

This is the (b) "end-to-end example" deliverable at CPU-feasible scale:
smollm-135m is one of the assigned architectures and its full config is
~135M params; pass --full to train it as-is (slow on CPU), or use the
default reduced width that keeps the same 30-layer llama-family wiring.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.quantizer import QuantConfig
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state
from repro.training.data import SyntheticLM
from repro.training.loop import SupervisorConfig, run_supervised


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="the real 135M config (slow on CPU)")
    ap.add_argument("--format", default="lns8", choices=["lns8", "fp8", "fp32"])
    ap.add_argument("--ckpt", default="/tmp/lns_madam_example")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:  # same family/depth, reduced width for CPU wall-time
        cfg = dataclasses.replace(cfg, d_model=192, num_heads=3,
                                  num_kv_heads=1, head_dim=64, d_ff=512,
                                  vocab_size=4096, dtype="float32")
    qcfg = {"lns8": QuantConfig.lns_madam(), "fp8": QuantConfig.fp8(),
            "fp32": QuantConfig.full_precision()}[args.format]
    mcfg = MadamConfig(lr=2.0 ** -6)

    state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"training {cfg.name}: {n / 1e6:.1f}M stored values, "
          f"format={args.format}")
    step = jax.jit(build_train_step(cfg, qcfg, mcfg))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
    ckpt = CheckpointManager(args.ckpt, keep=3)

    t0 = time.monotonic()
    report = run_supervised(
        step, state, data, ckpt,
        SupervisorConfig(max_steps=args.steps, save_every=50),
        device_put_batch=lambda b: jax.tree.map(jnp.asarray, b))
    dt = time.monotonic() - t0
    tok = args.steps * args.batch * args.seq
    print(f"{report.steps_done} steps, {tok / dt:.0f} tok/s, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
