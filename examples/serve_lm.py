"""Serving example: continuous batching with 8-bit packed LNS weights.

Shows the inference side of the paper's format: serving weights are packed
single-byte LNS codes (sign bit + 7-bit exponent) — half the HBM bytes of
bf16 — decoded on the fly inside each layer. A mixed-length trace flows
through ``repro.serving.Engine``: finished sequences free their decode slot
and KV rows mid-run, waiting requests are admitted without recompiling the
decode step. Reports weight bytes, per-request TTFT, and tokens/second.

  python examples/serve_lm.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.run([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma3-12b", "--smoke",
        "--requests", "4", "--slots", "2", "--mixed",
        "--prompt-len", "24", "--gen-len", "24",
        "--serve-bits", "8",
    ]).returncode)
