"""Serving example: batched prefill + decode with 8-bit packed LNS weights.

Shows the inference side of the paper's format: serving weights are packed
single-byte LNS codes (sign bit + 7-bit exponent) — half the HBM bytes of
bf16 — decoded on the fly inside each layer. Reports weight bytes and
tokens/second. This drives ``repro.launch.serve`` (the production driver).

  PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.run([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma3-12b", "--smoke",
        "--requests", "4", "--prompt-len", "24", "--gen-len", "24",
        "--serve-bits", "8",
    ]).returncode)
