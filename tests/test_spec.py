"""Self-speculative decoding (DESIGN.md §11): draft views, accept/rollback
equivalence, page accounting, metrics, autotuning.

The load-bearing claim is *equivalence*: with greedy and seeded sampled
rows mixed in one batch, the speculating engine must emit token-for-token
what the non-speculating engine emits — accepted drafts are by
construction the target's own samples, rejected drafts' KV writes are
overwritten before they are ever attendable, and the sampler fold rewinds
with the slot cursor.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat, is_lns_weight
from repro.core.quantizer import QuantConfig
from repro.optim.madam import MadamConfig
from repro.serving import (Engine, Request, SpecAutotuner, SpecConfig,
                           build_draft_params, spec_supported, summarize)
from repro.server.sampling import SamplingParams
from repro.training import init_train_state


def _mixed_requests(vocab, n=6, gen=12, seed=3):
    """Greedy and seeded-sampled rows interleaved, varied lengths."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        sp = None if i % 2 == 0 else SamplingParams(
            temperature=0.9, top_k=0 if i % 4 == 1 else 8, seed=100 + i)
        prompt = rng.integers(0, vocab, (4 + (i % 3) * 3,)).tolist()
        out.append(Request(rid=i, prompt=prompt,
                           max_new_tokens=gen - (i % 4), sampling=sp))
    return out


def _gen_map(engine):
    return {rs.request.rid: list(rs.generated) for rs in engine.finished}


# ---------------------------------------------------------------------------
# the parameter transform


def test_build_draft_params_shares_scales(smoke_serving_setup):
    _, _, _, params = smoke_serving_setup
    draft = build_draft_params(params, 6)
    n = 0
    for a, b in zip(jax.tree.leaves(params, is_leaf=is_lns_weight),
                    jax.tree.leaves(draft, is_leaf=is_lns_weight)):
        if is_lns_weight(a):
            assert b.scale is a.scale          # shared by reference
            assert b.fmt.bits == 6 and b.delta is None
            assert b.packed.dtype == a.packed.dtype  # still 1 B wire words
            n += 1
        else:
            assert b is a
    assert n >= 5
    # the B=8 view IS the target tree (identity draft, leaf for leaf)
    same = build_draft_params(params, 8)
    for a, b in zip(jax.tree.leaves(params, is_leaf=is_lns_weight),
                    jax.tree.leaves(same, is_leaf=is_lns_weight)):
        assert b is a


def test_spec_supported_gates_architectures():
    assert spec_supported(get_smoke_config("smollm-135m")) is None
    assert spec_supported(get_smoke_config("gemma3-12b")) is None
    assert "recurrent" in spec_supported(get_smoke_config("rwkv6-1.6b"))
    assert "codebook" in spec_supported(get_smoke_config("musicgen-medium"))


def test_engine_rejects_unsupported_arch():
    cfg = get_smoke_config("rwkv6-1.6b")
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    with pytest.raises(ValueError, match="recurrent"):
        Engine(cfg, QuantConfig.lns_madam(), mcfg, params, num_slots=2,
               max_len=32, speculate_k=4)


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(draft_bits=1)
    arms = SpecConfig(draft_bits=7, k=4).arms()
    assert arms[0] == (7, 4)
    assert len(arms) == len(set(arms)) == 9  # configured arm not repeated


# ---------------------------------------------------------------------------
# equivalence: spec engine == baseline engine, token for token


def test_spec_equals_baseline_dense(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    reqs = _mixed_requests(cfg.vocab_size)
    base = Engine(cfg, qcfg, mcfg, params, num_slots=3, max_len=48)
    base.run(reqs)
    spec = Engine(cfg, qcfg, mcfg, params, num_slots=3, max_len=48,
                  speculate_k=3, draft_bitwidth=7)
    spec.run(reqs)
    assert _gen_map(spec) == _gen_map(base)
    assert spec.spec_cycles > 0 and spec.spec_drafted > 0
    assert base.spec_snapshot() is None  # spec off -> no phantom metrics


def test_spec_equals_baseline_paged_and_returns_pages(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    reqs = _mixed_requests(cfg.vocab_size)
    kw = dict(num_slots=3, max_len=48, page_size=8, num_pages=18,
              prefix_cache=False, alloc_policy="ondemand")
    base = Engine(cfg, qcfg, mcfg, params, **kw)
    base.run(reqs)
    spec = Engine(cfg, qcfg, mcfg, params, **kw,
                  speculate_k=3, draft_bitwidth=6)
    spec.run(reqs)
    assert _gen_map(spec) == _gen_map(base)
    # rollback accounting: every page the lookahead grew beyond what the
    # accepted tokens used went back to the allocator
    assert spec.allocator.available == base.allocator.available == 18

    # per-request counters surface in the metrics layer
    summary = summarize(spec.completed, wall=1.0)
    assert summary["spec_requests"] >= 1
    assert summary["spec_drafted_tokens"] > 0
    assert 0.0 <= summary["spec_accept_rate"] <= 1.0
    assert 0.0 <= summary["spec_accept_rate_p95"] <= 1.0
    base_summary = summarize(base.completed, wall=1.0)
    assert base_summary["spec_drafted_tokens"] == 0


def test_spec_equals_baseline_sliding_window(smoke_serving_setup):
    """gemma3 mixes local (ring-cache) and global layers: the ring is
    over-provisioned by k so a rewind never reads a wrapped-over slot."""
    del smoke_serving_setup  # only to share session ordering
    cfg = get_smoke_config("gemma3-12b")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    reqs = _mixed_requests(cfg.vocab_size, n=4, gen=10)
    kw = dict(num_slots=2, max_len=32, page_size=8, num_pages=8,
              prefix_cache=False, alloc_policy="ondemand")
    base = Engine(cfg, qcfg, mcfg, params, **kw)
    base.run(reqs)
    spec = Engine(cfg, qcfg, mcfg, params, **kw,
                  speculate_k=3, draft_bitwidth=7)
    spec.run(reqs)
    assert _gen_map(spec) == _gen_map(base)
    assert spec.allocator.available == base.allocator.available


def test_abort_mid_flight_returns_pages(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    spec = Engine(cfg, qcfg, mcfg, params, num_slots=3, max_len=48,
                  page_size=8, num_pages=18, prefix_cache=False,
                  alloc_policy="ondemand", speculate_k=3, draft_bitwidth=6)
    for r in _mixed_requests(cfg.vocab_size, n=3, gen=24):
        spec.submit(r)
    for _ in range(4):  # prefill + a spec cycle or two
        spec.step()
    assert spec.allocator.available < 18
    for rid in (0, 1, 2):
        spec.abort(rid)
    while spec.step():
        pass
    assert spec.allocator.available == 18  # pool back to baseline


# ---------------------------------------------------------------------------
# autotuning


def test_autotuner_visits_all_arms_then_exploits():
    cfg = SpecConfig(draft_bits=6, k=2, autotune=True, decide_every=1)
    tuner = SpecAutotuner(cfg)
    best = (8, 4)
    history = []
    for _ in range(60):
        arm = tuner.propose()
        history.append(arm)
        tuner.observe(arm, emitted=8 if arm == best else 1, wall_s=0.01,
                      class_accepts={"greedy": (1, 2)})
    assert set(history) == set(tuner.arms)  # every arm got measured
    # exploitation dominates: at most every 4th decision re-measures
    assert history[-8:].count(best) >= 6
    assert max(tuner.reward, key=tuner.reward.get) == best
    snap = tuner.snapshot()
    assert {"spec_arm_bits", "spec_arm_k", "spec_tuner_cycles"} <= set(snap)
    assert any(k.startswith("spec_reward_b") for k in snap)
    assert snap["spec_accept_rate_b8_greedy"] == pytest.approx(0.5)


def test_engine_autotune_smoke(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=10)
            for i in range(4)]
    base = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    base.run(reqs)
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 speculate_k=2, draft_bitwidth=8, spec_autotune=True)
    eng.run(reqs)
    # arm switches never change semantics — outputs still match baseline
    assert _gen_map(eng) == _gen_map(base)
    snap = eng.spec_snapshot()
    assert snap["spec_cycles"] > 0
    assert "spec_arm_bits" in snap and "spec_tuner_cycles" in snap
