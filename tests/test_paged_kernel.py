"""Paged-attend Pallas kernel vs the jnp reference backend (interpret
mode — the CPU CI leg runs these with REPRO_KERNEL_INTERPRET=1)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels.dispatch import _paged_attend_reference
from repro.kernels.ops import paged_attend_decode

pytestmark = pytest.mark.interpret


def _setup(seed=0, B=3, h=6, kv=2, hd=16, page=4, mp=5, P=11):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, h, hd)), jnp.float32)
    kd = rng.normal(size=(P + 1, page, kv, hd)).astype(np.float32)
    vd = rng.normal(size=(P + 1, page, kv, hd)).astype(np.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, mp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, mp * page + 1, (B,)), jnp.int32)
    return q, kd, vd, tbl, lengths


def test_kernel_matches_reference_dense_pool():
    q, kd, vd, tbl, lengths = _setup()
    ref = _paged_attend_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25)
    ker = paged_attend_decode(q, jnp.asarray(kd), jnp.asarray(vd),
                              None, None, tbl, lengths,
                              fmt=None, softcap=None, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


def test_kernel_matches_reference_lns_pool_with_softcap():
    """Packed-LNS pages decode tile-locally inside the kernel (the shared
    core.lns decode), scales applied per position/head."""
    q, kd, vd, tbl, lengths = _setup(seed=1)
    fmt = LNSFormat(bits=8, gamma=8)

    def enc(x):
        s = compute_scale(jnp.asarray(x), axis=(0, 1, 2))
        sign, code = lns_encode(jnp.asarray(x), fmt, s)
        scale = jnp.broadcast_to(s, x.shape[:-1] + (1,)).astype(jnp.bfloat16)
        return lns_pack(sign, code, fmt), scale

    pk, sk = enc(kd)
    pv, sv = enc(vd)
    ref = _paged_attend_reference(q, pk, pv, sk, sv, tbl, lengths,
                                  fmt=fmt, softcap=30.0, sm_scale=0.25)
    ker = paged_attend_decode(q, pk, pv, sk, sv, tbl, lengths,
                              fmt=fmt, softcap=30.0, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


def test_kernel_single_valid_position():
    """length == 1 (a slot right after a 1-token prompt): the online
    softmax must not divide by a zero denominator on later pages."""
    q, kd, vd, tbl, _ = _setup(seed=2)
    lengths = jnp.asarray([1, 1, 1], jnp.int32)
    ref = _paged_attend_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25)
    ker = paged_attend_decode(q, jnp.asarray(kd), jnp.asarray(vd),
                              None, None, tbl, lengths,
                              fmt=None, softcap=None, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(ker)).all()


def test_engine_decode_routes_through_kernel(monkeypatch):
    """REPRO_KERNEL_BACKEND=pallas + interpret: the paged engine's decode
    path reaches the kernel and still matches the reference backend."""
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    from repro.configs import get_smoke_config
    from repro.core.quantizer import QuantConfig
    from repro.optim.madam import MadamConfig
    from repro.serving import Engine, Request
    from repro.training import init_train_state

    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params

    def mk():
        rng = np.random.default_rng(4)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, (6,)).tolist(),
                        max_new_tokens=4) for i in range(2)]

    ref_eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
                     page_size=4)
    ref_eng.run(mk())
    ref = {rs.request.rid: rs.generated for rs in ref_eng.finished}

    import dataclasses
    qk = dataclasses.replace(qcfg, backend="pallas")
    kern_eng = Engine(cfg, qk, mcfg, params, num_slots=2, max_len=16,
                      page_size=4)
    kern_eng.run(mk())
    got = {rs.request.rid: rs.generated for rs in kern_eng.finished}
    assert ref == got
