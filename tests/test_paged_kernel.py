"""Paged-attend Pallas kernel vs the jnp reference backend (interpret
mode — the CPU CI leg runs these with REPRO_KERNEL_INTERPRET=1)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels.dispatch import (_fused_sample_reference,
                                    _paged_attend_reference, fused_sample)
from repro.kernels.ops import paged_attend_blocktable, paged_attend_decode

pytestmark = pytest.mark.interpret


def _setup(seed=0, B=3, h=6, kv=2, hd=16, page=4, mp=5, P=11, S=1):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, h, hd)), jnp.float32)
    kd = rng.normal(size=(P + 1, page, kv, hd)).astype(np.float32)
    vd = rng.normal(size=(P + 1, page, kv, hd)).astype(np.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, mp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(max(S, 1), mp * page + 1, (B,)),
                          jnp.int32)
    return q, kd, vd, tbl, lengths


def _assert_parity(q, kd, vd, tbl, lengths, **kw):
    ref = _paged_attend_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25, **kw)
    ker = paged_attend_blocktable(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25,
                                  interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)
    return ker


def test_kernel_matches_reference_dense_pool():
    q, kd, vd, tbl, lengths = _setup()
    ref = _paged_attend_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25)
    ker = paged_attend_decode(q, jnp.asarray(kd), jnp.asarray(vd),
                              None, None, tbl, lengths,
                              fmt=None, softcap=None, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


def test_kernel_matches_reference_lns_pool_with_softcap():
    """Packed-LNS pages decode tile-locally inside the kernel (the shared
    core.lns decode), scales applied per position/head."""
    q, kd, vd, tbl, lengths = _setup(seed=1)
    fmt = LNSFormat(bits=8, gamma=8)

    def enc(x):
        s = compute_scale(jnp.asarray(x), axis=(0, 1, 2))
        sign, code = lns_encode(jnp.asarray(x), fmt, s)
        scale = jnp.broadcast_to(s, x.shape[:-1] + (1,)).astype(jnp.bfloat16)
        return lns_pack(sign, code, fmt), scale

    pk, sk = enc(kd)
    pv, sv = enc(vd)
    ref = _paged_attend_reference(q, pk, pv, sk, sv, tbl, lengths,
                                  fmt=fmt, softcap=30.0, sm_scale=0.25)
    ker = paged_attend_decode(q, pk, pv, sk, sv, tbl, lengths,
                              fmt=fmt, softcap=30.0, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


def test_kernel_single_valid_position():
    """length == 1 (a slot right after a 1-token prompt): the online
    softmax must not divide by a zero denominator on later pages."""
    q, kd, vd, tbl, _ = _setup(seed=2)
    lengths = jnp.asarray([1, 1, 1], jnp.int32)
    ref = _paged_attend_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                  None, None, tbl, lengths,
                                  fmt=None, softcap=None, sm_scale=0.25)
    ker = paged_attend_decode(q, jnp.asarray(kd), jnp.asarray(vd),
                              None, None, tbl, lengths,
                              fmt=None, softcap=None, sm_scale=0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(ker)).all()


def test_kernel_partial_last_page():
    """Lengths that end mid-page: the tail positions of the final gathered
    page must be masked out, not averaged in."""
    q, kd, vd, tbl, _ = _setup(seed=3)
    page, mp = 4, 5
    # one length per interesting phase: 1 into a page, page-1, exactly full
    lengths = jnp.asarray([page + 1, 2 * page - 1, mp * page], jnp.int32)
    _assert_parity(q, kd, vd, tbl, lengths)


def test_kernel_null_page_entries():
    """Unreserved tail entries of a block table point at the sacrificial
    null page (pool index P). They sit beyond ``lengths`` so the causal
    mask must hide whatever garbage the null page holds."""
    q, kd, vd, tbl, _ = _setup(seed=4, P=11)
    np.asarray(kd)[11] = 1e30  # poison the null page
    np.asarray(vd)[11] = -1e30
    tbl = np.asarray(tbl).copy()
    lengths = jnp.asarray([5, 9, 2], jnp.int32)  # 2, 3, 1 pages reserved
    for b, n in enumerate([2, 3, 1]):
        tbl[b, n:] = 11  # null out everything past the reservation
    ker = _assert_parity(q, kd, vd, jnp.asarray(tbl), lengths)
    assert np.isfinite(np.asarray(ker)).all()


def test_kernel_prefix_shared_tables():
    """Two slots whose tables alias the same physical prefix pages (the
    prefix cache's CoW sharing) must each read the shared pages correctly;
    parity additionally pins the aliased reads to the gather oracle."""
    q, kd, vd, _, _ = _setup(seed=5, B=2, mp=4, P=9)
    tbl = jnp.asarray([[3, 7, 2, 5],
                       [3, 7, 8, 6]], jnp.int32)  # pages 3,7 shared
    lengths = jnp.asarray([14, 11], jnp.int32)
    ker = _assert_parity(q, kd, vd, tbl, lengths)
    # the shared prefix really is the same memory: re-run slot 1 with
    # slot 0's suffix pages — positions inside the shared prefix agree
    q0 = q[:1]
    ref_a = _paged_attend_reference(
        q0, jnp.asarray(kd), jnp.asarray(vd), None, None, tbl[:1],
        jnp.asarray([8], jnp.int32), fmt=None, softcap=None, sm_scale=0.25)
    ref_b = _paged_attend_reference(
        q0, jnp.asarray(kd), jnp.asarray(vd), None, None, tbl[1:],
        jnp.asarray([8], jnp.int32), fmt=None, softcap=None, sm_scale=0.25)
    np.testing.assert_allclose(np.asarray(ref_a), np.asarray(ref_b),
                               rtol=1e-6, atol=1e-6)
    assert np.isfinite(np.asarray(ker)).all()


@pytest.mark.parametrize("page,mp", [(4, 5), (8, 3), (16, 2)])
def test_kernel_page_size_parity(page, mp):
    """The same sequence budget under different page sizes: the kernel's
    per-page loop must be parametric in the block size."""
    q, kd, vd, tbl, lengths = _setup(seed=6, page=page, mp=mp)
    _assert_parity(q, kd, vd, tbl, lengths)


def test_kernel_prefill_over_block_table():
    """S > 1 (the engine's suffix prefill over a prefix-cached table):
    causal masking applies per query row, not just at the tail."""
    q, kd, vd, tbl, _ = _setup(seed=7, S=6)
    lengths = jnp.asarray([6, 13, 20], jnp.int32)  # n_cached = 0, 7, 14
    _assert_parity(q, kd, vd, tbl, lengths)


def test_kernel_prefill_lns_pool_softcap():
    q, kd, vd, tbl, _ = _setup(seed=8, S=4)
    lengths = jnp.asarray([4, 11, 17], jnp.int32)
    fmt = LNSFormat(bits=8, gamma=8)

    def enc(x):
        s = compute_scale(jnp.asarray(x), axis=(0, 1, 2))
        sign, code = lns_encode(jnp.asarray(x), fmt, s)
        scale = jnp.broadcast_to(s, x.shape[:-1] + (1,)).astype(jnp.bfloat16)
        return lns_pack(sign, code, fmt), scale

    pk, sk = enc(kd)
    pv, sv = enc(vd)
    ref = _paged_attend_reference(q, pk, pv, sk, sv, tbl, lengths,
                                  fmt=fmt, softcap=30.0, sm_scale=0.25)
    ker = paged_attend_blocktable(q, pk, pv, sk, sv, tbl, lengths,
                                  fmt=fmt, softcap=30.0, sm_scale=0.25,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused sampler epilogue


def test_fused_sample_greedy_bit_exact():
    """Greedy rows (gumbel=None) are bit-exact between backends including
    first-max-wins tie-breaking on duplicated maxima."""
    rng = np.random.default_rng(9)
    lg = rng.normal(size=(6, 300)).astype(np.float32)
    lg[2, 5] = lg[2, 77] = 50.0  # duplicated max: must pick index 5
    lg = jnp.asarray(lg)
    ref = _fused_sample_reference(lg, None, None)
    ker = fused_sample(lg, None, None, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))
    assert int(np.asarray(ker)[2]) == 5


def test_fused_sample_mixed_temperature_bit_exact():
    """Per-row temps (0 and >0 mixed in one batch): gumbel sampling where
    temp>0, greedy where temp==0 — same tokens on both backends, so a
    seeded request replays identically whichever backend serves it."""
    rng = np.random.default_rng(10)
    B, V = 8, 130  # V=130: exercises the pad-to-128-multiple path
    lg = jnp.asarray(rng.normal(size=(B, V)), jnp.float32)
    gum = jnp.asarray(rng.gumbel(size=(B, V)), jnp.float32)
    temp = jnp.asarray([0.0, 0.7, 1.0, 0.0, 1.3, 0.2, 0.0, 2.0], jnp.float32)
    ref = _fused_sample_reference(lg, gum, temp)
    ker = fused_sample(lg, gum, temp, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))
    # the temp==0 rows really are the greedy tokens
    greedy = np.argmax(np.asarray(lg), axis=-1)
    for b in (0, 3, 6):
        assert int(np.asarray(ker)[b]) == int(greedy[b])


def test_engine_decode_routes_through_kernel(monkeypatch):
    """REPRO_KERNEL_BACKEND=pallas + interpret: the paged engine's decode
    path reaches the kernel and still matches the reference backend."""
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    from repro.configs import get_smoke_config
    from repro.core.quantizer import QuantConfig
    from repro.optim.madam import MadamConfig
    from repro.serving import Engine, Request
    from repro.training import init_train_state

    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params

    def mk():
        rng = np.random.default_rng(4)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, (6,)).tolist(),
                        max_new_tokens=4) for i in range(2)]

    ref_eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
                     page_size=4)
    ref_eng.run(mk())
    ref = {rs.request.rid: rs.generated for rs in ref_eng.finished}

    import dataclasses
    qk = dataclasses.replace(qcfg, backend="pallas")
    kern_eng = Engine(cfg, qk, mcfg, params, num_slots=2, max_len=16,
                      page_size=4)
    kern_eng.run(mk())
    got = {rs.request.rid: rs.generated for rs in kern_eng.finished}
    assert ref == got
