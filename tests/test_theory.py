"""Empirical verification of Theorems 1/2 and Lemma 1 (paper §4.2, App. A).

The bounds hold in expectation under stochastic rounding. The *separation*
(Fig. 4) appears in the regime the paper works in: weights already on the
LNS grid (they are, in quantized training) and normalized gradients small
enough that γ·η·|g| < 1 — multiplicative rules then move integer exponents
by a small fraction while GD lands at generic off-grid points.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import error_analysis as ea


def _mean_error(key, rule, w, g, eta, gamma, trials=48):
    w = ea.snap_to_grid(w, gamma)
    errs = []
    for i in range(trials):
        k = jax.random.fold_in(key, i)
        if rule == "gd":
            w_new = ea.update_gd(w, g, eta)
        elif rule == "mul":
            w_new = ea.update_mul(w, g, eta)
        else:
            w_new = ea.update_signmul(w, g, eta)
        q = ea.simplified_qlog(k, w_new, gamma)
        errs.append(float(ea.quant_error(w_new, q)))
    return float(np.mean(errs))


@pytest.mark.parametrize("gamma", [64.0, 256.0])
def test_theorem_bounds_hold(key, gamma):
    d = 256
    w = jax.random.normal(key, (d,)) * 0.5 + 1.0
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1
    eta = 2.0 ** -6
    bounds = ea.theoretical_bounds(w, g, eta, gamma)
    assert _mean_error(key, "gd", w, g, eta, gamma) <= float(bounds["gd"]) + 1e-3
    assert _mean_error(key, "mul", w, g, eta, gamma) <= float(bounds["mul"]) + 1e-3
    assert _mean_error(key, "signmul", w, g, eta, gamma) <= float(bounds["signmul"]) + 1e-3


def test_multiplicative_below_gd(key):
    """Fig. 4's headline, in the paper's regime (γη|g| < 1)."""
    d, gamma, eta = 512, 1024.0, 2.0 ** -7
    w = jnp.exp2(jax.random.normal(key, (d,)) * 2.0)  # magnitudes over decades
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.003
    e_gd = _mean_error(key, "gd", w, g, eta, gamma)
    e_mul = _mean_error(key, "mul", w, g, eta, gamma)
    e_sign = _mean_error(key, "signmul", w, g, eta, gamma)
    assert e_mul < 0.5 * e_gd
    assert e_sign < 0.01 * e_gd


def test_gd_updates_disregarded_at_large_weights(key):
    """Fig. 1: with deterministic rounding, GD's additive update is rounded
    away entirely once the quantization gap exceeds it — the weight never
    moves — while signMUL always moves the integer exponent."""
    gamma = 64.0
    eta = 2.0 ** -6
    for mag in (64.0, 256.0):
        w = jnp.full((128,), mag)
        g = jnp.full((128,), 0.05)
        w_gd = ea.update_gd(w, g, eta)           # W - eta*g: tiny step
        q_gd = ea.snap_to_grid(w_gd, gamma)      # deterministic rounding
        assert bool(jnp.all(q_gd == ea.snap_to_grid(w, gamma)))  # swallowed
        w_sm = ea.update_signmul(w, g, eta)
        q_sm = ea.snap_to_grid(w_sm, gamma)
        assert bool(jnp.all(q_sm != ea.snap_to_grid(w, gamma)))  # moved


def test_signmul_bound_independent_of_w_and_g(key):
    """Lemma 1: E r <= d·η/γ regardless of weights/gradients."""
    d, gamma, eta = 256, 512.0, 2.0 ** -5
    bound = d * eta / gamma
    for i, (wmag, gmag) in enumerate([(0.1, 0.1), (10.0, 5.0), (100.0, 0.01)]):
        w = jax.random.normal(jax.random.fold_in(key, i), (d,)) * wmag + wmag
        g = jax.random.normal(jax.random.fold_in(key, i + 10), (d,)) * gmag
        e = _mean_error(key, "signmul", w, g, eta, gamma, trials=48)
        assert e <= bound


def test_error_decreases_with_gamma(key):
    """Both Fig. 4 panels: r_t shrinks as γ grows (finer grid)."""
    d, eta = 256, 2.0 ** -6
    w = jax.random.normal(key, (d,)) + 2.0
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1
    errs = [
        _mean_error(key, "gd", w, g, eta, gamma, trials=32)
        for gamma in (64.0, 256.0, 1024.0)
    ]
    assert errs[0] > errs[1] > errs[2]


def test_mul_error_grows_with_eta(key):
    """Fig. 4 left panel: multiplicative error scales with η (Thm. 2)."""
    d, gamma = 256, 1024.0
    w = jnp.exp2(jax.random.normal(key, (d,)))
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.003
    errs = [
        _mean_error(key, "mul", w, g, eta, gamma, trials=32)
        for eta in (2.0 ** -9, 2.0 ** -7, 2.0 ** -5)
    ]
    assert errs[0] < errs[1] < errs[2]


def test_sr_unbiased(key):
    from repro.numerics.rounding import stochastic_round
    x = jnp.full((50000,), 0.3)
    r = stochastic_round(key, x)
    assert float(jnp.mean(r)) == pytest.approx(0.3, abs=0.01)
