"""Chunked Mamba2/RWKV6 vs sequential-scan oracles; decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig
from repro.models.rwkv import _wkv_chunked, init_rwkv_state, rwkv_apply, rwkv_init
from repro.models.ssm import _ssd_chunked, init_mamba_state, mamba_apply, mamba_init

CFG = ArchConfig(name="t", family="ssm", num_layers=1, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 vocab_size=64, ssm_state_dim=8, ssm_head_dim=16,
                 ssm_chunk=8, rwkv_chunk=8, dtype="float32")


def _ssd_sequential(xs, dt, dA, Bv, Cv):
    B, S, H, P = xs.shape
    N = Bv.shape[-1]

    def step(h, t):
        a = jnp.exp(dA[:, t])
        h = a[:, :, None, None] * h + jnp.einsum(
            "bhp,bn,bh->bhpn", xs[:, t], Bv[:, t], dt[:, t])
        y = jnp.einsum("bhpn,bn->bhp", h, Cv[:, t])
        return h, y

    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        h, y = step(h, t)
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,Q", [(16, 4), (32, 8), (24, 24)])
def test_ssd_chunked_equals_sequential(key, S, Q):
    B, H, P, N = 2, 3, 8, 4
    xs = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    dA = -dt * jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.2)
    Bv = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cv = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    y, h = _ssd_chunked(xs, dt, dA, Bv, Cv, Q)
    y_ref, h_ref = _ssd_sequential(xs, dt, dA, Bv, Cv)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def _wkv_sequential(r, k, v, logw, u):
    B, S, H, P = r.shape
    s = jnp.zeros((B, H, P, P))
    ys = []
    for t in range(S):
        att = s + u[None, :, :, None] * k[:, t, :, :, None] * v[:, t, :, None, :]
        ys.append(jnp.einsum("bhp,bhpq->bhq", r[:, t], att))
        s = jnp.exp(logw[:, t])[..., None] * s \
            + k[:, t, :, :, None] * v[:, t, :, None, :]
    return jnp.stack(ys, axis=1), s


@pytest.mark.parametrize("S,Q", [(16, 4), (32, 8), (16, 16)])
def test_wkv_chunked_equals_sequential(key, S, Q):
    B, H, P = 2, 2, 8
    r = jax.random.normal(key, (B, S, H, P))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, P))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                      (B, S, H, P)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, P)) * 0.1
    y, s = _wkv_chunked(r, k, v, logw, u, Q)
    y_ref, s_ref = _wkv_sequential(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_continuation(key):
    """Train-mode forward over S tokens == decode one token at a time."""
    p = mamba_init(key, CFG)
    S = 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, CFG.d_model))
    full, _ = mamba_apply(p, x, CFG, None)
    state = init_mamba_state(2, CFG)
    outs = []
    for t in range(S):
        o, state = mamba_apply(p, x[:, t:t + 1], CFG, None, state=state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_decode_continuation(key):
    p = rwkv_init(key, CFG)
    S = 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, CFG.d_model))
    xc = jax.random.normal(jax.random.fold_in(key, 2), (2, S, CFG.d_model))
    (tm_full, cm_full), _ = rwkv_apply(p, x, xc, CFG, None)
    state = init_rwkv_state(2, CFG)
    tms, cms = [], []
    for t in range(S):
        (tm, cm), state = rwkv_apply(p, x[:, t:t + 1], xc[:, t:t + 1], CFG,
                                     None, state=state)
        tms.append(tm)
        cms.append(cm)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(tms, 1)),
                               np.asarray(tm_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(cms, 1)),
                               np.asarray(cm_full), rtol=2e-3, atol=2e-3)


def test_rwkv_decay_is_data_dependent(key):
    """Finch's headline feature: different inputs -> different decays."""
    p = rwkv_init(key, CFG)
    p = dict(p, w_lora_b=jax.random.normal(key, p["w_lora_b"].shape) * 0.5)
    x1 = jnp.ones((1, 4, CFG.d_model))
    x2 = -jnp.ones((1, 4, CFG.d_model))
    (tm1, _), s1 = rwkv_apply(p, x1, x1, CFG, None,
                              state=init_rwkv_state(1, CFG))
    (tm2, _), s2 = rwkv_apply(p, x2, x2, CFG, None,
                              state=init_rwkv_state(1, CFG))
    assert not np.allclose(np.asarray(s1["S"]), np.asarray(s2["S"]))
