"""Gradient compression: LNS-compressed all-reduce, error feedback,
signSGD majority vote (beyond-paper distributed feature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.lns import LNSFormat
from repro.optim.compression import (error_feedback_update,
                                     lns_compressed_psum, sign_majority_psum)

FMT = LNSFormat(bits=8, gamma=8)


def test_error_feedback_reduces_bias(key):
    """With error feedback, the running sum of quantized grads tracks the
    running sum of true grads (compression error doesn't accumulate)."""
    g = jax.random.normal(key, (64,)) * 0.3
    residual = jnp.zeros((64,))
    acc_q = jnp.zeros((64,))
    for i in range(50):
        q, residual = error_feedback_update({"g": g}, {"g": residual}, FMT)
        q, residual = q["g"], residual["g"]
        acc_q = acc_q + q
    acc_true = 50 * g
    rel = float(jnp.linalg.norm(acc_q - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.02  # unbiased up to the last step's residual


def test_plain_quantize_accumulates_bias(key):
    """Without feedback the same loop drifts more — why EF matters."""
    from repro.core.lns import lns_quantize
    g = jax.random.normal(key, (64,)) * 0.3
    acc_q = jnp.zeros((64,))
    for _ in range(50):
        acc_q = acc_q + lns_quantize(g, FMT)
    rel_nofb = float(jnp.linalg.norm(acc_q - 50 * g) / jnp.linalg.norm(50 * g))
    assert rel_nofb > 0.002  # deterministic rounding bias accumulates


def test_lns_compressed_psum_single_device(key):
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(key, (16,))}

    def f(g):
        out, _ = lns_compressed_psum(g, "data", FMT)
        return out

    out = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P())(grads)
    # single participant: psum of the quantized grad == quantized grad
    from repro.core.lns import lns_quantize
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(lns_quantize(grads["w"], FMT)),
                               rtol=1e-6)


def test_sign_majority_psum_single_device(key):
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(key, (16,))}

    def f(g):
        return sign_majority_psum(g, "data")

    out = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P())(grads)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.sign(np.asarray(grads["w"])))


def test_compressed_wire_bytes(key):
    """The wire format is 1 byte/element + one f32 scale: a 4x cut vs f32."""
    from repro.core.lns import compute_scale, lns_encode, lns_pack
    g = jax.random.normal(key, (1024,))
    s = compute_scale(g)
    sign, code = lns_encode(g, FMT, s)
    packed = lns_pack(sign, code, FMT)
    wire = packed.size * packed.dtype.itemsize + 4
    assert wire <= g.size * 4 / 3.9
