"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode — the kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import given, settings, st
from repro.core.lns import LNSFormat, compute_scale, lns_encode, lns_pack
from repro.kernels import lns_matmul, lns_qmatmul, madam_step, quantize_pack
from repro.kernels import ref as kref
from repro.kernels.lns_matmul import lns_matmul_pallas
from repro.kernels.lns_qmatmul import lns_qmatmul_pallas
from repro.kernels.lns_quantize import lns_quantize_pallas
from repro.kernels.madam_update import madam_update_pallas

# kernel bodies execute in Python on CPU (interpret mode): correct but slow
pytestmark = pytest.mark.interpret

FMT = LNSFormat(bits=8, gamma=8)


def _packed(key, shape, fmt=FMT):
    x = jax.random.normal(key, shape)
    s = compute_scale(x)
    sign, code = lns_encode(x, fmt, s)
    return lns_pack(sign, code, fmt), x, s


# ---------------------------------------------------------------------------
# bit-exact datapath kernel


@pytest.mark.parametrize("m,k,n", [(128, 16, 128), (128, 32, 256),
                                   (256, 64, 128)])
@pytest.mark.parametrize("gamma", [2, 8])
def test_lns_matmul_bit_exact(key, m, k, n, gamma):
    fmt = LNSFormat(bits=8, gamma=gamma)
    pa, _, _ = _packed(jax.random.fold_in(key, 1), (m, k), fmt)
    pb, _, _ = _packed(jax.random.fold_in(key, 2), (k, n), fmt)
    out = lns_matmul_pallas(pa, pb, fmt, block_k=16)
    ref = kref.lns_matmul_ref(pa, pb, fmt, block_k=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("lut_entries", [1, 2, 4, 8])
def test_lns_matmul_hybrid_bit_exact(key, lut_entries):
    """App.-B Mitchell hybrid at every LUT size (Table 10 sweep)."""
    pa, _, _ = _packed(jax.random.fold_in(key, 1), (128, 32))
    pb, _, _ = _packed(jax.random.fold_in(key, 2), (32, 128))
    out = lns_matmul_pallas(pa, pb, FMT, lut_entries=lut_entries, block_k=16)
    ref = kref.lns_matmul_ref(pa, pb, FMT, lut_entries=lut_entries,
                              block_k=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_lns_matmul_end_to_end_accuracy(key):
    """The integer datapath approximates the fp32 matmul to quantization
    accuracy (both operands on the 8-bit LNS grid)."""
    a = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    b = jax.random.normal(jax.random.fold_in(key, 2), (48, 40))
    out = lns_matmul(a, b, FMT)
    exact = jnp.dot(a, b)
    err = float(jnp.max(jnp.abs(out - exact)))
    assert err < 0.12 * float(jnp.max(jnp.abs(exact)))


def test_lns_matmul_saturation():
    """Accumulator clamps at +/-(2^23 - 1) like the 24-bit collector."""
    fmt = LNSFormat(bits=8, gamma=8)
    # all-max-magnitude positive codes: every product is 1.0 = 2^16 in Q7.16
    pa = jnp.zeros((128, 256), jnp.uint8)       # code 0, sign + -> value 1.0
    pb = jnp.zeros((256, 128), jnp.uint8)
    out = lns_matmul_pallas(pa, pb, fmt, block_k=16)
    # unsaturated sum would be 256 * 2^16 = 2^24 > SAT24
    assert int(out[0, 0]) == kref.SAT24
    ref = kref.lns_matmul_ref(pa, pb, fmt, block_k=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# fused dequant -> MXU matmul


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (100, 60, 36)])  # odd shapes exercise padding
def test_lns_qmatmul_vs_ref(key, m, k, n):
    pa, a, sa = _packed(jax.random.fold_in(key, 1), (m, k))
    pb, b, sb = _packed(jax.random.fold_in(key, 2), (k, n))
    out = lns_qmatmul(pa, pb, FMT, sa, sb)
    ref = kref.lns_qmatmul_ref(pa, pb, FMT, compute_dtype=jnp.bfloat16) * sa * sb
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_lns_qmatmul_accuracy_vs_fp32(key):
    pa, a, sa = _packed(jax.random.fold_in(key, 1), (128, 128))
    pb, b, sb = _packed(jax.random.fold_in(key, 2), (128, 128))
    out = lns_qmatmul(pa, pb, FMT, sa, sb)
    exact = jnp.dot(a, b)
    rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.08  # 8-bit LNS quantization + bf16 MXU rounding


# ---------------------------------------------------------------------------
# fused quantize+pack


@pytest.mark.parametrize("r,c", [(256, 256), (512, 300), (100, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_exact(key, r, c, dtype):
    x = jax.random.normal(key, (r, c)).astype(dtype)
    packed, srow = quantize_pack(x, FMT, scale_axis=0)
    ref = kref.lns_quantize_ref(x, srow, FMT)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref))


@given(st.integers(1, 4), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_quantize_kernel_property(seed, cols):
    """Packed output always decodes to within one grid step of the input."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, cols)) * 3.0
    packed, srow = quantize_pack(x, FMT, scale_axis=0)
    code = (packed & 0x7F).astype(jnp.float32)
    sign = 1.0 - 2.0 * (packed >> 7).astype(jnp.float32)
    dec = sign * jnp.exp2(-code / FMT.gamma) * srow
    rel = jnp.abs(dec - x) / jnp.maximum(jnp.abs(x), 1e-6)
    grid = 2.0 ** (1.0 / (2 * FMT.gamma)) - 1.0
    floor = srow * 2.0 ** (-FMT.dynamic_range)
    ok = (rel <= grid + 1e-5) | (jnp.abs(x) <= floor)
    assert bool(jnp.all(ok))


# ---------------------------------------------------------------------------
# fused Madam update


@pytest.mark.parametrize("r,c", [(256, 256), (100, 70), (512, 10)])
def test_madam_kernel_exact(key, r, c):
    ufmt = LNSFormat(bits=16, gamma=8 * 256)
    code = jax.random.randint(jax.random.fold_in(key, 1), (r, c), 0,
                              ufmt.max_code, jnp.int32).astype(jnp.int16)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5,
                                          (r, c)), 1, -1).astype(jnp.int8)
    g = jax.random.normal(jax.random.fold_in(key, 3), (r, c))
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 4), (r, c)))
    nc, nv = madam_step(code, sign, g, v, jnp.asarray(7), ufmt, lr=2.0 ** -7)
    rc, rv = kref.madam_update_ref(code, sign, g, v, ufmt, lr=2.0 ** -7,
                                   beta=0.999, count=7)
    np.testing.assert_array_equal(np.asarray(nc), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(nv), np.asarray(rv), rtol=1e-6)


def test_madam_kernel_matches_optimizer(key):
    """The fused kernel reproduces optim.madam's leaf update bit-for-bit."""
    from repro.core.lns import lns_pack
    from repro.optim.madam import LNSWeight, MadamConfig, madam_lns
    mcfg = MadamConfig()
    ufmt = mcfg.update_format
    code = jax.random.randint(key, (64, 32), 0, ufmt.max_code,
                              jnp.int32).astype(jnp.int16)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                          (64, 32)), 1, -1).astype(jnp.int8)
    scale = jnp.ones((1, 32))
    params = {"w": LNSWeight(packed=lns_pack(sign, code, ufmt), scale=scale,
                             fmt=ufmt)}
    init, update = madam_lns(mcfg)
    st0 = init(params)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 2), (64, 32))}
    new_p, new_st = update(g, st0, params)
    # kernel path: v starts at 0, count becomes 1
    knc, knv = madam_step(code, sign, g["w"], jnp.zeros((64, 32)),
                          jnp.asarray(1), ufmt, lr=mcfg.lr, beta=mcfg.beta,
                          eps=mcfg.eps)
    np.testing.assert_array_equal(np.asarray(new_p["w"].code), np.asarray(knc))
    np.testing.assert_array_equal(np.asarray(new_p["w"].sign), np.asarray(sign))
    np.testing.assert_allclose(np.asarray(new_st.g2["w"]), np.asarray(knv),
                               rtol=1e-6)


def test_madam_packed_kernel_matches_unpacked(key):
    """Packed-word kernel == unpacked (code, sign) kernel, word for word."""
    from repro.core.lns import lns_pack, lns_unpack
    ufmt = LNSFormat(bits=16, gamma=8 * 256)
    code = jax.random.randint(key, (100, 70), 0, ufmt.max_code,
                              jnp.int32).astype(jnp.int16)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                          (100, 70)), 1, -1).astype(jnp.int8)
    g = jax.random.normal(jax.random.fold_in(key, 2), (100, 70))
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (100, 70)))
    packed = lns_pack(sign, code, ufmt)
    from repro.kernels import madam_step_packed
    npk, nv = madam_step_packed(packed, g, v, jnp.asarray(5), ufmt,
                                lr=2.0 ** -7)
    rc, rv = madam_step(code, sign, g, v, jnp.asarray(5), ufmt, lr=2.0 ** -7)
    s2, c2 = lns_unpack(npk, ufmt)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(sign))
    np.testing.assert_allclose(np.asarray(nv), np.asarray(rv), rtol=1e-6)
