"""Per-layer LNS numerics telemetry (DESIGN.md §14, ISSUE 10).

Covers the in-graph stat epilogue (brute-force numpy oracle + pallas
parity), the induced-saturation flag, the host-side NumericsObserver
round-trips (jsonl / Prometheus with per-layer labels / Chrome trace
counter tracks + validator), and the serving-side numerics block.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lns import (LNSFormat, compute_scale, lns_encode, lns_pack,
                            lns_unpack, lns_weight_encode, quantization_gap)
from repro.kernels import dispatch, ops
from repro.kernels.madam_update import (MADAM_STAT_KEYS, MADAM_STAT_WIDTH,
                                        madam_stats_dict, madam_stats_vec,
                                        requant_spec)
from repro.obs.numerics import (NumericsObserver, REQUIRED_TRAIN_COUNTERS,
                                encode_sat_stats, grad_encode_stats,
                                tree_code_stats, validate_train_trace)
from repro.obs.prom import parse_prometheus_text
from repro.optim.madam import MadamConfig, init_lns_params, madam_lns


FMT = LNSFormat(bits=8, gamma=8)


def _packed_inputs(key, shape=(32, 48)):
    kx, kg = jax.random.split(key)
    x = jax.random.normal(kx, shape) * 0.5
    w = lns_weight_encode(x, FMT)
    g = jax.random.normal(kg, shape) * 0.01
    v = jnp.zeros(shape, jnp.float32)
    return w, g, v


# ---------------------------------------------------------------------------
# stat vector: brute-force numpy oracle


def _numpy_stats(packed, g, v, count, fmt, *, lr, beta, eps, requant=None):
    """Independent float32 numpy re-derivation of the fused epilogue."""
    w = np.asarray(packed).astype(np.int64)
    code = (w & fmt.max_code).astype(np.float32)
    sign = 1.0 - 2.0 * ((w >> (fmt.bits - 1)) & 1).astype(np.float32)
    g = np.asarray(g, dtype=np.float32)
    v = np.asarray(v, dtype=np.float32)
    nv = np.float32(1.0 - beta) * g * g + np.float32(beta) * v
    bc = np.float32(1.0 - beta ** float(count))
    gstar = g / np.sqrt(nv / bc + np.float32(eps))
    target = code + np.float32(lr * fmt.gamma) * gstar * sign
    rounded = np.floor(target + 0.5)
    new_code = np.clip(rounded, 0, fmt.max_code)
    n = code.size
    stats = {
        "sat_lo": np.sum(rounded < 0) / n,
        "sat_hi": np.sum(rounded > fmt.max_code) / n,
        "dead_frac": np.sum((new_code == code) & (target != code)) / n,
        "qerr_rel": np.mean(np.abs(
            2.0 ** (-(new_code - target) / fmt.gamma) - 1.0)),
        # drift signal: tracks the POST-update code (where weights head)
        "code_mean": np.mean(new_code),
    }
    if requant is not None:
        r, dst_max = requant
        stats["requant_sat_hi"] = np.sum(
            (new_code + r // 2) // r > dst_max) / n
    else:
        stats["requant_sat_hi"] = 0.0
    return stats


@pytest.mark.parametrize("requant_fmt", [None, LNSFormat(bits=8, gamma=8)])
def test_update_stats_match_numpy_bruteforce(key, requant_fmt):
    src = LNSFormat(bits=16, gamma=2048) if requant_fmt else FMT
    kx, kg, kv = jax.random.split(key, 3)
    x = jax.random.normal(kx, (16, 24)) * 0.5
    w = lns_weight_encode(x, src)
    g = jax.random.normal(kg, (16, 24)) * 0.02
    # v > 0 keeps gstar off the exact ±1 fixed point a cold second moment
    # produces (lr·γ·gstar would land every element on a rounding tie)
    v = jax.random.uniform(kv, (16, 24), jnp.float32, 1e-5, 1e-3)
    lr, beta, eps = 2.0 ** -4, 0.999, 1e-30
    pk, nv, stats = dispatch.madam_step(
        w.packed, g, v, jnp.ones((), jnp.int32), src, lr=lr, beta=beta,
        eps=eps, with_stats=True, requant_fmt=requant_fmt,
        backend="reference")
    want = _numpy_stats(w.packed, g, v, 1, src, lr=lr, beta=beta, eps=eps,
                        requant=requant_spec(src, requant_fmt))
    for k, expect in want.items():
        got = float(stats[k])
        assert got == pytest.approx(expect, rel=1e-4, abs=1e-6), \
            (k, got, expect)
    # the gap-normalized error references Thm. 1's quantization_gap
    gap = float(quantization_gap(jnp.ones(()), src))
    assert float(stats["qerr_gap_ratio"]) == pytest.approx(
        float(stats["qerr_rel"]) / gap, rel=1e-5)
    # stats never perturb the update itself
    pk2, nv2 = dispatch.madam_step(
        w.packed, g, v, jnp.ones((), jnp.int32), src, lr=lr, beta=beta,
        eps=eps, backend="reference")
    assert jnp.array_equal(pk, pk2) and jnp.allclose(nv, nv2)


def test_zero_gradient_is_all_fixed_points(key):
    w, _, v = _packed_inputs(key)
    g = jnp.zeros(w.shape, jnp.float32)
    _, _, stats = dispatch.madam_step(
        w.packed, g, v, jnp.ones((), jnp.int32), FMT, lr=0.1,
        with_stats=True, backend="reference")
    for k in ("sat_lo", "sat_hi", "dead_frac", "qerr_rel"):
        assert float(stats[k]) == 0.0, (k, float(stats[k]))


@pytest.mark.interpret
def test_pallas_stats_match_reference(key):
    """The fused-kernel epilogue and the jnp reference agree exactly —
    including the 256-block padding, which must contribute zero."""
    w, g, v = _packed_inputs(key, shape=(40, 72))  # forces padding
    count = jnp.ones((), jnp.int32)
    requant = requant_spec(LNSFormat(bits=16, gamma=2048), FMT)
    src = LNSFormat(bits=16, gamma=2048)
    x = jax.random.normal(key, (40, 72)) * 0.5
    w16 = lns_weight_encode(x, src)
    with dispatch.configured(backend="reference"):
        _, _, ref = dispatch.madam_step(
            w16.packed, g, v, count, src, lr=2.0 ** -4, with_stats=True,
            requant_fmt=FMT)
    npk, nvv, vec = ops.madam_step_packed_stats(
        w16.packed, g, v, count, src, lr=2.0 ** -4, requant=requant,
        interpret=True)
    got = madam_stats_dict(vec, w16.packed.size, src, requant_fmt=FMT)
    assert vec.shape == (MADAM_STAT_WIDTH,)
    for k in MADAM_STAT_KEYS:
        assert float(got[k]) == pytest.approx(float(ref[k]), abs=1e-7), k


# ---------------------------------------------------------------------------
# induced saturation: the regime the telemetry exists to flag


def test_induced_saturation_is_flagged(key):
    """An oversized multiplicative LR rails exponent codes on step one
    (v starts at 0, so gstar == sign(g) and the step is ±lr·γ codes);
    a healthy LR shows ~zero saturation on the same tree."""
    params = {"wq": lns_weight_encode(
        jax.random.normal(key, (32, 32)) * 0.3, FMT)}
    grads = {"wq": jax.random.normal(jax.random.fold_in(key, 1),
                                     (32, 32)) * 0.01}

    def run(lr):
        init, update = madam_lns(MadamConfig(lr=lr))
        _, _, stats = update(grads, init(params), params, with_stats=True)
        s = stats["wq"]
        return float(s["sat_lo"]) + float(s["sat_hi"])

    assert run(2.0 ** -7) == pytest.approx(0.0, abs=1e-6)
    assert run(8.0) > 0.25  # ±64-code jumps from mid-range hit a rail


def test_encode_sat_stats_flags_tiny_bitwidth(key):
    x = jnp.exp2(jax.random.normal(key, (64, 64)) * 4.0)
    healthy = encode_sat_stats(x, LNSFormat(bits=8, gamma=8))
    starved = encode_sat_stats(x, LNSFormat(bits=4, gamma=8))
    # whole-tensor absmax scale: the overflow rail is unreachable
    assert float(healthy["sat_lo"]) == 0.0
    # 3 exponent bits at γ=8 cover <1 octave: most values underflow
    assert float(starved["sat_hi"]) > float(healthy["sat_hi"])
    assert float(starved["sat_hi"]) > 0.5
    # scale_log2 tracks the pow2 scale the encode actually uses
    assert float(healthy["scale_log2"]) == float(
        jnp.log2(compute_scale(x)))


def test_grad_encode_stats_layers(key):
    from repro.core.quantizer import QuantConfig
    qcfg = QuantConfig.lns_madam()
    grads = {"a": jax.random.normal(key, (8, 8)),
             "b": jax.random.normal(key, (4,)),  # 1-D: not quantized
             "nest": {"c": jax.random.normal(key, (8, 4))}}
    out = grad_encode_stats(grads, qcfg)
    assert set(out) == {"a", "nest.c"}
    assert set(out["a"]) == {"sat_lo", "sat_hi", "scale_log2"}
    assert grad_encode_stats(grads, QuantConfig.full_precision()) == {}


# ---------------------------------------------------------------------------
# observer round-trips


def _fake_metrics(step):
    layers = {"embed.tok": 0.0, "blk0.attn.wq": 0.001 * step}
    upd = {layer: {"sat_lo": 0.0, "sat_hi": v, "dead_frac": 0.1,
                   "qerr_rel": 6e-5, "qerr_gap_ratio": 0.25,
                   "code_mean": 60.0, "requant_sat_hi": 0.0,
                   "scale_log2": 1.0}
           for layer, v in layers.items()}
    enc = {layer: {"sat_lo": 0.0, "sat_hi": 0.0001, "scale_log2": -3.0}
           for layer in layers}
    return {"loss": jnp.float32(3.0 - 0.1 * step),
            "grad_norm": jnp.float32(1.0),
            "numerics": {"update": upd, "grad_encode": enc}}


def test_observer_jsonl_and_summary(tmp_path):
    log = tmp_path / "steps.jsonl"
    obs = NumericsObserver(log_path=str(log), quiet=True)
    for s in range(1, 4):
        obs.record_step(s, _fake_metrics(s), walltime_s=0.01)
    obs.close()
    rows = [json.loads(x) for x in log.read_text().splitlines()]
    assert [r["step"] for r in rows] == [1, 2, 3]
    assert all("numerics" in r and "loss" in r for r in rows)
    summ = obs.summary()
    assert summ["steps"] == 3
    assert summ["update.sat_hi_max"] == pytest.approx(0.003)
    assert summ["worst_sat_site"] == "update:blk0.attn.wq"


def test_observer_prometheus_per_layer_labels():
    obs = NumericsObserver(quiet=True)
    obs.record_step(1, _fake_metrics(1), walltime_s=0.01)
    parsed = parse_prometheus_text(obs.prom_text())
    fam = parsed["repro_numerics_update_sat_hi"]
    layers = {lab["layer"]: v for lab, v in fam["samples"]
              if lab.get("layer")}
    assert set(layers) == {"embed.tok", "blk0.attn.wq"}
    assert layers["blk0.attn.wq"] == pytest.approx(0.001)
    # the aggregate alongside the labeled family
    agg = parsed["repro_numerics_update_sat_hi_max"]["samples"]
    assert agg[0][1] == pytest.approx(0.001)


def test_observer_chrome_trace_validates():
    obs = NumericsObserver(quiet=True)
    for s in range(1, 4):
        obs.record_step(s, _fake_metrics(s), walltime_s=0.01)
    doc = obs.to_chrome()
    stats = validate_train_trace(doc)
    assert stats["steps"] == 3
    for track in REQUIRED_TRAIN_COUNTERS:
        assert track in stats["tracks"]
    # per-layer series ride in the counter args
    assert stats["series"] >= 2 * len(REQUIRED_TRAIN_COUNTERS)


def test_validate_train_trace_rejections():
    obs = NumericsObserver(quiet=True)
    obs.record_step(1, _fake_metrics(1), walltime_s=0.01)
    doc = obs.to_chrome()
    with pytest.raises(ValueError, match="traceEvents"):
        validate_train_trace({"events": []})
    no_steps = {"traceEvents": [e for e in doc["traceEvents"]
                                if e.get("name") != "train_step"]}
    with pytest.raises(ValueError, match="train_step"):
        validate_train_trace(no_steps)
    no_counters = {"traceEvents": [
        e for e in doc["traceEvents"]
        if not str(e.get("name", "")).startswith("numerics/update")]}
    with pytest.raises(ValueError, match="counter track"):
        validate_train_trace(no_counters)


def test_observer_export_files(tmp_path):
    obs = NumericsObserver(quiet=True)
    obs.record_step(1, _fake_metrics(1), walltime_s=0.01)
    paths = obs.export(str(tmp_path), tag="unit")
    doc = json.loads(open(paths["trace"]).read())
    assert validate_train_trace(doc)["steps"] == 1
    summ = json.loads(open(paths["summary"]).read())
    assert summ["steps"] == 1


# ---------------------------------------------------------------------------
# instrumented train step (real graph, tiny model)


def test_train_step_numerics_aux(key):
    from repro.configs.paper_models import TINY_LM
    from repro.core.quantizer import QuantConfig
    from repro.training import build_train_step, init_train_state
    from repro.training.data import SyntheticLM

    cfg, qcfg = TINY_LM, QuantConfig.lns_madam()
    mcfg = MadamConfig(lr=2.0 ** -7)
    state = init_train_state(jax.random.PRNGKey(0), cfg, mcfg)
    step = jax.jit(build_train_step(cfg, qcfg, mcfg, numerics=True))
    data = SyntheticLM(cfg, batch=2, seq=8, seed=0)
    batch = jax.tree.map(jnp.asarray, next(iter(data)))
    new_state, metrics = step(state, batch)
    num = metrics["numerics"]
    assert set(num) == {"update", "grad_encode"}
    assert len(num["update"]) >= 4  # every LNS layer reports
    for layer, stats in num["update"].items():
        for k in MADAM_STAT_KEYS + ("scale_log2",):
            assert k in stats, (layer, k)
        assert 0.0 <= float(stats["sat_hi"]) <= 1.0
    # healthy config: nothing rails, update error near the RTN floor
    worst = max(float(s["sat_lo"]) + float(s["sat_hi"])
                for s in num["update"].values())
    assert worst < 0.05
    # plain step carries no numerics key (no silent overhead)
    plain = jax.jit(build_train_step(cfg, qcfg, mcfg))
    _, m2 = plain(state, batch)
    assert "numerics" not in m2


# ---------------------------------------------------------------------------
# serving side


def test_tree_code_stats(key):
    params = {"a": lns_weight_encode(jax.random.normal(key, (8, 8)), FMT),
              "b": jnp.ones((4,))}
    out = tree_code_stats(params)
    assert out["elements"] == 64
    assert 0.0 <= out["code0_frac"] <= 1.0
    assert 0.0 <= out["maxcode_frac"] <= 1.0
    assert 0.0 < out["code_mean"] < FMT.max_code
    assert tree_code_stats({"x": jnp.ones((2,))}) == {"elements": 0}


def test_engine_numerics_snapshot_and_health(smoke_serving_setup):
    from repro.serving import Engine
    from repro.server.driver import EngineDriver

    cfg, qcfg, mcfg, params = smoke_serving_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 speculate_k=2, draft_bitwidth=6)
    snap = eng.numerics_snapshot()
    assert snap["weights"]["elements"] > 0
    assert "draft_requant" not in snap  # no view built yet
    assert eng.numerics_snapshot() is snap  # cached
    eng._draft_params(6)
    snap2 = eng.numerics_snapshot()
    assert snap2 is not snap  # view build invalidates the cache
    dr = snap2["draft_requant"]["b6"]
    assert dr["bits"] == 6 and dr["elements"] > 0
    assert dr["rel_err_mean"] > 0.0  # a 6-bit re-grid is lossy
    assert 0.0 <= dr["sat_hi_frac"] <= 1.0

    driver = EngineDriver(eng, max_inflight=4).start()
    try:
        h = driver.health()
        assert h["numerics"]["weights"]["elements"] == \
            snap["weights"]["elements"]
    finally:
        driver.shutdown()


def test_draft_requant_error_identity_is_zero(smoke_serving_setup):
    from repro.serving.spec import build_draft_params, draft_requant_error

    _, _, _, params = smoke_serving_setup
    view8 = build_draft_params(params, 8)
    out = draft_requant_error(params, view8)
    assert out["rel_err_mean"] == 0.0 and out["sat_hi_frac"] == 0.0
    view6 = build_draft_params(params, 6)
    lossy = draft_requant_error(params, view6)
    assert lossy["rel_err_mean"] > 0.0
