"""Multi-base LNS format: representation, rounding, packing (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import given, settings, st
from repro.core.lns import (LNSFormat, compute_scale, lns_decode, lns_encode,
                            lns_pack, lns_quantize, lns_requant_packed,
                            lns_unpack, pow2_scale, quantization_gap)


# gamma=1 at 8 bits reaches 2^-127 (f32 subnormal edge) — the paper's own
# Table 3 marks that configuration NaN; we test it at 5 bits instead.
@pytest.mark.parametrize("bits,gamma", [(8, 8), (8, 2), (5, 1), (4, 2),
                                        (8, 32), (16, 2048), (12, 128)])
def test_encode_decode_roundtrip_on_grid(bits, gamma):
    """Decoded values re-encode to the same codes (grid is a fixed point)."""
    fmt = LNSFormat(bits=bits, gamma=gamma)
    codes = jnp.arange(fmt.max_code + 1, dtype=jnp.int32).astype(fmt.code_dtype)
    sign = jnp.where(jnp.arange(codes.size) % 2 == 0, 1, -1).astype(jnp.int8)
    scale = jnp.ones(())
    vals = lns_decode(sign, codes, fmt, scale)
    s2, c2 = lns_encode(vals, fmt, scale)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(sign))


def test_dynamic_range_matches_paper():
    """Table 3: B=8 γ=8 -> range (0, 15.875)."""
    fmt = LNSFormat(bits=8, gamma=8)
    assert fmt.max_code == 127
    assert fmt.dynamic_range == pytest.approx(15.875)


def test_with_bits_preserves_range():
    """§6.1.1: widening Q_U keeps the ~(0,15.9) dynamic range (exact up to
    the max_code = 2^(B-1)-1 off-by-one, <1%)."""
    fmt = LNSFormat(bits=8, gamma=8)
    for bits in (10, 12, 16):
        wide = fmt.with_bits(bits)
        assert wide.dynamic_range == pytest.approx(fmt.dynamic_range, rel=0.01)


@given(st.floats(min_value=-100.0, max_value=100.0,
                 allow_nan=False, allow_infinity=False))
@settings(max_examples=200, deadline=None)
def test_quantize_relative_error_bound(x):
    """|Q(x) - x| <= half the local quantization gap (plus clamp floor)."""
    fmt = LNSFormat(bits=8, gamma=8)
    xa = jnp.asarray([x], jnp.float32)
    q = lns_quantize(xa, fmt)
    if abs(x) < 1e-6:
        return  # near zero: clamped to smallest magnitude
    scale = float(pow2_scale(jnp.abs(xa))[0])
    if abs(x) / scale < 2.0 ** (-fmt.dynamic_range):
        return  # below the representable floor -> clamps
    rel = abs(float(q[0]) - x) / abs(x)
    # grid step is a factor 2^(1/γ): worst-case rel err ~ (2^(1/2γ) - 1)
    assert rel <= 2.0 ** (1.0 / (2 * fmt.gamma)) - 1.0 + 1e-6


def test_sign_preserved_and_monotone(key):
    fmt = LNSFormat(bits=8, gamma=8)
    x = jnp.sort(jnp.abs(jax.random.normal(key, (64,)))) + 0.01
    q = lns_quantize(x, fmt)
    assert bool(jnp.all(q > 0))
    assert bool(jnp.all(jnp.diff(q) >= 0))  # monotone non-decreasing
    qn = lns_quantize(-x, fmt)
    np.testing.assert_allclose(np.asarray(qn), -np.asarray(q), rtol=1e-6)


def test_pow2_scale_properties(key):
    x = jnp.abs(jax.random.normal(key, (100,))) + 1e-3
    s = pow2_scale(x)
    assert bool(jnp.all(s >= x))
    log = jnp.log2(s)
    np.testing.assert_allclose(np.asarray(log), np.round(np.asarray(log)),
                               atol=1e-6)


def test_per_channel_scale_shape(key):
    x = jax.random.normal(key, (4, 6, 8))
    s = compute_scale(x, axis=-1)
    assert s.shape == (1, 1, 8)
    s0 = compute_scale(x, axis=0)
    assert s0.shape == (4, 1, 1)


@given(st.integers(min_value=0, max_value=127),
       st.sampled_from([-1, 1]))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(code, sign):
    fmt = LNSFormat(bits=8, gamma=8)
    c = jnp.asarray([[code]], fmt.code_dtype)
    s = jnp.asarray([[sign]], jnp.int8)
    packed = lns_pack(s, c, fmt)
    assert packed.dtype == jnp.uint8
    s2, c2 = lns_unpack(packed, fmt)
    assert int(s2[0, 0]) == sign and int(c2[0, 0]) == code


def test_pack_is_hardware_wire_format():
    """MSB = sign bit, low 7 bits = exponent code."""
    fmt = LNSFormat(bits=8, gamma=8)
    packed = lns_pack(jnp.asarray([-1], jnp.int8),
                      jnp.asarray([5], jnp.int8), fmt)
    assert int(packed[0]) == 128 + 5


def test_stochastic_rounding_unbiased(key):
    fmt = LNSFormat(bits=8, gamma=8, stochastic=True)
    x = jnp.full((20000,), 1.3456)
    scale = jnp.full((), 2.0)
    keys = jax.random.split(key, 1)[0]
    sign, code = lns_encode(x, fmt, scale, key=keys)
    dec = lns_decode(sign, code, fmt, scale)
    # E[2^-SR(e)/γ] != 2^(-e/γ) exactly (Jensen) but must straddle x
    lo = float(jnp.min(dec))
    hi = float(jnp.max(dec))
    assert lo < 1.3456 < hi


def test_quantization_gap_grows_with_magnitude():
    fmt = LNSFormat(bits=8, gamma=8)
    g = quantization_gap(jnp.asarray([0.1, 1.0, 10.0]), fmt)
    assert float(g[0]) < float(g[1]) < float(g[2])


def test_zero_and_flush_zero():
    fmt = LNSFormat(bits=8, gamma=8)
    s, c = lns_encode(jnp.zeros((3,)), fmt, jnp.ones(()))
    assert bool(jnp.all(c == fmt.max_code))  # clamps to smallest magnitude
    fz = LNSFormat(bits=8, gamma=8, flush_zero=True)
    dec = lns_decode(s, c, fz, jnp.ones(()))
    assert bool(jnp.all(dec == 0.0))


# ---------------------------------------------------------------------------
# narrowing re-grid: the self-speculative draft transform (DESIGN.md §11)


def test_with_bits_narrowing_halves_gamma():
    """Dropping wire bits halves the base factor per bit so the dynamic
    range survives — a B=6/7 draft spans the same magnitudes as B=8, just
    on a coarser exponent grid."""
    fmt = LNSFormat(bits=8, gamma=8)
    assert fmt.with_bits(7) == LNSFormat(bits=7, gamma=4)
    assert fmt.with_bits(6) == LNSFormat(bits=6, gamma=2)
    # range match is exact up to the max_code = 2^(B-1)-1 off-by-one,
    # which costs one coarse step: 15.75 at B=7, 15.5 at B=6 (vs 15.875)
    for bits in (6, 7):
        assert fmt.with_bits(bits).dynamic_range == pytest.approx(
            fmt.dynamic_range, rel=0.03)


@pytest.mark.parametrize("bits", [6, 7])
def test_requant_narrow_is_projection(key, bits):
    """Narrow -> widen -> narrow lands on the same coarse words: the
    draft view is a projection, so re-deriving it is lossless."""
    fmt8 = LNSFormat(bits=8, gamma=8)
    dst = fmt8.with_bits(bits)
    codes = jax.random.randint(key, (4096,), 0, fmt8.max_code + 1, jnp.int32)
    sign = jnp.where(jnp.arange(codes.size) % 2 == 0, 1, -1).astype(jnp.int8)
    packed = lns_pack(sign, codes, fmt8)
    down = lns_requant_packed(packed, fmt8, dst)
    up = lns_requant_packed(down, dst, fmt8)
    down2 = lns_requant_packed(up, fmt8, dst)
    np.testing.assert_array_equal(np.asarray(down), np.asarray(down2))


@pytest.mark.parametrize("bits", [6, 7])
def test_requant_monotone_and_sign_preserved(bits):
    """Exhaustive over the B=8 grid: the narrow code is monotone in the
    source code and the sign bit rides across untouched."""
    fmt8 = LNSFormat(bits=8, gamma=8)
    dst = fmt8.with_bits(bits)
    codes = jnp.arange(fmt8.max_code + 1, dtype=jnp.int32)
    for sval in (1, -1):
        sign = jnp.full(codes.shape, sval, jnp.int8)
        out = np.asarray(lns_requant_packed(
            lns_pack(sign, codes, fmt8), fmt8, dst))
        np.testing.assert_array_equal(out >> (dst.bits - 1),
                                      np.full(codes.shape, int(sval < 0)))
        assert np.all(np.diff(out & dst.max_code) >= 0)


@pytest.mark.parametrize("bits", [6, 7])
def test_requant_draft_decode_error_bound(bits):
    """Every un-clamped draft value sits within half a coarse grid step of
    its source value (the re-grid rounds the exponent to nearest)."""
    fmt8 = LNSFormat(bits=8, gamma=8)
    dst = fmt8.with_bits(bits)
    codes = jnp.arange(fmt8.max_code + 1, dtype=jnp.int32)
    sign = jnp.ones(codes.shape, jnp.int8)
    packed = lns_pack(sign, codes, fmt8)
    out = lns_requant_packed(packed, fmt8, dst)
    s, c = lns_unpack(out, dst)
    got = np.asarray(lns_decode(s, c, dst, jnp.ones(())))
    want = np.asarray(lns_decode(sign, codes, fmt8, jnp.ones(())))
    unclamped = np.asarray(c) < dst.max_code
    rel = np.abs(got - want) / want
    assert rel[unclamped].max() <= 2.0 ** (1.0 / (2 * dst.gamma)) - 1 + 1e-6


def test_format_validation():
    with pytest.raises(ValueError):
        LNSFormat(bits=8, gamma=3)
    with pytest.raises(ValueError):
        LNSFormat(bits=1, gamma=8)


# ---------------------------------------------------------------------------
# quantization_gap vs a brute-force nearest-code search (ISSUE-10: the
# Thm.-1 normalizer behind qerr_gap_ratio must be exact, per format)


@pytest.mark.parametrize("bits,gamma", [(4, 2), (5, 1), (6, 4), (8, 8),
                                        (8, 2), (12, 128), (16, 2048)])
def test_quantization_gap_bruteforce(bits, gamma):
    """On every on-grid magnitude, the closed form |x|·(2^(1/γ)-1) equals
    the distance to the next representable value found by brute-force
    search over the whole code grid."""
    fmt = LNSFormat(bits=bits, gamma=gamma)
    grid = np.exp2(-np.arange(fmt.max_code + 1, dtype=np.float64) / gamma)
    # e >= 1: code 0 is the top of the grid, nothing representable above
    for e in range(1, min(fmt.max_code + 1, 64)):
        v = grid[e]
        above = grid[grid > v * (1 + 1e-12)]
        brute = above.min() - v
        got = float(quantization_gap(jnp.asarray(v, jnp.float32), fmt))
        assert got == pytest.approx(brute, rel=1e-5), (e, got, brute)
    # off-grid points: the gap is the local grid spacing at that magnitude
    # (scales linearly — factor-of-2 shifts multiply it by exactly 2)
    x = jnp.asarray([0.3, 0.6, 1.2], jnp.float32)
    g = np.asarray(quantization_gap(x, fmt))
    assert g[1] == pytest.approx(2 * g[0], rel=1e-6)
    assert g[2] == pytest.approx(2 * g[1], rel=1e-6)


# ---------------------------------------------------------------------------
# with_bits keep_range semantics + the extreme 8 -> 4 re-grid drop


def test_with_bits_keep_range_both_directions():
    fmt = LNSFormat(bits=8, gamma=8)
    # widening: gamma scales 2x per bit, range preserved (§6.1.1)
    wide = fmt.with_bits(16)
    assert wide == LNSFormat(bits=16, gamma=2048)
    assert wide.dynamic_range == pytest.approx(fmt.dynamic_range, rel=0.01)
    # narrowing: gamma halves per dropped bit until it floors at 1
    assert fmt.with_bits(6) == LNSFormat(bits=6, gamma=2)
    assert fmt.with_bits(6).dynamic_range == pytest.approx(
        fmt.dynamic_range, rel=0.03)
    # extreme drop 8 -> 4: gamma would need 16x shrink but only has 8x —
    # it floors at 1 and the dynamic range shrinks (7.0 vs 15.875)
    tiny = fmt.with_bits(4)
    assert tiny == LNSFormat(bits=4, gamma=1)
    assert tiny.dynamic_range == pytest.approx(7.0)
    # keep_range=False pins gamma: same grid spacing, truncated range
    assert fmt.with_bits(4, keep_range=False) == LNSFormat(bits=4, gamma=8)
    assert fmt.with_bits(16, keep_range=False) == LNSFormat(bits=16, gamma=8)
    # round-tripping the bitwidth restores the original format
    assert fmt.with_bits(6).with_bits(8) == fmt


def test_requant_extreme_drop_sign_preserved_at_rails():
    """8 -> 4 bits (γ 8 -> 1, ratio 8): the sign bit must survive at BOTH
    rails and every coarse code stays in [0, 7] with the hi rail clamped."""
    fmt8 = LNSFormat(bits=8, gamma=8)
    dst = fmt8.with_bits(4)
    assert fmt8.gamma // dst.gamma == 8
    codes = jnp.arange(fmt8.max_code + 1, dtype=jnp.int32)
    for sval in (1, -1):
        sign = jnp.full(codes.shape, sval, jnp.int8)
        out = np.asarray(lns_requant_packed(
            lns_pack(sign, codes, fmt8), fmt8, dst))
        s, c = lns_unpack(jnp.asarray(out), dst)
        c = np.asarray(c)
        # sign rides across on every word, including both rail codes
        np.testing.assert_array_equal(np.asarray(s),
                                      np.full(codes.shape, sval))
        # overflow rail (code 0, largest magnitude) maps to coarse code 0
        assert c[0] == 0
        # underflow rail (code 127, smallest magnitude) clamps to dst max
        assert c[-1] == dst.max_code == 7
        assert c.min() >= 0 and c.max() <= dst.max_code
        assert np.all(np.diff(c) >= 0)  # monotone through the clamp
        # round-to-nearest on the un-clamped body: code 20 -> (20+4)//8;
        # code 60 re-grids past the rail and clamps
        assert c[20] == 3 and c[60] == dst.max_code
    # packed MSB check at the rails, directly on the wire word
    neg = np.asarray(lns_requant_packed(
        lns_pack(jnp.full((2,), -1, jnp.int8),
                 jnp.asarray([0, fmt8.max_code], jnp.int32), fmt8),
        fmt8, dst))
    assert np.all((neg >> (dst.bits - 1)) & 1 == 1)
