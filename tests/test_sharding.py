"""Logical sharding rules, param-tree axis assignment, mesh resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.params_sharding import (batch_shardings,
                                               cache_logical_axes,
                                               opt_logical_axes,
                                               params_logical_axes,
                                               tree_shardings)
from repro.distributed.sharding import (LOGICAL_RULES, shard, shard_ctx,
                                        spec_for)
from repro.models import ArchConfig, init_params
from repro.optim.madam import LNSWeight, MadamConfig, init_lns_params, madam_lns


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_resolution_drops_missing_axes():
    mesh = _mesh()  # no "pod" axis
    with shard_ctx(mesh):
        spec = spec_for(("batch", "embed"))
        assert spec == P("data", None)  # ("pod","data") -> "data"


def test_shard_noop_without_mesh(key):
    x = jax.random.normal(key, (4, 4))
    y = shard(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_applies_constraint_under_mesh(key):
    x = jax.random.normal(key, (4, 4))
    with shard_ctx(_mesh()):
        y = jax.jit(lambda x: shard(x, "batch", "mlp"))(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rule_overrides(key):
    with shard_ctx(_mesh(), {"mlp": None}):
        assert spec_for((None, "mlp")) == P(None, None)
    with shard_ctx(_mesh()):
        assert spec_for((None, "mlp")) == P(None, "model")


def test_params_logical_axes_known_paths(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=4, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    params = init_params(key, cfg)
    axes = params_logical_axes(params)
    assert axes["embed"]["tok"] == ("vocab", "embed")
    assert axes["embed"]["head"] == ("embed", "vocab")
    # stacked period weights get the leading "stack" axis
    assert axes["period"]["pos0"]["mlp"]["up"] == ("stack", "embed", "mlp")
    assert axes["period"]["pos0"]["attn"]["wq"] == ("stack", "embed", "qkv_out")
    # norms are replicated (the "stack" prefix resolves to None anyway)
    assert axes["period"]["pos0"]["ln1"] == (None, None)


def test_lns_weight_axes_and_shardings(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    mcfg = MadamConfig()
    params = init_lns_params(init_params(key, cfg), mcfg)
    axes = params_logical_axes(params)
    lw = axes["period"]["pos0"]["mlp"]["up"]
    assert isinstance(lw, LNSWeight)
    assert lw.packed == ("stack", "embed", "mlp")
    # scale has a size-1 axis -> unsharded there
    assert lw.scale == ("stack", None, "mlp")
    sh = tree_shardings(axes, _mesh())
    leaf = sh["period"]["pos0"]["mlp"]["up"]
    assert leaf.packed.spec == P(None, None, "model")


def test_opt_axes_factored(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    mcfg = MadamConfig(factored=True)
    params = init_lns_params(init_params(key, cfg), mcfg)
    init, _ = madam_lns(mcfg)
    opt = init(params)
    oax = opt_logical_axes(params, opt)
    g2 = oax.g2["period"]["pos0"]["mlp"]["up"]
    assert g2 == {"r": ("stack", "embed"), "c": ("stack", "mlp")}


def test_cache_axes(key):
    from repro.models import init_caches
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    caches = init_caches(2, 16, cfg)
    axes = cache_logical_axes(caches)
    # k carries BOTH kv_seq and kv_heads: training rules give the model
    # axis to kv_seq (first wins), serving rules flip it to the head dim
    assert axes["period"]["pos0"]["k"] == ("stack", "batch", "kv_seq",
                                           "kv_heads", None)
    with shard_ctx(_mesh()):
        assert spec_for(axes["period"]["pos0"]["k"]) == \
            P(None, "data", "model", None, None)


def test_spec_for_dedups_mesh_axes_first_wins():
    with shard_ctx(_mesh()):
        # kv_seq and kv_heads both map to "model": the earlier dim keeps it
        assert spec_for(("batch", "kv_seq", "kv_heads", None)) == \
            P("data", "model", None, None)
    # serving-style override frees the axis for the later dim
    with shard_ctx(_mesh(), {"kv_seq": None}):
        assert spec_for(("batch", "kv_seq", "kv_heads", None)) == \
            P("data", None, "model", None)


def test_spec_for_tuple_axes_partially_present():
    # ("pod","data","model") with no "pod" in the mesh -> remaining axes
    mesh = _mesh()
    with shard_ctx(mesh):
        assert spec_for(("batch_full",)) == P(("data", "model"))
        # a tuple whose members were all consumed upstream collapses to None
        assert spec_for(("batch", "batch_full")) == P("data", "model")


def test_shard_ctx_nesting_and_restore_on_exception():
    mesh = _mesh()
    with shard_ctx(mesh, {"mlp": None}):
        assert spec_for((None, "mlp")) == P(None, None)
        with shard_ctx(mesh, {"mlp": "model", "embed": "data"}):
            assert spec_for(("embed", "mlp")) == P("data", "model")
        # inner overrides rolled back, outer still active
        assert spec_for((None, "mlp")) == P(None, None)
        with pytest.raises(RuntimeError):
            with shard_ctx(mesh, {"mlp": "model"}):
                assert spec_for((None, "mlp")) == P(None, "model")
                raise RuntimeError("boom")
        # exception unwound the inner context, not the outer one
        assert spec_for((None, "mlp")) == P(None, None)
    from repro.distributed.sharding import current_mesh
    assert current_mesh() is None


def test_lns_weight_packed_and_scale_specs_consistent(key):
    """spec_for over a packed LNSWeight pytree: the scale's non-unit dims
    resolve exactly like the packed words' (a shard never pairs its local
    codes with another shard's scale column)."""
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    params = init_lns_params(init_params(key, cfg), MadamConfig())
    axes = params_logical_axes(params)
    mesh = _mesh()
    sh = tree_shardings(axes, mesh)

    def check(ax, lf):
        if not isinstance(ax, LNSWeight):
            return
        packed_spec = spec_for(ax.packed, mesh)
        scale_spec = spec_for(ax.scale, mesh)
        assert lf.packed.spec == packed_spec
        assert lf.scale.spec == scale_spec
        # wherever the scale is non-unit it must match the packed spec
        for i, (pa, sa) in enumerate(zip(ax.packed, ax.scale)):
            if sa is not None:
                assert sa == pa

    is_axes_leaf = lambda x: isinstance(x, LNSWeight) or (
        isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                     for a in x))
    jax.tree.map(check, axes, sh, is_leaf=is_axes_leaf)


def test_batch_shardings(key):
    mesh = _mesh()
    b = {"tokens": jnp.zeros((4, 8), jnp.int32),
         "patches": jnp.zeros((4, 2, 16))}
    sh = batch_shardings(b, mesh)
    assert sh["tokens"].spec == P("data", None)
    assert sh["patches"].spec == P("data", None, None)


def test_unknown_logical_axis_raises():
    with shard_ctx(_mesh()):
        with pytest.raises(KeyError):
            spec_for(("no_such_axis",))


def test_make_host_mesh_raises_on_oversubscription():
    """A mesh request larger than the platform must raise (not silently
    collapse to (n, 1) — that let CI mesh legs pass vacuously)."""
    from repro.launch.mesh import make_host_mesh
    n = jax.device_count()
    with pytest.raises(ValueError) as ei:
        make_host_mesh(data=n, model=2)
    msg = str(ei.value)
    assert f"data={n}, model=2" in msg          # requested shape
    assert f"only {n} are available" in msg     # available count
    # the largest satisfiable shape still works
    assert make_host_mesh(data=n, model=1).devices.size == n


def test_serving_rules_divisibility_gates():
    from repro.distributed.sharding import serving_rules
    from repro.models import ArchConfig
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}

    div = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=4, num_kv_heads=4, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    rules = serving_rules(div, FakeMesh())
    assert rules["kv_heads"] == "model" and rules["qkv_out"] == "model"
    assert rules["mlp"] == "model"
    # equality-critical axes always replicate in serving
    assert rules["batch"] is None and rules["kv_seq"] is None
    assert rules["attn_out"] is None and rules["vocab"] is None

    # smollm-smoke shape: 3 heads / 1 kv head don't divide model=2
    odd = ArchConfig(name="t", family="dense", num_layers=2, d_model=48,
                     num_heads=3, num_kv_heads=1, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    rules = serving_rules(odd, FakeMesh())
    assert rules["kv_heads"] is None and rules["qkv_out"] is None
    assert rules["mlp"] == "model"  # d_ff still divides

    # trivial model axis -> nothing sharded at all
    rules = serving_rules(div, mesh2)
    assert all(v is None for v in rules.values())
