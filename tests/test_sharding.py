"""Logical sharding rules, param-tree axis assignment, mesh resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.params_sharding import (batch_shardings,
                                               cache_logical_axes,
                                               opt_logical_axes,
                                               params_logical_axes,
                                               tree_shardings)
from repro.distributed.sharding import (LOGICAL_RULES, shard, shard_ctx,
                                        spec_for)
from repro.models import ArchConfig, init_params
from repro.optim.madam import LNSWeight, MadamConfig, init_lns_params, madam_lns


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_resolution_drops_missing_axes():
    mesh = _mesh()  # no "pod" axis
    with shard_ctx(mesh):
        spec = spec_for(("batch", "embed"))
        assert spec == P("data", None)  # ("pod","data") -> "data"


def test_shard_noop_without_mesh(key):
    x = jax.random.normal(key, (4, 4))
    y = shard(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_applies_constraint_under_mesh(key):
    x = jax.random.normal(key, (4, 4))
    with shard_ctx(_mesh()):
        y = jax.jit(lambda x: shard(x, "batch", "mlp"))(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rule_overrides(key):
    with shard_ctx(_mesh(), {"mlp": None}):
        assert spec_for((None, "mlp")) == P(None, None)
    with shard_ctx(_mesh()):
        assert spec_for((None, "mlp")) == P(None, "model")


def test_params_logical_axes_known_paths(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=4, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    params = init_params(key, cfg)
    axes = params_logical_axes(params)
    assert axes["embed"]["tok"] == ("vocab", "embed")
    assert axes["embed"]["head"] == ("embed", "vocab")
    # stacked period weights get the leading "stack" axis
    assert axes["period"]["pos0"]["mlp"]["up"] == ("stack", "embed", "mlp")
    assert axes["period"]["pos0"]["attn"]["wq"] == ("stack", "embed", "qkv_out")
    # norms are replicated (the "stack" prefix resolves to None anyway)
    assert axes["period"]["pos0"]["ln1"] == (None, None)


def test_lns_weight_axes_and_shardings(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    mcfg = MadamConfig()
    params = init_lns_params(init_params(key, cfg), mcfg)
    axes = params_logical_axes(params)
    lw = axes["period"]["pos0"]["mlp"]["up"]
    assert isinstance(lw, LNSWeight)
    assert lw.packed == ("stack", "embed", "mlp")
    # scale has a size-1 axis -> unsharded there
    assert lw.scale == ("stack", None, "mlp")
    sh = tree_shardings(axes, _mesh())
    leaf = sh["period"]["pos0"]["mlp"]["up"]
    assert leaf.packed.spec == P(None, None, "model")


def test_opt_axes_factored(key):
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    mcfg = MadamConfig(factored=True)
    params = init_lns_params(init_params(key, cfg), mcfg)
    init, _ = madam_lns(mcfg)
    opt = init(params)
    oax = opt_logical_axes(params, opt)
    g2 = oax.g2["period"]["pos0"]["mlp"]["up"]
    assert g2 == {"r": ("stack", "embed"), "c": ("stack", "mlp")}


def test_cache_axes(key):
    from repro.models import init_caches
    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, dtype="float32")
    caches = init_caches(2, 16, cfg)
    axes = cache_logical_axes(caches)
    assert axes["period"]["pos0"]["k"] == ("stack", "batch", "kv_seq",
                                           None, None)


def test_batch_shardings(key):
    mesh = _mesh()
    b = {"tokens": jnp.zeros((4, 8), jnp.int32),
         "patches": jnp.zeros((4, 2, 16))}
    sh = batch_shardings(b, mesh)
    assert sh["tokens"].spec == P("data", None)
    assert sh["patches"].spec == P("data", None, None)


def test_unknown_logical_axis_raises():
    with shard_ctx(_mesh()):
        with pytest.raises(KeyError):
            spec_for(("no_such_axis",))
