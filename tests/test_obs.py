"""Observability layer: span ring + Chrome trace contract, step
timeline, Prometheus exposition round trip, kernel-time attribution, and
the engine/driver integration (spans for every completed request, torn-
read-free /metrics under scrape concurrency)."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (EngineObserver, Histogram, SpanRing, StepTimeline,
                       kernel_stats, parse_prometheus_text,
                       render_prometheus, validate_chrome_trace)
from repro.obs.spans import CAT_ENGINE, CAT_REQUEST, request_tid
from repro.serving import Engine, synthetic_trace


# ---------------------------------------------------------------------------
# span ring + Chrome trace schema


def _finished_request_ring(rid=0):
    ring = SpanRing(64)
    tid = request_tid(rid)
    ring.name_tid(tid, f"req {rid}")
    ring.complete("queue", CAT_REQUEST, tid, 0.0, 0.1)
    ring.complete("prefill", CAT_REQUEST, tid, 0.1, 0.2)
    ring.complete("decode", CAT_REQUEST, tid, 0.2, 0.9)
    ring.instant("finish", CAT_REQUEST, tid, 0.9,
                 {"reason": "length", "tokens": 8})
    return ring


def test_chrome_trace_round_trip(tmp_path):
    ring = _finished_request_ring()
    path = tmp_path / "t.trace.json"
    ring.export(str(path))
    doc = json.loads(path.read_text())
    per_rid = validate_chrome_trace(doc)
    assert per_rid == {0: {"queue": 1, "prefill": 1, "decode": 1}}
    # timestamps are microseconds, sorted, with thread-name metadata
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert [e["ts"] for e in evs] == sorted(e["ts"] for e in evs)
    decode = next(e for e in evs if e["name"] == "decode")
    assert decode["ts"] == pytest.approx(0.2e6)
    assert decode["dur"] == pytest.approx(0.7e6)
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"engine", "req 0"} <= names


def test_validate_rejects_incomplete_traces():
    # no finish marker at all
    ring = SpanRing(16)
    ring.complete("queue", CAT_REQUEST, request_tid(0), 0.0, 0.1)
    with pytest.raises(ValueError, match="no completed request"):
        validate_chrome_trace(ring.to_chrome())
    # finished but missing its decode span
    ring2 = SpanRing(16)
    tid = request_tid(1)
    ring2.complete("queue", CAT_REQUEST, tid, 0.0, 0.1)
    ring2.complete("prefill", CAT_REQUEST, tid, 0.1, 0.2)
    ring2.instant("finish", CAT_REQUEST, tid, 0.3, {"reason": "stop"})
    with pytest.raises(ValueError, match="decode"):
        validate_chrome_trace(ring2.to_chrome())
    # spec required but absent
    with pytest.raises(ValueError, match="spec"):
        validate_chrome_trace(_finished_request_ring().to_chrome(),
                              require_spec=True)
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"not": "a trace"})


def test_span_ring_bounded():
    ring = SpanRing(4)
    for i in range(10):
        ring.complete("s", CAT_ENGINE, 0, float(i), float(i) + 0.5)
    assert len(ring) == 4
    assert ring.dropped == 6
    doc = ring.to_chrome()
    assert doc["otherData"]["dropped_events"] == 6
    ring.clear()
    assert len(ring) == 0 and ring.dropped == 0


# ---------------------------------------------------------------------------
# step timeline


def test_timeline_summary_and_counters():
    tl = StepTimeline(64)
    tl.record("prefill", 0.0, 0.2, running=1, queued=3, emitted=1)
    tl.record("decode", 0.2, 0.3, running=2, emitted=2,
              pages_free=10, pages_cached=1)
    tl.record("spec", 0.3, 0.5, running=2, emitted=3, drafted=8,
              accepted=5)
    s = tl.summary()
    assert s["prefill_steps"] == 1 and s["decode_steps"] == 1
    assert s["spec_steps"] == 1
    assert s["prefill_time_s"] == pytest.approx(0.2)
    assert s["emitted_tokens"] == 6
    assert s["drafted_tokens"] == 8 and s["accepted_tokens"] == 5
    counters = tl.to_chrome_counters()
    assert all(e["ph"] == "C" for e in counters)
    assert any(e["name"] == "slots" for e in counters)
    assert any(e["name"] == "pages" for e in counters)


def test_timeline_wraps_without_allocation():
    tl = StepTimeline(8)
    for i in range(20):
        tl.record("decode", float(i), float(i) + 0.5, emitted=1)
    assert len(tl) == 8
    assert tl.total == 20
    t0s = tl.samples()["t0"]
    # chronological after wrap: the newest 8 rows in order
    assert list(t0s) == [float(i) for i in range(12, 20)]


# ---------------------------------------------------------------------------
# Prometheus exposition


def test_prometheus_round_trip():
    h = Histogram("ttft_seconds", "ttft", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    h.observe(None)
    h.observe(float("nan"))
    assert h.count == 3
    stats = {"completed_total": 3, "slots_busy": 2,
             "tokens_per_s": float("nan"), "flag": True,
             "name": "not-numeric"}
    text = render_prometheus(stats, [h], info={"arch": "smoke",
                                               "backend": "reference",
                                               "skipme": None})
    parsed = parse_prometheus_text(text)
    assert parsed["repro_completed_total"]["type"] == "counter"
    assert parsed["repro_slots_busy"]["type"] == "gauge"
    # NaN rates, bools, and strings never become series
    assert "repro_tokens_per_s" not in parsed
    assert "repro_flag" not in parsed
    info_labels = parsed["repro_build_info"]["samples"][0][0]
    assert info_labels["arch"] == "smoke" and "skipme" not in info_labels
    hist = parsed["repro_ttft_seconds"]
    assert hist["type"] == "histogram"
    by_le = {s.get("le"): v for s, v in hist["samples"]
             if s["__name__"] == "repro_ttft_seconds_bucket"}
    assert by_le == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}


def test_prometheus_parser_rejects_bad_text():
    with pytest.raises(ValueError, match="precedes its TYPE"):
        parse_prometheus_text("repro_x 1\n# TYPE repro_x counter\n")
    with pytest.raises(ValueError, match="non-numeric"):
        parse_prometheus_text("# TYPE repro_x gauge\nrepro_x potato\n")
    with pytest.raises(ValueError, match=r"\+Inf"):
        parse_prometheus_text(
            "# TYPE repro_h histogram\n"
            'repro_h_bucket{le="1"} 1\nrepro_h_sum 0.5\nrepro_h_count 1\n')
    with pytest.raises(ValueError, match="not cumulative"):
        parse_prometheus_text(
            "# TYPE repro_h histogram\n"
            'repro_h_bucket{le="1"} 5\nrepro_h_bucket{le="+Inf"} 3\n'
            "repro_h_sum 0.5\nrepro_h_count 3\n")


# ---------------------------------------------------------------------------
# kernel-time attribution


def test_kernel_stats_traces_vs_calls():
    from repro.kernels import dispatch
    from repro.core.lns import LNSFormat

    fmt = LNSFormat(bits=8, gamma=8)
    x = jnp.asarray(np.linspace(-1, 1, 32, dtype=np.float32).reshape(4, 8))
    try:
        stats = kernel_stats.enable(block_every=1)
        # eager: timed call (kwarg forwarding must not collide with the
        # positional-only observe() parameters)
        dispatch.encode_pack(x, fmt)
        # jit: first call traces (counted as a trace, not timed), the
        # cached second call never re-enters python
        jitted = jax.jit(lambda a: dispatch.encode_pack(a, fmt)[0])
        jitted(x)
        jitted(x)
        snap = kernel_stats.get()
        row = next(v for k, v in snap.items() if v["op"] == "encode_pack")
        assert row["calls"] == 1 and row["traces"] == 1
        assert row["bits"] == 8
        assert row["time_s"] >= 0.0
        assert row["blocked_calls"] == 1  # block_every=1 samples every call
    finally:
        kernel_stats.disable()
    assert kernel_stats.active() is None
    assert kernel_stats.get() == {}


# ---------------------------------------------------------------------------
# engine integration (real runs on the smoke config)


@pytest.fixture(scope="module")
def obs_run(smoke_serving_setup):
    """One speculative paged run with an observer attached."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    obs = EngineObserver()
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 page_size=4, prefix_cache=False, alloc_policy="ondemand",
                 speculate_k=2, observer=obs)
    trace = synthetic_trace(cfg, requests=3, prompt_len=6, gen_len=4,
                            lengths="uniform", seed=3)
    agg = eng.run(trace)
    return eng, obs, agg


def test_engine_emits_spans_per_completed_request(obs_run, tmp_path):
    eng, obs, agg = obs_run
    assert agg["completed"] == 3
    per_rid = validate_chrome_trace(obs.to_chrome(), require_spec=True)
    assert sorted(per_rid) == [0, 1, 2]
    for counts in per_rid.values():
        assert counts["queue"] == 1 and counts["prefill"] >= 1
    path = obs.export(str(tmp_path), tag="unit")
    assert path.endswith(".trace.json")
    validate_chrome_trace(json.loads(open(path).read()),
                          require_spec=True)
    s = obs.summary()
    assert s["prefill_steps"] >= 3
    assert s["spec_steps"] == eng.spec_cycles
    bd = obs.time_breakdown(agg["wall_s"])
    assert bd["wall_s"] == agg["wall_s"]
    assert 0.0 <= bd["host_share"] <= 1.0
    shares = sum(bd[k] for k in ("prefill_share", "decode_share",
                                 "spec_share", "host_share"))
    assert shares == pytest.approx(1.0, abs=0.01)


def test_engine_disabled_observer_is_default(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    assert eng.observer is None


def test_preemption_and_abort_events(smoke_serving_setup):
    cfg, qcfg, mcfg, params = smoke_serving_setup
    obs = EngineObserver()
    # a pool too small for both requests' full contexts: ondemand decode
    # growth must preempt under pressure
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 page_size=4, num_pages=5, prefix_cache=False,
                 alloc_policy="ondemand", observer=obs)
    trace = synthetic_trace(cfg, requests=2, prompt_len=8, gen_len=12,
                            lengths="fixed", seed=0)
    eng.run(trace)
    names = [ev[0] for ev in obs.spans.snapshot()]
    if eng.preemptions:
        assert "preempt" in names
        assert "resume" in names
    # queued abort leaves a terminal marker without any decode span
    obs2 = EngineObserver()
    eng2 = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                  observer=obs2)
    from repro.serving import Request
    eng2.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    eng2.submit(Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4))
    eng2.step()
    eng2.abort(1)
    evs = [ev for ev in obs2.spans.snapshot()
           if ev[0] == "finish" and ev[2] == request_tid(1)]
    assert len(evs) == 1


def test_driver_prometheus_scrape_under_concurrency(smoke_serving_setup):
    """/metrics renders under the driver lock: hammer prom_text() and
    stats() from scrape threads during a live run and require every
    snapshot to parse cleanly with monotone counters."""
    from repro.server.driver import EngineDriver

    cfg, qcfg, mcfg, params = smoke_serving_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 page_size=4, prefix_cache=False)
    driver = EngineDriver(eng, max_inflight=8).start()
    done = threading.Event()
    errors: list = []
    # one list per scrape thread: monotonicity is a per-scraper property
    # (a Prometheus server polls from one client), so cross-thread
    # interleaving must not enter the comparison
    per_thread: list = [[] for _ in range(3)]

    def scrape(seen):
        while not done.is_set():
            try:
                parsed = parse_prometheus_text(driver.prom_text())
                vals = [v for s, v in
                        parsed["repro_completed_total"]["samples"]]
                seen.append(vals[0])
                st = driver.stats()
                assert st["completed_total"] >= 0
                assert st["inflight"] >= 0
            except Exception as e:  # surfaced after join
                errors.append(e)
                return

    threads = [threading.Thread(target=scrape, args=(seen,))
               for seen in per_thread]
    try:
        for t in threads:
            t.start()
        finished = threading.Semaphore(0)

        def sink(event):
            if event[0] == "finish":
                finished.release()

        rids = [driver.submit([1, 2, 3, 4], 5, sink=sink)
                for _ in range(4)]
        assert all(r is not None for r in rids)
        for _ in rids:
            assert finished.acquire(timeout=60)
    finally:
        done.set()
        for t in threads:
            t.join(timeout=10)
        driver.shutdown()
    assert not errors, errors
    for seen in per_thread:
        assert seen == sorted(seen), \
            "completed_total went backwards across scrapes"
    assert max(seen[-1] for seen in per_thread if seen) == 4
    # the lifetime histograms saw every finished request
    parsed = parse_prometheus_text(driver.prom_text())
    count = [v for s, v in parsed["repro_ttft_seconds"]["samples"]
             if s["__name__"] == "repro_ttft_seconds_count"]
    assert count[0] == 4


def test_driver_health_context(smoke_serving_setup):
    from repro.server.driver import EngineDriver

    cfg, qcfg, mcfg, params = smoke_serving_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32,
                 page_size=4, prefix_cache=True, alloc_policy="reserve",
                 speculate_k=2, checkpoint_id="unit-ckpt")
    driver = EngineDriver(eng, max_inflight=8).start()
    try:
        h = driver.health()
        assert h["status"] == "ok"
        assert h["arch"] == cfg.name
        assert h["checkpoint_id"] == "unit-ckpt"
        assert h["paged"] and h["alloc_policy"] == "reserve"
        assert h["prefix_cache"] is True
        assert h["spec"]["k"] == 2
        assert h["backend"]
    finally:
        driver.shutdown()
    assert driver.health()["status"] == "stopping"
