"""On-device sampling: parameter validation, the jit-safe batch sampler,
and the engine-level guarantees the gateway relies on — per-request seeds
reproduce token-for-token, per-slot params don't leak across a batch, and
changing sampling settings never recompiles the decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import Engine, Request
from repro.server.sampling import (GREEDY, SamplingParams, sample_logits,
                                   sampling_rows, set_row)

# ---------------------------------------------------------------------------
# SamplingParams


def test_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    for p in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=p)
    sp = SamplingParams(stop=[3, 5, 3], seed=2**40 + 7)
    assert sp.stop == frozenset({3, 5})
    assert 0 <= sp.seed < 2**32          # normalized to PRNGKey range
    assert GREEDY.is_greedy and not SamplingParams(temperature=0.5).is_greedy


# ---------------------------------------------------------------------------
# sampler unit (synthetic logits, no model)


def _rows(**overrides):
    rows = sampling_rows(1)
    for k, v in overrides.items():
        rows[k][0] = v
    return {k: jnp.asarray(v) for k, v in rows.items()}


def test_greedy_matches_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)),
                         jnp.float32)
    rows = {k: jnp.asarray(v) for k, v in sampling_rows(4).items()}
    toks = np.asarray(sample_logits(logits, rows))
    np.testing.assert_array_equal(toks, np.argmax(np.asarray(logits), -1))


def test_greedy_ties_break_like_numpy():
    logits = jnp.zeros((2, 8), jnp.float32)  # all tied -> first index
    rows = {k: jnp.asarray(v) for k, v in sampling_rows(2).items()}
    np.testing.assert_array_equal(np.asarray(sample_logits(logits, rows)),
                                  [0, 0])


def test_top_k_one_is_greedy_at_any_temperature():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(1, 128)),
                         jnp.float32)
    toks = sample_logits(logits, _rows(temp=5.0, top_k=1, seed=123))
    assert int(toks[0]) == int(jnp.argmax(logits[0]))


def test_top_p_tiny_is_greedy():
    logits = jnp.asarray(np.random.default_rng(2).normal(size=(1, 128)),
                         jnp.float32)
    toks = sample_logits(logits, _rows(temp=3.0, top_p=1e-6, seed=5))
    assert int(toks[0]) == int(jnp.argmax(logits[0]))


def test_top_k_restricts_support():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)
    top8 = set(np.argsort(-np.asarray(logits[0]))[:8].tolist())
    for seed in range(20):
        t = sample_logits(logits, _rows(temp=10.0, top_k=8, seed=seed))
        assert int(t[0]) in top8


def test_seeded_sampling_reproduces_and_seeds_differ():
    logits = jnp.asarray(np.random.default_rng(4).normal(size=(1, 256)),
                         jnp.float32)
    a = [int(sample_logits(logits, _rows(temp=1.0, seed=7, step=s))[0])
         for s in range(8)]
    b = [int(sample_logits(logits, _rows(temp=1.0, seed=7, step=s))[0])
         for s in range(8)]
    c = [int(sample_logits(logits, _rows(temp=1.0, seed=8, step=s))[0])
         for s in range(8)]
    assert a == b
    assert a != c
    assert len(set(a)) > 1   # the step fold actually advances the chain


def test_per_slot_params_are_independent():
    """One batch, one greedy row + one hot row: the greedy row must equal
    plain argmax regardless of its neighbour's settings."""
    logits = jnp.asarray(np.random.default_rng(5).normal(size=(2, 64)),
                         jnp.float32)
    rows = sampling_rows(2)
    set_row(rows, 1, SamplingParams(temperature=8.0, seed=3))
    toks = np.asarray(sample_logits(
        logits, {k: jnp.asarray(v) for k, v in rows.items()}))
    assert toks[0] == int(np.argmax(np.asarray(logits)[0]))


def test_codebook_sampling_shape_and_greedy():
    k, v = 3, 32
    logits = jnp.asarray(np.random.default_rng(6).normal(size=(2, k * v)),
                         jnp.float32)
    rows = {kk: jnp.asarray(vv) for kk, vv in sampling_rows(2).items()}
    toks = np.asarray(sample_logits(logits, rows, num_codebooks=k,
                                    vocab_size=v))
    assert toks.shape == (2, k)
    ref = np.argmax(np.asarray(logits).reshape(2, k, v), -1)
    np.testing.assert_array_equal(toks, ref)


# ---------------------------------------------------------------------------
# engine integration


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (n,), dtype=np.int32).tolist()


def test_engine_seeded_sampling_reproducible_across_engines(
        smoke_serving_setup):
    """Same request seed => same tokens, independent of slot count, slot
    index, and co-batched traffic (acceptance criterion)."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    sp = SamplingParams(temperature=0.9, top_k=50, seed=42)
    prompt = _prompt(cfg, 9)

    eng = Engine(cfg, qcfg, mcfg, params, num_slots=3, max_len=32)
    eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6, sampling=sp),
             Request(rid=1, prompt=_prompt(cfg, 5, seed=1), max_new_tokens=8),
             Request(rid=2, prompt=prompt, max_new_tokens=6, sampling=sp)])
    by_rid = {rs.request.rid: rs.generated for rs in eng.finished}
    assert by_rid[0] == by_rid[2]        # same seed, different slots

    solo = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    solo.run([Request(rid=7, prompt=prompt, max_new_tokens=6, sampling=sp)])
    assert solo.finished[0].generated == by_rid[0]


def test_engine_sampled_neighbour_leaves_greedy_rows_unchanged(
        smoke_serving_setup):
    """Sampling is per-slot: a hot-temperature neighbour must not perturb
    a greedy request's tokens (vs an all-greedy run)."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    g = Request(rid=0, prompt=_prompt(cfg, 8), max_new_tokens=6)

    ref = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    ref.run([g])
    want = ref.finished[0].generated

    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    eng.run([Request(rid=0, prompt=_prompt(cfg, 8), max_new_tokens=6),
             Request(rid=1, prompt=_prompt(cfg, 8, seed=9), max_new_tokens=6,
                     sampling=SamplingParams(temperature=1.5, seed=11))])
    got = {rs.request.rid: rs.generated for rs in eng.finished}
    assert got[0] == want


def test_sampling_params_never_recompile_decode(smoke_serving_setup):
    """Temperature/top-k/top-p/seed are batch inputs of the decode jit:
    serving a mix of settings keeps decode_compiles at 1."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=_prompt(cfg, 6, seed=i), max_new_tokens=4,
                    sampling=SamplingParams(temperature=0.3 * i + 0.1,
                                            top_k=10 * i, top_p=1.0 - 0.2 * i,
                                            seed=i))
            for i in range(4)]
    eng.run(reqs)
    assert eng.decode_compiles == 1
    assert len(eng.finished) == 4


def test_stop_token_sets_terminate_generation(smoke_serving_setup):
    """A request stops on *any* id in its stop set, reports reason
    "stop", and the budget path still reports "length"."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    probe = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    probe.run([Request(rid=0, prompt=_prompt(cfg, 8), max_new_tokens=6)])
    toks = probe.finished[0].generated
    assert len(toks) == 6

    # stop on the 3rd greedy token (plus a decoy id never produced)
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32)
    eng.run([Request(rid=1, prompt=_prompt(cfg, 8), max_new_tokens=6,
                     eos_id={toks[2], cfg.vocab_size + 99})])
    rs = eng.finished[0]
    assert rs.generated == toks[:3]
    assert rs.finish_reason == "stop"
    assert probe.finished[0].finish_reason == "length"
