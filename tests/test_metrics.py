"""serving.metrics edge cases: nearest-rank percentile bounds, empty and
all-truncated summaries, and single-token TPOT."""
import math

import pytest

from repro.serving.metrics import RequestMetrics, percentile, summarize


def _metric(rid=0, *, arrival=0.0, t_admit=0.1, t_first=0.5, t_finish=1.5,
            new_tokens=5, truncated=False):
    return RequestMetrics(rid=rid, slot=0, arrival=arrival,
                          t_admit=t_admit, t_first_token=t_first,
                          t_finish=t_finish, prompt_len=4,
                          new_tokens=new_tokens, truncated=truncated)


def test_percentile_nearest_rank():
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 0.5) == 3.0
    # q=1.0 must land exactly on the last rank, not wrap to the front
    assert percentile(vals, 1.0) == 5.0
    assert percentile([7.0], 1.0) == 7.0
    # out-of-range q clamps instead of indexing from the wrong end
    assert percentile(vals, 1.7) == 5.0
    assert percentile(vals, -0.3) == 1.0
    assert math.isnan(percentile([], 0.5))


def test_summarize_empty():
    agg = summarize([], wall=2.0)
    assert agg["completed"] == 0.0
    assert agg["generated_tokens"] == 0.0
    assert agg["tokens_per_s"] == 0.0
    for key in ("ttft_mean_s", "ttft_p95_s", "latency_p50_s",
                "tpot_p50_s", "spec_accept_rate"):
        assert math.isnan(agg[key]), key
    agg0 = summarize([], wall=0.0)
    assert math.isnan(agg0["tokens_per_s"])


def test_summarize_all_truncated():
    ms = [_metric(i, truncated=True) for i in range(3)]
    agg = summarize(ms, wall=2.0)
    assert agg["completed"] == 3.0
    assert agg["truncated"] == 3.0
    assert agg["tokens_per_s"] == pytest.approx(15 / 2.0)
    assert agg["ttft_p95_s"] == pytest.approx(0.5)


def test_tpot_single_token_is_none():
    m = _metric(new_tokens=1, t_first=0.5, t_finish=0.5)
    assert m.tpot is None
    assert m.decode_tps is None
    # a single-token request contributes nothing to the tpot percentile
    agg = summarize([m], wall=1.0)
    assert math.isnan(agg["tpot_p50_s"])
    multi = _metric(new_tokens=5, t_first=0.5, t_finish=1.5)
    assert multi.tpot == pytest.approx(0.25)
    assert summarize([m, multi], wall=1.0)["tpot_p50_s"] == \
        pytest.approx(0.25)


def test_spec_accept_rate_none_without_drafts():
    m = _metric()
    assert m.spec_accept_rate is None
    agg = summarize([m], wall=1.0)
    assert agg["spec_requests"] == 0.0
    assert math.isnan(agg["spec_accept_rate"])
