"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def smoke_serving_setup():
    """One smollm-smoke packed-LNS param tree shared by the serving-layer
    test modules (engine construction stays per-test; params init is the
    expensive part)."""
    from repro.configs import get_smoke_config
    from repro.core.lns import LNSFormat
    from repro.core.quantizer import QuantConfig
    from repro.optim.madam import MadamConfig
    from repro.training import init_train_state

    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    return cfg, qcfg, mcfg, params
