"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
