"""MoE: sort dispatch vs dense oracle, capacity behavior, router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig
from repro.models.moe import _router, moe_apply, moe_init

CFG = ArchConfig(name="t", family="moe", num_layers=1, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 vocab_size=64, num_experts=8, experts_per_token=2,
                 moe_d_ff=48, capacity_factor=8.0,  # ample: no drops
                 moe_dispatch="sort", dtype="float32")


def test_sort_dispatch_matches_dense_ref(key):
    """With ample capacity the sorted dispatch equals the dense oracle."""
    p = moe_init(key, CFG)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    y_sort, aux1 = moe_apply(p, x, CFG, None)
    cfg_ref = dataclasses.replace(CFG, moe_dispatch="dense_ref")
    y_ref, aux2 = moe_apply(p, x, cfg_ref, None)
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)


def test_capacity_drops_tokens(key):
    """Tiny capacity factor drops overflow tokens instead of crashing."""
    cfg = dataclasses.replace(CFG, capacity_factor=0.25)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    y, _ = moe_apply(p, x, cfg, None)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens produce smaller outputs than the ample-capacity run
    y_full, _ = moe_apply(p, x, CFG, None)
    assert float(jnp.sum(jnp.abs(y))) < float(jnp.sum(jnp.abs(y_full)))


def test_router_gates_normalized(key):
    p = moe_init(key, CFG)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 32))
    top_p, top_i, aux = _router(p, x, CFG, None)
    np.testing.assert_allclose(np.asarray(jnp.sum(top_p, -1)), 1.0, rtol=1e-5)
    assert top_i.shape == (16, 2)
    assert bool(jnp.all((top_i >= 0) & (top_i < CFG.num_experts)))
    assert float(aux) > 0


def test_aux_loss_prefers_balance(key):
    """Uniform routing scores the minimum aux loss (≈1)."""
    p = moe_init(key, CFG)
    # force uniform router
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 32))
    _, _, aux = _router(p, x, CFG, None)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_shared_expert_always_active(key):
    cfg = dataclasses.replace(CFG, num_shared_experts=1)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 32))
    y_with, _ = moe_apply(p, x, cfg, None)
    # zero the routed experts: output reduces to the shared expert alone
    p0 = dict(p, w_down=jnp.zeros_like(p["w_down"]))
    y_shared_only, _ = moe_apply(p0, x, cfg, None)
    assert float(jnp.max(jnp.abs(y_shared_only))) > 0
    assert not np.allclose(np.asarray(y_with), np.asarray(y_shared_only))


def test_moe_grads_reach_experts(key):
    p = moe_init(key, CFG)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))

    def loss(p):
        y, aux = moe_apply(p, x, CFG, None)
        return jnp.sum(y * y) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["w_up"]))) > 0
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
