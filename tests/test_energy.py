"""Analytical energy model vs the paper's published tables (§6.2)."""
import pytest

from repro.core import energy


def test_table8_reproduced_within_tolerance():
    """Model reproduces all 16 Table-8 cells within 25% (single calibrated
    overhead constant across 4 models x 4 formats)."""
    pred = energy.paper_table8()
    for model, row in energy.PAPER_TABLE8_MJ.items():
        for fmt, want in row.items():
            got = pred[model][fmt]
            assert got == pytest.approx(want, rel=0.25), (model, fmt, got)


def test_format_ratios_match_paper():
    """§6.2: LNS datapath is 2.2x/4.6x/11x cheaper than FP8/FP16/FP32."""
    e = energy.DATAPATH_FJ_PER_OP
    assert e["fp8"] / e["lns8"] == pytest.approx(2.2, rel=1e-6)
    assert e["fp16"] / e["lns8"] == pytest.approx(4.6, rel=1e-6)
    assert e["fp32"] / e["lns8"] == pytest.approx(11.0, rel=1e-6)


def test_lns_over_90_percent_savings_vs_fp32():
    """The abstract's headline: >90% energy reduction vs FP32."""
    for model in energy.PAPER_MODEL_MACS:
        lns = energy.per_iteration_energy_mj(
            energy.PAPER_MODEL_MACS[model], "lns8")
        fp32 = energy.per_iteration_energy_mj(
            energy.PAPER_MODEL_MACS[model], "fp32")
        assert lns < 0.10 * fp32


def test_lut_sweep_monotone():
    """Table 10: smaller LUT -> cheaper conversion."""
    costs = [energy.DATAPATH_FJ_PER_OP[f"lns8_lut{n}"] for n in (1, 2, 4, 8)]
    assert costs == sorted(costs)
    # ~35% max saving (paper §.4)
    assert 1.0 - costs[0] / costs[-1] == pytest.approx(0.354, abs=0.02)


def test_gpt_scaling_monotone():
    table = energy.gpt_scaling()
    sizes = ["gpt-1b", "gpt-13b", "gpt-175b", "gpt-530b", "gpt-1t"]
    vals = [table[s]["lns8"] for s in sizes]
    assert vals == sorted(vals)
    for s in sizes:
        assert table[s]["fp32"] / table[s]["lns8"] == pytest.approx(11.0, rel=1e-6)


def test_unknown_format_raises():
    with pytest.raises(KeyError):
        energy.per_iteration_energy_mj(1e9, "int4")
