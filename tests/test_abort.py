"""Mid-flight cancellation: abort a request mid-queue, mid-prefill, and
mid-decode on both the dense and the paged engine. Slots must recycle,
the page allocator must return to its free-page baseline, and surviving
co-batched requests must produce token-for-token identical output to an
abort-free run."""
import numpy as np
import pytest

from repro.serving import Engine, Request

pytestmark = pytest.mark.parametrize(
    "page_size", [None, 4], ids=["dense", "paged"])


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (n,), dtype=np.int32).tolist()


def _mk_engine(setup, page_size, **kw):
    cfg, qcfg, mcfg, params = setup
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    return Engine(cfg, qcfg, mcfg, params, page_size=page_size, **kw)


def _baseline(eng):
    return eng.allocator.available if eng.page_size else None


def _assert_allocator_at_baseline(eng, baseline):
    if eng.page_size:
        assert eng.allocator.available == baseline
        assert not eng.allocator._ref     # every refcount returned to 0


def test_abort_mid_queue(smoke_serving_setup, page_size):
    cfg = smoke_serving_setup[0]
    eng = _mk_engine(smoke_serving_setup, page_size, num_slots=1)
    base = _baseline(eng)
    events = []
    eng.finish_sink = lambda rid, reason, rs: events.append((rid, reason))
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 8), max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=_prompt(cfg, 8, 1), max_new_tokens=4))
    eng.step()                       # admits rid 0 only (one slot)
    assert len(eng.queue) == 1
    assert eng.abort(1)              # still queued: dropped, no slot bound
    assert not eng.queue
    while eng.scheduler.running:
        eng.step()
    assert [rs.request.rid for rs in eng.finished] == [0]
    assert len(eng.finished[0].generated) == 4
    assert (1, "aborted") in events and (0, "length") in events
    _assert_allocator_at_baseline(eng, base)


def test_abort_mid_prefill(smoke_serving_setup, page_size):
    """Cancel right after admission (prefill done, no decode yet): the
    slot and its pages must free, and the engine must admit a fresh
    request into the recycled slot."""
    cfg = smoke_serving_setup[0]
    eng = _mk_engine(smoke_serving_setup, page_size, num_slots=1)
    base = _baseline(eng)
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 8), max_new_tokens=8))
    eng.step()                       # admission + first decode
    rs0 = next(iter(eng.scheduler.running.values()))
    assert rs0.request.rid == 0 and len(rs0.generated) >= 1
    assert eng.abort(0)
    assert not eng.scheduler.running and eng.scheduler.free_slots == 1
    _assert_allocator_at_baseline(eng, base)
    assert eng.aborted[0].finish_reason == "aborted"

    eng.run([Request(rid=1, prompt=_prompt(cfg, 8), max_new_tokens=3)])
    assert len(eng.finished) == 1 and eng.finished[0].slot == 0


def test_abort_mid_decode_survivors_unperturbed(smoke_serving_setup,
                                                page_size):
    """The acceptance-criterion scenario: cancel one of two co-batched
    streams mid-decode; the survivor's tokens must equal an abort-free
    run and the allocator must return to baseline."""
    cfg = smoke_serving_setup[0]
    doomed = lambda: Request(rid=1, prompt=_prompt(cfg, 7, 1),
                             max_new_tokens=10)

    ref = _mk_engine(smoke_serving_setup, page_size)
    ref.run([Request(rid=0, prompt=_prompt(cfg, 9), max_new_tokens=10)])
    want = ref.finished[0].generated

    eng = _mk_engine(smoke_serving_setup, page_size)
    base = _baseline(eng)
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 9), max_new_tokens=10))
    eng.submit(doomed())
    for _ in range(4):               # both admitted + a few decode steps
        eng.step()
    assert len(eng.scheduler.running) == 2
    assert eng.abort(1)
    assert len(eng.scheduler.running) == 1
    while eng.scheduler.running:
        eng.step()
    assert eng.finished[0].request.rid == 0
    assert eng.finished[0].generated == want
    aborted = eng.aborted[0]
    assert aborted.request.rid == 1 and 0 < len(aborted.generated) < 10
    _assert_allocator_at_baseline(eng, base)
    # the freed slot is admissible again
    eng.run([Request(rid=2, prompt=_prompt(cfg, 5, 2), max_new_tokens=2)])
    assert len(eng.finished) == 2


def test_abort_unknown_or_finished_rid_is_noop(smoke_serving_setup,
                                               page_size):
    cfg = smoke_serving_setup[0]
    eng = _mk_engine(smoke_serving_setup, page_size, num_slots=1)
    eng.run([Request(rid=0, prompt=_prompt(cfg, 6), max_new_tokens=2)])
    assert not eng.abort(0)          # already finished
    assert not eng.abort(123)        # never submitted
    assert len(eng.finished) == 1 and not eng.aborted
