"""End-to-end gateway tests: real sockets against the asyncio HTTP/SSE
front-end over a live engine driver — streaming, per-request sampling,
mid-flight cancellation (DELETE and client disconnect), backpressure, and
protocol validation."""
import asyncio
import json
import time

import numpy as np
import pytest

from repro.serving import Engine
from repro.server import protocol
from repro.server.app import Gateway
from repro.server.driver import EngineDriver
from repro.server.sse import DONE, SSEParser


# ---------------------------------------------------------------------------
# protocol unit tests (no sockets)


def test_parse_completion_validates():
    ok = protocol.parse_completion(
        b'{"prompt": [1,2,3], "max_tokens": 4, "temperature": 0.5,'
        b' "top_k": 10, "seed": 9, "stop": 7, "stream": true}')
    assert ok.prompt == [1, 2, 3] and ok.max_tokens == 4 and ok.stream
    assert ok.sampling.temperature == 0.5 and ok.sampling.stop == {7}

    bad = [b"", b"[]", b'{"prompt": []}', b'{"prompt": "hi"}',
           b'{"prompt": [1], "max_tokens": 0}',
           b'{"prompt": [1], "temperature": -1}',
           b'{"prompt": [1], "top_p": 0}',
           b'{"prompt": [1], "stream": "yes"}',
           b'{"prompt": [1], "stop": ["x"]}',
           b'{"prompt": [[1,2],[3]]}']
    for body in bad:
        with pytest.raises(protocol.ProtocolError):
            protocol.parse_completion(body)


def test_parse_completion_codebook_rows():
    ok = protocol.parse_completion(b'{"prompt": [[1,2],[3,4]]}')
    assert ok.prompt == [[1, 2], [3, 4]]


def test_sse_parser_framing():
    p = SSEParser()
    # byte-at-a-time chunking reassembles events
    out = []
    for i in range(len(b"data: hello\n\ndata: [DONE]\n\n")):
        out += p.feed(b"data: hello\n\ndata: [DONE]\n\n"[i:i + 1])
    assert out == ["hello", "[DONE]"]
    # mixed CRLF/LF framing stays two distinct events, and a CR-split
    # across chunks doesn't drop a line
    p = SSEParser()
    assert p.feed(b"data: a\r\n\r\ndata: b\n\n") == ["a", "b"]
    p = SSEParser()
    assert p.feed(b"data: c\r") == []
    assert p.feed(b"\n\r\ndata: d\n\r\n") == ["c", "d"]
    # multi-line data joins; comment/event fields are ignored
    p = SSEParser()
    assert p.feed(b": ping\nevent: x\ndata: 1\ndata: 2\n\n") == ["1\n2"]


# ---------------------------------------------------------------------------
# live-gateway fixture

SLOTS, MAX_LEN, PAGE = 2, 48, 4


@pytest.fixture(scope="module")
def live_gateway(smoke_serving_setup):
    """(engine, driver, host, port) with the gateway running on a
    background event-loop thread for the whole module."""
    cfg, qcfg, mcfg, params = smoke_serving_setup
    engine = Engine(cfg, qcfg, mcfg, params, num_slots=SLOTS,
                    max_len=MAX_LEN, page_size=PAGE)
    driver = EngineDriver(engine, max_inflight=SLOTS + 2).start()

    import threading
    loop = asyncio.new_event_loop()
    started = {}

    def run_loop():
        asyncio.set_event_loop(loop)
        gw = loop.run_until_complete(
            Gateway(driver, port=0, model=cfg.name).start())
        started["gw"] = gw
        started["addr"] = gw.address
        loop.run_forever()

    t = threading.Thread(target=run_loop, daemon=True)
    t.start()
    deadline = time.monotonic() + 10
    while "addr" not in started and time.monotonic() < deadline:
        time.sleep(0.01)
    assert "addr" in started, "gateway failed to start"
    host, port = started["addr"]
    yield engine, driver, host, port
    asyncio.run_coroutine_threadsafe(started["gw"].stop(), loop).result(5)
    loop.call_soon_threadsafe(loop.stop)
    t.join(5)
    driver.shutdown()
    assert not driver.alive


# small blocking client helpers (tests run in the main thread; the
# gateway loop lives on its own thread, so plain sockets are fine)


def _client(fn):
    return asyncio.run(fn)


async def _http(host, port, method, path, body=None):
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps(body).encode() if body is not None else b""
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n").encode()
                 + payload)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, data = raw.partition(b"\r\n\r\n")
    status = int(head.split()[1])
    return status, json.loads(data) if data else {}


async def _http_text(host, port, method, path):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: 0\r\n\r\n").encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, data = raw.partition(b"\r\n\r\n")
    return int(head.split()[1]), data.decode()


async def _stream(host, port, body, *, cancel_after=None, delete_via=None):
    """Returns (status, frames, frame_times, finish_reason)."""
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps({**body, "stream": True}).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n").encode()
                 + payload)
    await writer.drain()
    head = b""
    while b"\r\n\r\n" not in head:
        chunk = await reader.read(1)
        assert chunk, "connection closed before response head"
        head += chunk
    status = int(head.split()[1])
    parser, tokens, times, reason, rid = SSEParser(), [], [], None, None
    if status != 200:
        writer.close()
        return status, tokens, times, reason
    while True:
        chunk = await reader.read(4096)
        if not chunk:
            break
        done = False
        for ev in parser.feed(chunk):
            if ev == DONE:
                done = True
                break
            obj = json.loads(ev)
            rid = rid or obj["id"]
            choice = obj["choices"][0]
            if choice["delta"]["token_ids"]:
                tokens.extend(choice["delta"]["token_ids"])
                times.append(time.monotonic())
            if choice["finish_reason"]:
                reason = choice["finish_reason"]
        if done:
            break
        if cancel_after is not None and len(tokens) >= cancel_after:
            break  # close the socket mid-stream (client disconnect)
        if delete_via is not None and len(tokens) >= 1 and rid:
            await _http(host, port, "DELETE", f"/v1/requests/{rid}")
            delete_via = None  # fire once, keep consuming the stream
    writer.close()
    return status, tokens, times, reason


def _prompt(cfg_vocab, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg_vocab, (n,), dtype=np.int32).tolist()


# ---------------------------------------------------------------------------
# e2e


def test_health_and_metrics(live_gateway):
    _, _, host, port = live_gateway
    status, obj = _client(_http(host, port, "GET", "/health"))
    assert status == 200 and obj["status"] == "ok"
    # readiness context: what this node serves with
    for key in ("backend", "arch", "num_slots", "max_len", "paged"):
        assert key in obj
    assert obj["paged"] and obj["page_size"] == PAGE
    # the JSON stats snapshot moved to /metrics.json ...
    status, obj = _client(_http(host, port, "GET", "/metrics.json"))
    assert status == 200
    for key in ("running", "queued", "inflight", "decode_steps",
                "queued_p50_s", "tpot_p50_s", "kv_pages_available"):
        assert key in obj
    # ... and /metrics is Prometheus exposition text
    status, text = _client(_http_text(host, port, "GET", "/metrics"))
    assert status == 200
    from repro.obs import parse_prometheus_text
    parsed = parse_prometheus_text(text)
    assert parsed["repro_completed_total"]["type"] == "counter"
    assert parsed["repro_ttft_seconds"]["type"] == "histogram"


def test_unary_completion(live_gateway):
    engine, _, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    status, obj = _client(_http(
        host, port, "POST", "/v1/completions",
        {"prompt": _prompt(vocab, 6), "max_tokens": 4}))
    assert status == 200
    choice = obj["choices"][0]
    assert len(choice["token_ids"]) == 4
    assert choice["finish_reason"] == "length"
    assert obj["usage"]["completion_tokens"] == 4


def test_streaming_is_incremental_and_seed_reproducible(live_gateway):
    engine, _, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    body = {"prompt": _prompt(vocab, 8), "max_tokens": 6,
            "temperature": 0.8, "top_k": 50, "seed": 77}
    status, toks_a, times, reason = _client(_stream(host, port, body))
    assert status == 200 and reason == "length"
    assert len(toks_a) == 6 and len(times) == 6
    assert times[-1] > times[0], "frames did not arrive incrementally"
    status, toks_b, _, _ = _client(_stream(host, port, body))
    assert toks_a == toks_b
    status, toks_c, _, _ = _client(_stream(host, port,
                                           {**body, "seed": 78}))
    assert toks_a != toks_c


def test_delete_aborts_streaming_request(live_gateway):
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    status, toks, _, reason = _client(_stream(
        host, port, {"prompt": _prompt(vocab, 6), "max_tokens": 40},
        delete_via=True))
    assert status == 200
    assert reason == "aborted"
    assert 1 <= len(toks) < 40


def test_client_disconnect_frees_slot_and_pages(live_gateway):
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    baseline = engine.allocator.available
    status, toks, _, _ = _client(_stream(
        host, port, {"prompt": _prompt(vocab, 6), "max_tokens": 40},
        cancel_after=2))
    assert status == 200 and len(toks) >= 2
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        if not engine.scheduler.running \
                and engine.allocator.available >= baseline:
            break
        time.sleep(0.05)
    assert not engine.scheduler.running, "abort did not release the slot"
    assert engine.allocator.available >= baseline, "KV pages leaked"
    assert driver.stats()["aborted_total"] >= 1


def test_unary_disconnect_aborts_request(live_gateway):
    """A non-streaming client that drops its connection must not keep a
    slot and KV pages pinned until the token budget runs out."""
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    aborted0 = driver.stats()["aborted_total"]

    async def drop_unary():
        reader, writer = await asyncio.open_connection(host, port)
        payload = json.dumps({"prompt": _prompt(vocab, 6),
                              "max_tokens": 4000}).encode()
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload)
        await writer.drain()
        writer.close()          # walk away before any response

    _client(drop_unary())
    deadline = time.monotonic() + 15
    while time.monotonic() < deadline:
        if driver.stats()["aborted_total"] > aborted0 \
                and not engine.scheduler.running:
            break
        time.sleep(0.05)
    assert driver.stats()["aborted_total"] > aborted0, \
        "unary disconnect did not abort the request"
    assert not engine.scheduler.running


def test_backpressure_429_then_drains(live_gateway):
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size

    async def scenario():
        # saturate the inflight watermark with slow streams...
        max_inflight = driver._max_inflight
        streams = [asyncio.ensure_future(_stream(
            host, port, {"prompt": _prompt(vocab, 4, seed=i),
                         "max_tokens": 30}))
            for i in range(max_inflight)]
        # ...wait until all are live server-side, then one more must 429
        deadline = time.monotonic() + 15
        while time.monotonic() < deadline:
            if driver.stats()["inflight"] >= max_inflight:
                break
            await asyncio.sleep(0.02)
        status, obj = await _http(
            host, port, "POST", "/v1/completions",
            {"prompt": _prompt(vocab, 4), "max_tokens": 2})
        results = await asyncio.gather(*streams)
        return status, obj, results

    status, obj, results = _client(scenario())
    assert status == 429
    assert obj["error"]["type"] == "rate_limit_exceeded"
    assert all(r[3] == "length" for r in results)  # saturators finish
    # and the system drains: a fresh request succeeds afterwards
    status, obj = _client(_http(host, port, "POST", "/v1/completions",
                                {"prompt": _prompt(vocab, 4),
                                 "max_tokens": 2}))
    assert status == 200


def test_bad_requests_get_400_not_a_wedged_slot(live_gateway):
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    status, obj = _client(_http(host, port, "POST", "/v1/completions",
                                {"prompt": "not tokens"}))
    assert status == 400
    # out-of-vocab ids would be silently clamped by the embedding gather
    status, obj = _client(_http(host, port, "POST", "/v1/completions",
                                {"prompt": [-1, 5], "max_tokens": 2}))
    assert status == 400 and "token ids must be in" in obj["error"]["message"]
    # over-capacity prompt is a 400 (engine can never host it), not 429
    status, obj = _client(_http(
        host, port, "POST", "/v1/completions",
        {"prompt": _prompt(vocab, MAX_LEN + 1), "max_tokens": 2}))
    assert status == 400
    status, _ = _client(_http(host, port, "GET", "/nope"))
    assert status == 404
    # engine still fully serviceable
    status, obj = _client(_http(host, port, "POST", "/v1/completions",
                                {"prompt": _prompt(vocab, 4),
                                 "max_tokens": 2}))
    assert status == 200


def test_shape_mismatched_prompt_is_400_not_engine_death(live_gateway):
    """Codebook-style rows into a flat-vocab model pass the protocol
    layer but can never run — the pre-flight must turn them into a 400;
    the old behaviour was a crash inside step() that killed the driver
    thread and flipped /health to 503 for everyone (remote DoS)."""
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    status, obj = _client(_http(host, port, "POST", "/v1/completions",
                                {"prompt": [[1, 2], [3, 4]],
                                 "max_tokens": 2}))
    assert status == 400
    assert "flat list" in obj["error"]["message"]
    assert driver.alive
    status, _ = _client(_http(host, port, "GET", "/health"))
    assert status == 200
    status, _ = _client(_http(host, port, "POST", "/v1/completions",
                              {"prompt": _prompt(vocab, 4),
                               "max_tokens": 2}))
    assert status == 200


def test_content_length_abuse_gets_clean_http_errors(live_gateway):
    """Malformed / oversized / negative Content-Length must produce a
    400/413 response, not an unhandled exception or an unbounded body
    buffer."""
    _, _, host, port = live_gateway

    async def raw_status(head: bytes) -> int:
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(head)
        await writer.drain()
        data = await reader.read()
        writer.close()
        return int(data.split()[1])

    base = b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
    assert _client(raw_status(
        base + b"Content-Length: banana\r\n\r\n")) == 400
    assert _client(raw_status(
        base + b"Content-Length: 999999999999\r\n\r\n")) == 413
    assert _client(raw_status(
        base + b"Content-Length: -5\r\n\r\n")) == 400
    many = b"".join(b"X-H%d: v\r\n" % i for i in range(200))
    assert _client(raw_status(base + many + b"\r\n")) == 400
    # duplicate-name headers count as lines, not dict keys
    dupes = b"X-Same: v\r\n" * 200
    assert _client(raw_status(base + dupes + b"\r\n")) == 400


def test_trailing_bytes_after_body_are_not_a_disconnect(live_gateway):
    """Stray bytes after the body (a pipelined request, a trailing CRLF)
    must not trip the disconnect watcher — only EOF (or exhausting the
    trailing-bytes budget, tested below) means the client is gone."""
    engine, _, host, port = live_gateway
    vocab = engine.cfg.vocab_size

    async def run():
        reader, writer = await asyncio.open_connection(host, port)
        payload = json.dumps({"prompt": _prompt(vocab, 4),
                              "max_tokens": 2}).encode()
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload + b"\r\n\r\n")  # stray pipelined bytes
        await writer.drain()
        raw = await reader.read()
        writer.close()
        return raw

    raw = _client(run())
    head, _, data = raw.partition(b"\r\n\r\n")
    assert int(head.split()[1]) == 200
    assert len(json.loads(data)["choices"][0]["token_ids"]) == 2


def test_trailing_byte_flood_aborts_the_request(live_gateway):
    """Past the watcher's trailing-bytes budget the peer is treated as
    gone: the request is aborted (no response) instead of the server
    sinking an arbitrary byte stream for the request's lifetime."""
    engine, driver, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    aborted0 = driver.stats()["aborted_total"]

    async def run():
        reader, writer = await asyncio.open_connection(host, port)
        payload = json.dumps({"prompt": _prompt(vocab, 6),
                              "max_tokens": 4000}).encode()
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload + b"X" * (80 << 10))  # flood past 64 KB
        await writer.drain()
        raw = await asyncio.wait_for(reader.read(), timeout=30)
        writer.close()
        return raw

    assert _client(run()) == b""  # aborted server-side: no response
    deadline = time.monotonic() + 15
    while time.monotonic() < deadline:
        if driver.stats()["aborted_total"] > aborted0 \
                and not engine.scheduler.running:
            break
        time.sleep(0.05)
    assert driver.stats()["aborted_total"] > aborted0, \
        "trailing-byte flood did not abort the request"
    assert not engine.scheduler.running


def test_stop_token_finishes_stream_with_reason_stop(live_gateway):
    engine, _, host, port = live_gateway
    vocab = engine.cfg.vocab_size
    probe = _client(_http(host, port, "POST", "/v1/completions",
                          {"prompt": _prompt(vocab, 7), "max_tokens": 5}))
    toks = probe[1]["choices"][0]["token_ids"]
    status, got, _, reason = _client(_stream(
        host, port, {"prompt": _prompt(vocab, 7), "max_tokens": 5,
                     "stop": [toks[1]]}))
    assert status == 200
    assert got == toks[:2]
    assert reason == "stop"
