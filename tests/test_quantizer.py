"""QAT machinery: STE, Q_E cotangent quantization, qeinsum, Q_G (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lns import LNSFormat, lns_quantize
from repro.core.quantizer import (QuantConfig, backward_quantize, qeinsum,
                                  quantize_grads, ste_quantize)
from repro.core.quant_training import approx_product_values, approx_qeinsum
from repro.numerics.fp import FPFormat, fp_quantize

FMT = LNSFormat(bits=8, gamma=8)


def test_ste_identity_gradient(key):
    x = jax.random.normal(key, (32,))
    g = jax.grad(lambda x: jnp.sum(ste_quantize(x, FMT) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


def test_ste_forward_on_grid(key):
    x = jax.random.normal(key, (32,))
    q = ste_quantize(x, FMT)
    q2 = lns_quantize(q, FMT)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q2), rtol=1e-6)


def test_backward_quantize_forward_identity(key):
    x = jax.random.normal(key, (8, 8))
    np.testing.assert_array_equal(
        np.asarray(backward_quantize(x, FMT, None, None)), np.asarray(x))


def test_backward_quantize_quantizes_cotangent(key):
    x = jax.random.normal(key, (64,))
    cot = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    _, vjp = jax.vjp(lambda x: backward_quantize(x, FMT, None, None), x)
    (g,) = vjp(cot)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(lns_quantize(cot, FMT)), rtol=1e-6)


def test_backward_quantize_cot_dtype(key):
    """Cotangents stay in the compute dtype through Q_E (the quantizer's
    internal f32 math must not leak f32 containers into the backward)."""
    x = jax.random.normal(key, (16,), jnp.bfloat16)
    _, vjp = jax.vjp(
        lambda x: backward_quantize(x, FMT, None, jnp.bfloat16), x)
    (g,) = vjp(jnp.ones((16,), jnp.bfloat16))
    assert g.dtype == jnp.bfloat16


def test_qeinsum_fp_path_equals_einsum(key):
    x = jax.random.normal(key, (4, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    y = qeinsum("bi,ij->bj", x, w, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5)


def test_qeinsum_quantized_close_to_fp(key):
    x = jax.random.normal(key, (16, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    y = qeinsum("bi,ij->bj", x, w, QuantConfig.lns_madam())
    rel = float(jnp.max(jnp.abs(y - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.15


def test_qeinsum_grads_flow_to_both_operands(key):
    x = jax.random.normal(key, (4, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    cfg = QuantConfig.lns_madam()
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(qeinsum("bi,ij->bj", x, w, cfg)), (0, 1))(x, w)
    assert float(jnp.max(jnp.abs(gx))) > 0
    assert float(jnp.max(jnp.abs(gw))) > 0


def test_quantize_grads_puts_grads_on_grid(key):
    cfg = QuantConfig.lns_madam()
    grads = {"a": jax.random.normal(key, (8, 8)),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (4,))}
    q = quantize_grads(grads, cfg)
    for k in q:
        np.testing.assert_allclose(np.asarray(q[k]),
                                   np.asarray(lns_quantize(q[k], cfg.grad)),
                                   rtol=1e-6)


def test_quant_config_presets():
    c = QuantConfig.lns_madam()
    assert c.weight.bits == 8 and c.weight.gamma == 8
    assert c.update.bits == 16
    # range preserved up to the 2^(B-1)-1 off-by-one (<1%)
    assert c.update.dynamic_range == pytest.approx(15.875, rel=0.01)
    assert QuantConfig.fp8().weight.bits == 8
    assert not QuantConfig.full_precision().is_quantized


def test_fp8_quantize_known_values():
    fmt = FPFormat(exp_bits=4, man_bits=3)
    # values already on the e4m3-like grid survive (scale = absmax/max_value)
    x = jnp.asarray([fmt.max_value, fmt.max_value / 2, 0.0])
    q = fp_quantize(x, fmt)
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), rtol=1e-6)


def test_approx_qeinsum_matches_elementwise_oracle(key):
    """Bucketed approximate GEMM == elementwise hybrid-decode oracle."""
    cfg = QuantConfig.lns_madam(approx_lut=2)
    x = jnp.abs(jax.random.normal(key, (5, 12))) + 0.1
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (12, 7))) + 0.1
    y = approx_qeinsum("bi,ij->bj", x, w, cfg)

    from repro.core.lns import compute_scale, lns_decode, lns_encode
    fmt = cfg.weight
    sx = compute_scale(x, axis=cfg.act_scale_axis)
    sw = compute_scale(w, axis=cfg.weight_scale_axis)
    sgx, ex = lns_encode(x, fmt, sx)
    sgw, ew = lns_encode(w, fmt, sw)
    px = (fmt.max_code - ex.astype(jnp.int32))
    pw = (fmt.max_code - ew.astype(jnp.int32))
    vals = approx_product_values(px[:, :, None], pw[None, :, :], fmt, 2)
    base = 2.0 ** (-2.0 * fmt.max_code / fmt.gamma)
    ref = jnp.einsum("bij,bij->bj",
                     vals * sgx.astype(jnp.float32)[:, :, None]
                     * sgw.astype(jnp.float32)[None],
                     jnp.ones_like(vals)) * base * sx * sw
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_approx_qeinsum_ste_backward(key):
    cfg = QuantConfig.lns_madam(approx_lut=1)
    x = jax.random.normal(key, (4, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, 4))
    g = jax.grad(lambda x: jnp.sum(qeinsum("bi,ij->bj", x, w, cfg)))(x)
    assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))
