"""LNS<->integer conversion: exact decomposition + Mitchell hybrid (§2.2/2.3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conversion as cv


@pytest.mark.parametrize("gamma", [1, 2, 4, 8, 16, 32])
def test_exact_decomposition_equals_exp2(gamma):
    """2^(p/γ) = 2^q · LUT[r] exactly (float flavour)."""
    p = jnp.arange(0, 8 * gamma)
    got = cv.exp2_exact(p, gamma)
    want = np.exp2(np.arange(0, 8 * gamma) / gamma)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


@pytest.mark.parametrize("gamma,lut", [(8, 8), (8, 4), (8, 2), (8, 1),
                                       (16, 4), (32, 8)])
def test_hybrid_error_bound(gamma, lut):
    """Mitchell hybrid error <= the single-interval Mitchell bound (~8.6%)
    shrinking as the LUT grows."""
    p = jnp.arange(0, 4 * gamma)
    approx = cv.exp2_hybrid(p, gamma, lut)
    exact = np.exp2(np.arange(0, 4 * gamma) / gamma)
    rel = np.abs(np.asarray(approx) - exact) / exact
    b_l = (gamma // lut).bit_length() - 1
    # worst Mitchell error over an interval of 2^b_l remainder steps
    bound = 0.09 / max(lut, 1) ** 0.0 if lut == 1 else 0.09
    assert rel.max() <= 0.09
    if lut == gamma:
        assert rel.max() <= 1e-6  # full LUT = exact


def test_hybrid_error_monotone_in_lut():
    """Max *relative* error is non-increasing in LUT size (the Mitchell
    max-error point t*=1/ln2-1 sits inside [0, 0.5), so LUT=1 and LUT=2 tie;
    larger LUTs clip the interval below t*)."""
    gamma = 8
    p = jnp.arange(0, 16 * gamma)
    exact = np.exp2(np.arange(0, 16 * gamma) / gamma)
    errs = []
    for lut in (1, 2, 4, 8):
        approx = np.asarray(cv.exp2_hybrid(p, gamma, lut))
        errs.append((np.abs(approx - exact) / exact).max())
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-9
    assert errs[-1] <= 1e-6  # full LUT = exact


@pytest.mark.parametrize("gamma", [2, 8, 32])
def test_fixed_point_matches_float(gamma):
    """Integer datapath == float path up to fixed-point rounding."""
    p = jnp.arange(0, 4 * gamma)
    fixed = np.asarray(cv.exp2_exact_fixed(p, gamma, frac_bits=16))
    want = np.exp2(np.arange(0, 4 * gamma) / gamma) * (1 << 16)
    # LUT rounding + shift: error < one LUT ulp shifted up
    assert np.all(np.abs(fixed - want) <= 2.0 ** (np.arange(4 * gamma) // gamma))


@pytest.mark.parametrize("gamma", [2, 8])
@pytest.mark.parametrize("frac_bits", [12, 16, 20])
def test_neg_fixed_point(gamma, frac_bits):
    """Negative-exponent flavour: LUT >> q with underflow below the LSB."""
    m = jnp.arange(0, 8 * gamma)
    fixed = np.asarray(cv.exp2_neg_exact_fixed(m, gamma, frac_bits))
    want = np.exp2(-np.arange(0, 8 * gamma) / gamma) * (1 << frac_bits)
    assert np.all(np.abs(fixed - want) <= 1.0 + want * 1e-5)
    # monotone non-increasing; eventually underflows to 0
    assert np.all(np.diff(fixed) <= 0)


def test_neg_hybrid_vs_exact():
    """Complement-Mitchell keeps the <=6.2% worst-case error of the RTL's
    positive-convention Mitchell (the naive 1 - r/γ form reaches 77%)."""
    gamma = 8
    m = jnp.arange(0, 8 * gamma)
    exact = np.exp2(-np.arange(0, 8 * gamma) / gamma)
    for lut in (1, 2, 4):
        approx = np.asarray(
            cv.exp2_neg_hybrid_fixed(m, gamma, lut, frac_bits=16)) / 2.0 ** 16
        rel = np.abs(approx - exact) / np.maximum(exact, 1e-9)
        assert rel.max() <= 0.063


def test_approx_decode_factor_bins():
    """Error factor == approx/exact per remainder bin (App. §.4)."""
    gamma, lut = 8, 2
    r = jnp.arange(gamma)
    f = np.asarray(cv.approx_decode_factor(r, gamma, lut))
    exact = np.exp2(np.arange(gamma) / gamma)
    approx = np.asarray(cv.exp2_hybrid(r, gamma, lut))
    np.testing.assert_allclose(f, approx / exact, rtol=1e-6)
    assert f[0] == pytest.approx(1.0)  # r=0 is exact


def test_lut_sizes():
    assert cv.remainder_lut(8).shape == (8,)
    assert cv.remainder_lut(8, 2).shape == (2,)
    with pytest.raises(ValueError):
        cv.remainder_lut(8, 16)  # lut larger than gamma
    with pytest.raises(ValueError):
        cv.remainder_lut(6)      # not a power of two
