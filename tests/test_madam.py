"""Madam optimizer: LNS-native semantics, convergence, factored g2,
quantized-update baselines (paper §4, Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lns import LNSFormat, lns_decode
from repro.optim import (MadamConfig, adamw, init_lns_params, madam_fp,
                         madam_lns, materialize, quantized_update, sgd)
from repro.optim.madam import LNSWeight, is_lns_weight


def test_init_policy_lns_vs_fp(key):
    params = {"w": jax.random.normal(key, (8, 8)),
              "gain": jnp.ones((8,))}
    mcfg = MadamConfig()
    lp = init_lns_params(params, mcfg)
    assert is_lns_weight(lp["w"])
    assert not is_lns_weight(lp["gain"])  # 1-D stays fp (BN carve-out)
    dense = materialize(lp, mcfg, dtype=jnp.float32)
    rel = jnp.abs(dense["w"] - params["w"]) / jnp.maximum(
        jnp.abs(params["w"]), 1e-6)
    assert float(jnp.max(rel)) < 2e-4  # 16-bit codes: fine grid


def test_sign_never_flips(key):
    mcfg = MadamConfig(lr=0.5)  # huge lr
    params = init_lns_params({"w": jax.random.normal(key, (16, 16))}, mcfg)
    init, update = madam_lns(mcfg)
    st = init(params)
    sign0 = params["w"].sign
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (16, 16))}
        params, st = update(g, st, params)
    np.testing.assert_array_equal(np.asarray(params["w"].sign),
                                  np.asarray(sign0))


def test_codes_clamped_to_format(key):
    mcfg = MadamConfig(lr=2.0)
    params = init_lns_params({"w": jax.random.normal(key, (8, 8))}, mcfg)
    init, update = madam_lns(mcfg)
    st = init(params)
    for i in range(10):
        g = {"w": jnp.ones((8, 8))}
        params, st = update(g, st, params)
    c = np.asarray(params["w"].code)
    assert c.min() >= 0 and c.max() <= mcfg.update_format.max_code


def test_update_is_integer_exponent_step(key):
    """One Madam step moves each code by round(η·γ_U·g*·sign(W))."""
    mcfg = MadamConfig(lr=2.0 ** -7, beta=0.999)
    w = jnp.abs(jax.random.normal(key, (4, 4))) + 0.5
    params = init_lns_params({"w": w}, mcfg)
    init, update = madam_lns(mcfg)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (4, 4))}
    new_params, st = update(g, init(params), params)
    gf = np.asarray(g["w"], np.float64)
    v = (1 - mcfg.beta) * gf * gf
    bc = 1 - mcfg.beta
    gstar = gf / np.sqrt(v / bc + mcfg.eps)
    step = mcfg.lr * mcfg.update_format.gamma * gstar * np.asarray(
        params["w"].sign)
    want = np.clip(np.floor(np.asarray(params["w"].code) + step + 0.5), 0,
                   mcfg.update_format.max_code)
    np.testing.assert_array_equal(np.asarray(new_params["w"].code), want)


def _quadratic_loss(target):
    def loss(dense):
        return jnp.sum((dense["w"] - target) ** 2)
    return loss


def test_madam_lns_converges_on_quadratic(key):
    """LNS-native Madam drives a quadratic toward its optimum with NO fp
    master copy — the paper's core claim."""
    target = jnp.abs(jax.random.normal(key, (8, 8))) + 0.5
    w0 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (8, 8))) + 0.5
    mcfg = MadamConfig(lr=2.0 ** -5)
    params = init_lns_params({"w": w0}, mcfg)
    init, update = madam_lns(mcfg)
    st = init(params)
    loss_fn = _quadratic_loss(target)
    losses = []
    for _ in range(300):
        dense = materialize(params, mcfg, dtype=jnp.float32)
        losses.append(float(loss_fn(dense)))
        g = jax.grad(loss_fn)(dense)
        params, st = update(g, st, params)
    assert losses[-1] < 0.05 * losses[0]


def test_factored_matches_full_direction(key):
    """Factored g2 yields updates within ~30% of full-g2 codes on average."""
    w0 = jnp.abs(jax.random.normal(key, (16, 16))) + 0.5
    g = jax.random.normal(jax.random.fold_in(key, 1), (16, 16))
    full_cfg = MadamConfig(lr=2.0 ** -5)
    fact_cfg = MadamConfig(lr=2.0 ** -5, factored=True)
    out = {}
    for name, mcfg in (("full", full_cfg), ("fact", fact_cfg)):
        params = init_lns_params({"w": w0}, mcfg)
        init, update = madam_lns(mcfg)
        st = init(params)
        new_p, _ = update({"w": g}, st, params)
        out[name] = np.asarray(new_p["w"].code, np.int32) - np.asarray(
            params["w"].code, np.int32)
    # sign of the step always agrees; magnitudes are close
    agree = (np.sign(out["full"]) == np.sign(out["fact"])) | (out["full"] == 0)
    assert agree.mean() > 0.95


def test_factored_state_is_small(key):
    mcfg = MadamConfig(factored=True)
    params = init_lns_params({"w": jax.random.normal(key, (64, 128))}, mcfg)
    init, _ = madam_lns(mcfg)
    st = init(params)
    n = sum(x.size for x in jax.tree.leaves(st.g2))
    assert n == 64 + 128  # row + col instead of 64*128


def test_quantized_update_wrapper_keeps_grid(key):
    fmt = LNSFormat(bits=10, gamma=32)
    opt = quantized_update(sgd(lr=0.1), fmt)
    init, update = opt
    params = {"w": jnp.abs(jax.random.normal(key, (8, 8))) + 0.1}
    st = init(params)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8, 8))}
    new_p, _ = update(g, st, params)
    from repro.core.lns import lns_quantize
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(lns_quantize(new_p["w"], fmt)),
                               rtol=1e-6)


def test_sgd_adamw_reduce_quadratic(key):
    target = jax.random.normal(key, (8,))
    for opt in (sgd(lr=0.05, weight_decay=0.0), adamw(lr=0.05)):
        init, update = opt
        params = {"w": jnp.zeros((8,))}
        st = init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, st = update(g, st, params)
        assert float(jnp.sum((params["w"] - target) ** 2)) < 1e-2


def test_lns_update_matches_base2_closed_form(key):
    """At a very fine Q_U grid, the integer-exponent step converges to the
    continuous base-2 multiplicative update W·2^(-η·g*·sign W) (Eq. 9
    with base 2 — Algorithm 1)."""
    w0 = jnp.abs(jax.random.normal(key, (8, 8))) + 0.5
    g = jax.random.normal(jax.random.fold_in(key, 1), (8, 8))
    mcfg = MadamConfig(lr=2.0 ** -6,
                       update_format=LNSFormat(bits=24, gamma=8 * (1 << 16)))
    params = init_lns_params({"w": w0}, mcfg)
    init, update = madam_lns(mcfg)
    new_p, _ = update({"w": g}, init(params), params)
    lns_w = lns_decode(new_p["w"].sign, new_p["w"].code, mcfg.update_format,
                       new_p["w"].scale, jnp.float32)
    gf = g.astype(jnp.float32)
    bc = 1.0 - mcfg.beta
    gstar = gf * jax.lax.rsqrt((1 - mcfg.beta) * gf * gf / bc + mcfg.eps)
    want = w0 * jnp.exp2(-mcfg.lr * gstar * jnp.sign(w0))
    np.testing.assert_allclose(np.asarray(lns_w), np.asarray(want), rtol=1e-3)
