"""Checkpoint manager: atomic commit, keep-k, async, elastic restore."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.lns import LNSFormat
from repro.optim.madam import LNSWeight

_FMT = LNSFormat(bits=16, gamma=8 * (1 << 8))


def _state(key, scale=1.0):
    return {
        "w": LNSWeight(packed=(jnp.arange(16).reshape(4, 4) * scale
                               ).astype(jnp.uint16),
                       scale=jnp.ones((1, 4)), fmt=_FMT),
        "b": jax.random.normal(key, (8,)),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path, key):
    m = CheckpointManager(str(tmp_path), keep=3)
    st = _state(key)
    m.save(7, st, data_cursor=42, async_=False)
    assert m.latest_step() == 7
    assert m.manifest(7)["data_cursor"] == 42
    step, restored = m.restore_latest(st)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_save_and_wait(tmp_path, key):
    m = CheckpointManager(str(tmp_path))
    m.save(1, _state(key), async_=True)
    m.wait()
    assert m.latest_step() == 1


def test_keep_k_gc(tmp_path, key):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _state(key), async_=False)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert m.latest_step() == 4


def test_atomicity_no_partial_latest(tmp_path, key):
    """LATEST only ever points at a fully-committed snapshot."""
    m = CheckpointManager(str(tmp_path))
    m.save(5, _state(key), async_=False)
    # simulate a crashed later save: orphaned tmp dir
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert m.latest_step() == 5
    _, restored = m.restore_latest(_state(key))
    assert int(restored["step"]) == 7  # payload intact


def test_elastic_restore_with_shardings(tmp_path, key):
    """Restore places arrays with explicitly-provided (new-mesh) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    m = CheckpointManager(str(tmp_path))
    st = {"w": jnp.arange(8.0)}
    m.save(1, st, async_=False)
    sh = {"w": NamedSharding(mesh, P("data"))}
    _, restored = m.restore_latest(st, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(st["w"]))


def test_restore_casts_dtype(tmp_path, key):
    m = CheckpointManager(str(tmp_path))
    st = {"w": jnp.arange(8, dtype=jnp.float32)}
    m.save(1, st, async_=False)
    like = {"w": jnp.zeros(8, jnp.bfloat16)}
    _, restored = m.restore_latest(like)
    assert restored["w"].dtype == jnp.bfloat16
