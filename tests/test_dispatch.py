"""Packed-LNS store + kernel dispatch layer (DESIGN.md §3-4).

Covers the acceptance surface of the packed refactor:
  * one wire format: training state is packed words (1 B/elem at B=8),
    checkpoints round-trip it bit-exactly, serving loads them unchanged
  * backend registry: reference == pallas (interpret) on GEMM, update,
    train and decode; env-var override resolves
  * integer re-grid (B_U -> B_W) matches decode->re-encode bit-exactly
  * the kernel's tile decode is pinned to the jnp oracle across formats
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lns import (LNSFormat, LNSWeight, compute_scale, lns_decode,
                            lns_decode_packed, lns_encode, lns_pack,
                            lns_requant_packed, lns_unpack)
from repro.core.quantizer import QuantConfig, qeinsum
from repro.kernels import dispatch
from repro.optim.madam import MadamConfig, init_lns_params
from repro.training import (build_decode_step, build_train_step,
                            init_train_state)
from repro.training.data import SyntheticLM

FMT8 = LNSFormat(bits=8, gamma=8)
SERVE_MCFG = MadamConfig(update_format=FMT8)


def _packed(key, shape, fmt=FMT8):
    x = jax.random.normal(key, shape)
    s = compute_scale(x)
    return lns_pack(*lns_encode(x, fmt, s), fmt), x, s


# ---------------------------------------------------------------------------
# shared decode / integer re-grid


@pytest.mark.parametrize("bits,gamma", [(8, 8), (8, 2), (16, 2048), (10, 32)])
def test_decode_packed_pinned_to_oracle(key, bits, gamma):
    """The kernel-prologue decode == unpack+decode oracle for every fmt."""
    fmt = LNSFormat(bits=bits, gamma=gamma)
    codes = jax.random.randint(key, (64, 32), 0, fmt.max_code + 1, jnp.int32)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                          (64, 32)), 1, -1).astype(jnp.int8)
    packed = lns_pack(sign, codes, fmt)
    got = lns_decode_packed(packed, fmt, jnp.float32)
    s, c = lns_unpack(packed, fmt)
    want = lns_decode(s, c, fmt, jnp.ones(()), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requant_matches_float_reencode(key):
    """16-bit words -> 8-bit grid: the shift-round == decode->encode off
    the exact grid ties; at ties the integer path rounds deterministically
    (away from zero) while the float path depends on f32 roundoff."""
    src = LNSFormat(bits=16, gamma=8 * 256)
    dst = FMT8
    ratio = src.gamma // dst.gamma
    codes = jax.random.randint(key, (4096,), 0, src.max_code + 1, jnp.int32)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                          codes.shape), 1, -1).astype(jnp.int8)
    packed = lns_pack(sign, codes, src)
    got = lns_requant_packed(packed, src, dst)
    dense = lns_decode(sign, codes, src, jnp.ones(()), jnp.float32)
    want = lns_pack(*lns_encode(dense, dst, jnp.ones(())), dst)
    tie = np.asarray(codes % ratio) == ratio // 2
    np.testing.assert_array_equal(np.asarray(got)[~tie],
                                  np.asarray(want)[~tie])
    # ties: deterministic round-away — code floor(c/r)+1, sign preserved
    want_tie = np.minimum(np.asarray(codes)[tie] // ratio + 1, dst.max_code)
    got_tie = np.asarray(got)[tie]
    np.testing.assert_array_equal(got_tie & dst.max_code, want_tie)
    np.testing.assert_array_equal(got_tie >> (dst.bits - 1),
                                  np.asarray(packed)[tie] >> (src.bits - 1))


def test_requant_identity_and_widen(key):
    packed, _, _ = _packed(key, (32, 32))
    same = lns_requant_packed(packed, FMT8, FMT8)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(packed))
    wide = lns_requant_packed(packed, FMT8, LNSFormat(bits=16, gamma=8 * 256))
    s8, c8 = lns_unpack(packed, FMT8)
    s16, c16 = lns_unpack(wide, LNSFormat(bits=16, gamma=8 * 256))
    np.testing.assert_array_equal(np.asarray(s16), np.asarray(s8))
    np.testing.assert_array_equal(np.asarray(c16),
                                  np.asarray(c8.astype(np.int32) * 256))


# ---------------------------------------------------------------------------
# backend registry


def test_backend_resolution_env_override(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_BACKEND, raising=False)
    assert dispatch.resolve_backend(None) in dispatch.BACKENDS
    monkeypatch.setenv(dispatch.ENV_BACKEND, "pallas")
    assert dispatch.resolve_backend(None) == "pallas"
    monkeypatch.setenv(dispatch.ENV_BACKEND, "reference")
    assert dispatch.resolve_backend(None) == "reference"
    assert dispatch.resolve_backend("pallas") == "pallas"  # arg wins
    monkeypatch.setenv(dispatch.ENV_BACKEND, "nope")
    with pytest.raises(ValueError):
        dispatch.resolve_backend(None)


def test_configure_outranks_arg_and_env(monkeypatch):
    """Layer 1 beats everything: configure() wins over the per-call arg
    (the channel config fields use) and over the env var."""
    monkeypatch.setenv(dispatch.ENV_BACKEND, "pallas")
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "0")
    with dispatch.configured(backend="reference", interpret=True):
        assert dispatch.resolve_backend("pallas") == "reference"
        assert dispatch.resolve_interpret(False) is True
    # restored on exit: arg > env > auto again
    assert dispatch.resolve_backend("pallas") == "pallas"
    assert dispatch.resolve_backend(None) == "pallas"  # env layer
    assert dispatch.resolve_interpret(None) is False


def test_configure_partial_fields_and_clear():
    prev = dispatch.get_configured()
    try:
        dispatch.configure(backend="reference")
        assert dispatch.get_configured().backend == "reference"
        assert dispatch.get_configured().interpret is None  # untouched
        dispatch.configure(interpret=True)
        assert dispatch.get_configured().backend == "reference"  # untouched
        dispatch.configure(backend=None)  # clear one field only
        assert dispatch.get_configured().backend is None
        assert dispatch.get_configured().interpret is True
    finally:
        dispatch.configure(backend=prev.backend, interpret=prev.interpret)


def test_configure_rejects_unknown_backend():
    with pytest.raises(ValueError):
        dispatch.configure(backend="mosaic")
    assert dispatch.get_configured().backend is None  # state unchanged


def test_configured_restores_on_exception():
    with pytest.raises(RuntimeError):
        with dispatch.configured(backend="reference"):
            assert dispatch.get_configured().backend == "reference"
            raise RuntimeError("boom")
    assert dispatch.get_configured().backend is None


def test_configured_nests():
    with dispatch.configured(backend="reference"):
        with dispatch.configured(interpret=True):
            st = dispatch.get_configured()
            assert st.backend == "reference" and st.interpret is True
        assert dispatch.get_configured().interpret is None
    assert dispatch.get_configured().backend is None


def test_config_backend_fields_removed():
    """The deprecated ``backend`` config fields are gone; both attribute
    access and the ctor kwarg point at ``dispatch.configure()``."""
    with pytest.raises(AttributeError, match="configure"):
        QuantConfig.lns_madam().backend
    with pytest.raises(AttributeError, match="configure"):
        MadamConfig(update_format=FMT8).backend
    with pytest.raises(TypeError, match="configure"):
        QuantConfig(backend="pallas")
    with pytest.raises(TypeError, match="configure"):
        MadamConfig(update_format=FMT8, backend="pallas")
    # replace() routes through __init__, so the old test idiom raises too
    with pytest.raises(TypeError, match="configure"):
        dataclasses.replace(SERVE_MCFG, backend="reference")


def test_interpret_resolution_env_override(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_INTERPRET, raising=False)
    # compiled wherever pallas is the platform default (TPU/GPU)
    assert dispatch.resolve_interpret(None) == (
        jax.default_backend() not in ("tpu", "gpu"))
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "0")
    assert dispatch.resolve_interpret(None) is False
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "true")
    assert dispatch.resolve_interpret(None) is True
    assert dispatch.resolve_interpret(False) is False  # arg wins
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "sometimes")
    with pytest.raises(ValueError):
        dispatch.resolve_interpret(None)


@pytest.mark.interpret
def test_qmatmul_backends_agree(key):
    pa, _, sa = _packed(jax.random.fold_in(key, 1), (64, 48))
    pb, _, sb = _packed(jax.random.fold_in(key, 2), (48, 40))
    ref = dispatch.qmatmul(pa, pb, FMT8, sa, sb, backend="reference")
    pal = dispatch.qmatmul(pa, pb, FMT8, sa, sb, backend="pallas",
                           interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.interpret
def test_encode_pack_backends_agree(key):
    x = jax.random.normal(key, (100, 60))
    pr, sr = dispatch.encode_pack(x, FMT8, scale_axis=0, backend="reference")
    pp, sp = dispatch.encode_pack(x, FMT8, scale_axis=0, backend="pallas",
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(pp))
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sp))


@pytest.mark.interpret
@pytest.mark.parametrize("bits", [6, 7, 8])
def test_requant_pack_backends_bit_exact(key, bits):
    """The draft re-grid dispatch op: pallas (interpret) == reference,
    word for word, at every draft bitwidth (8 = identity) including
    tile-padded odd shapes and 3-D stacked leaves."""
    dst = FMT8.with_bits(bits)
    for shape in ((64, 48), (33, 17), (3, 20, 11)):
        packed, _, _ = _packed(jax.random.fold_in(key, sum(shape)), shape)
        ref = dispatch.requant_pack(packed, FMT8, dst, backend="reference")
        pal = dispatch.requant_pack(packed, FMT8, dst, backend="pallas",
                                    interpret=True)
        assert ref.dtype == jnp.uint8 and ref.shape == shape
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.interpret
def test_madam_step_backends_bit_exact(key):
    """The fused packed update: pallas (interpret) == jnp reference, word
    for word, including 3-D leaves folded to 2-D."""
    fmt = LNSFormat(bits=16, gamma=8 * 256)
    codes = jax.random.randint(key, (3, 40, 20), 0, fmt.max_code + 1,
                               jnp.int32)
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                          codes.shape), 1, -1).astype(jnp.int8)
    packed = lns_pack(sign, codes, fmt)
    g = jax.random.normal(jax.random.fold_in(key, 2), codes.shape)
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), codes.shape))
    a = dispatch.madam_step(packed, g, v, jnp.asarray(4), fmt, lr=2.0 ** -7,
                            backend="reference")
    b = dispatch.madam_step(packed, g, v, jnp.asarray(4), fmt, lr=2.0 ** -7,
                            backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=1e-6)


# ---------------------------------------------------------------------------
# routed qeinsum


def test_qeinsum_routes_packed_weight(key):
    """Packed 2-D weights route (no dense fake-quant) and stay close to the
    dense fake-quant answer."""
    x = jax.random.normal(key, (4, 6, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    lw = init_lns_params({"w": w}, SERVE_MCFG)["w"]
    qcfg = dataclasses.replace(QuantConfig.lns_madam(), update=FMT8)
    with dispatch.configured(backend="reference"):
        y_packed = qeinsum("bsd,df->bsf", x, lw, qcfg)
        y_dense = qeinsum("bsd,df->bsf", x, w, qcfg)
    assert y_packed.shape == (4, 6, 16) and y_packed.dtype == x.dtype
    rel = float(jnp.max(jnp.abs(y_packed - y_dense))
                / jnp.max(jnp.abs(y_dense)))
    assert rel < 0.15


def test_routed_gradients_match_ste(key):
    """dL/dx and dL/dW of the routed path == the fake-quant STE path when
    weights are already on the forward grid (same scale)."""
    qcfg = dataclasses.replace(QuantConfig.lns_madam(), update=FMT8)
    x = jax.random.normal(key, (8, 32)).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    lw = init_lns_params({"w": w}, SERVE_MCFG)["w"]
    wq = lw.decode(jnp.float32)  # exactly on the stored grid

    def loss_packed(x, delta):
        out = qeinsum("bd,df->bf", x, lw.replace(delta=delta), qcfg)
        return jnp.sum(out * out)

    def loss_dense(x, w):
        return jnp.sum(jnp.square(qeinsum("bd,df->bf", x, w, qcfg)))

    with dispatch.configured(backend="reference"):
        gx_p, gd = jax.grad(loss_packed, (0, 1))(x, jnp.zeros_like(wq))
        gx_d, gw = jax.grad(loss_dense, (0, 1))(x, wq)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_d),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gw),
                               rtol=2e-2, atol=2e-2)


def test_qeinsum_fallback_decodes_nonroutable(key):
    """3-D packed stacks and non-LNS configs fall back to per-leaf decode."""
    x = jax.random.normal(key, (2, 4, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 8, 5))
    lw = init_lns_params({"w": w}, SERVE_MCFG)["w"]
    qcfg = dataclasses.replace(QuantConfig.lns_madam(), update=FMT8)
    y = qeinsum("bsd,edf->bsef", x, lw, qcfg)  # not a routable plan
    assert y.shape == (2, 4, 3, 5)
    y_fp = qeinsum("bsd,edf->bsef", x, lw, None)  # fp config: decode path
    assert y_fp.shape == (2, 4, 3, 5)


# ---------------------------------------------------------------------------
# the 1-byte store + checkpoint/serving interop


def test_train_state_is_one_byte_per_element():
    """>=2-D training parameter state at B=8 is exactly 1 byte/element."""
    cfg = get_smoke_config("smollm-135m")
    state = init_train_state(jax.random.PRNGKey(0), cfg, SERVE_MCFG)

    def visit(leaf):
        if isinstance(leaf, LNSWeight):
            assert leaf.packed.dtype == jnp.uint8  # 1 B/elem wire words
            assert leaf.packed.dtype.itemsize == 1
            assert leaf.delta is None
            visit.count += 1
    visit.count = 0
    jax.tree.map(visit, state.params,
                 is_leaf=lambda l: isinstance(l, LNSWeight))
    assert visit.count >= 5  # embed + attn + mlp stacks all packed


def test_checkpoint_roundtrip_and_serving_load(tmp_path):
    """A training checkpoint is loaded by the serving engine with zero
    re-encoding: identical packed bytes, working decode."""
    from repro.checkpoint import CheckpointManager
    from repro.serving import Engine
    from repro.serving.request import Request

    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    state = init_train_state(jax.random.PRNGKey(0), cfg, SERVE_MCFG)
    step = jax.jit(build_train_step(cfg, qcfg, SERVE_MCFG))
    data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
    b = jax.tree.map(jnp.asarray, data.batch_at(0))
    state, _ = step(state, b)

    m = CheckpointManager(str(tmp_path))
    m.save(1, state, async_=False)
    _, restored = m.restore_latest(state)
    for a, c in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        assert a.dtype == c.dtype  # uint8 words restored as uint8 words

    engine = Engine(cfg, qcfg, SERVE_MCFG, restored.params, num_slots=2,
                    max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    while engine.step():
        pass
    assert len(engine.finished) == 1
    assert len(engine.finished[0].generated) >= 1


# ---------------------------------------------------------------------------
# end-to-end backend equivalence (acceptance: train + decode)


@pytest.mark.interpret
def test_train_backends_equivalent():
    """3 train steps on smollm: pallas (interpret) losses == reference
    losses to tolerance; parameter words near-identical."""
    cfg = get_smoke_config("smollm-135m")
    losses, params = {}, {}
    for backend in ("reference", "pallas"):
        qcfg = dataclasses.replace(QuantConfig.lns_madam(), update=FMT8)
        with dispatch.configured(backend=backend):
            state = init_train_state(jax.random.PRNGKey(0), cfg, SERVE_MCFG)
            step = jax.jit(build_train_step(cfg, qcfg, SERVE_MCFG))
            data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
            ls = []
            for i, b in zip(range(3), data):
                state, m = step(state, jax.tree.map(jnp.asarray, b))
                ls.append(float(m["loss"]))
        losses[backend] = ls
        params[backend] = state.params
    np.testing.assert_allclose(losses["reference"], losses["pallas"],
                               rtol=1e-4)
    agree = [
        float(np.mean(np.asarray(a) == np.asarray(b)))
        for a, b in zip(jax.tree.leaves(params["reference"]),
                        jax.tree.leaves(params["pallas"]))
        if np.asarray(a).dtype == np.uint8]
    assert min(agree) > 0.99  # bf16 GEMM tile-order noise only


@pytest.mark.interpret
def test_decode_backends_equivalent():
    cfg = get_smoke_config("smollm-135m")
    from repro.models import init_caches
    state = init_train_state(jax.random.PRNGKey(0), cfg, SERVE_MCFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0,
                              cfg.vocab_size)
    outs = {}
    for backend in ("reference", "pallas"):
        qcfg = dataclasses.replace(QuantConfig.lns_madam(), update=FMT8)
        with dispatch.configured(backend=backend):
            decode = jax.jit(build_decode_step(cfg, qcfg, SERVE_MCFG))
            caches = init_caches(2, 16, cfg)
            logits, _ = decode(state.params, caches, {"tokens": toks},
                               jnp.asarray(0, jnp.int32))
            outs[backend] = np.asarray(logits)
    np.testing.assert_allclose(outs["reference"], outs["pallas"],
                               rtol=1e-3, atol=1e-3)
