"""Paged KV-cache pool: block allocator, prefix caching, and
token-for-token equivalence of the paged engine with the dense engine."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.optim.madam import MadamConfig
from repro.serving import Engine, Request
from repro.serving.scheduler import BlockAllocator
from repro.training import init_train_state


# ---------------------------------------------------------------------------
# allocator (pure python)


def test_allocator_alloc_release_refcount():
    a = BlockAllocator(num_pages=4, page_size=2)
    pages = a.alloc(3)
    assert len(pages) == 3 and len(set(pages)) == 3
    assert a.available == 1
    assert a.alloc(2) is None          # over capacity: nothing taken
    assert a.available == 1
    a.retain(pages[:1])                # second reference
    a.release(pages)                   # slot drops its refs
    assert a.available == 3            # pages[0] still held once
    a.release(pages[:1])
    assert a.available == 4
    with pytest.raises(ValueError, match="released more than retained"):
        a.release(pages[:1])


def test_allocator_prefix_registry_and_lru_eviction():
    a = BlockAllocator(num_pages=3, page_size=2)
    keys = BlockAllocator.chain_keys([1, 2, 3, 4], page_size=2)
    assert len(keys) == 2 and keys[0] != keys[1]
    # same tokens -> same chain; different first page -> different chain
    assert BlockAllocator.chain_keys([1, 2, 3, 4], 2) == keys
    assert BlockAllocator.chain_keys([9, 2, 3, 4], 2)[1] != keys[1]

    (p0, p1) = a.alloc(2)
    a.register(keys[0], p0)
    a.register(keys[1], p1)
    assert a.match(keys) == [p0, p1]
    a.release([p0, p1])
    assert a.cached == 2               # resident but unreferenced
    assert a.match(keys) == [p0, p1]   # still matchable
    hit = a.match(keys)
    a.retain(hit)                      # a prefix hit revives them
    assert a.cached == 0
    a.release(hit)
    # pressure: 3 allocs force eviction of the oldest cached page (p0);
    # the chain then breaks at its first page even though p1 survives
    taken = a.alloc(3)
    assert taken is not None
    assert a.match(keys) == []
    a.release(taken)


def test_allocator_match_stops_at_first_gap():
    a = BlockAllocator(num_pages=4, page_size=2)
    keys = BlockAllocator.chain_keys(list(range(8)), 2)
    pages = a.alloc(2)
    a.register(keys[0], pages[0])
    a.register(keys[2], pages[1])      # gap at keys[1]
    assert a.match(keys) == [pages[0]]


# ---------------------------------------------------------------------------
# engine over the real model


@pytest.fixture(scope="module")
def smollm_setup():
    cfg = get_smoke_config("smollm-135m")
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    return cfg, qcfg, mcfg, params


def _trace(cfg, n, seed=3, base_prompt=5, base_gen=4):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (base_prompt + 3 * i,)).tolist(),
                    max_new_tokens=base_gen + i) for i in range(n)]


def _by_rid(engine):
    return {rs.request.rid: rs.generated for rs in engine.finished}


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-12b", "rwkv6-1.6b"])
def test_paged_engine_matches_dense_engine(arch):
    """Acceptance: paged == dense token-for-token on the full-context,
    sliding-window (rings stay dense), and recurrent (fully dense
    fallback) smokes — including slot recycling."""
    cfg = get_smoke_config(arch)
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    params = init_train_state(jax.random.PRNGKey(0), cfg, mcfg).params
    dense = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=24)
    dense.run(_trace(cfg, 3))
    paged = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=24,
                   page_size=4)
    paged.run(_trace(cfg, 3))
    assert _by_rid(dense) == _by_rid(paged)


def test_paged_pool_smaller_than_dense_equivalent(smollm_setup):
    """More slots than the dense layout could back: 4 slots x max_len 32
    would need 32 pages dense-equivalent; 14 pages still serve the trace
    (short requests hold few pages), token-identical to the dense engine."""
    cfg, qcfg, mcfg, params = smollm_setup
    dense = Engine(cfg, qcfg, mcfg, params, num_slots=4, max_len=32)
    dense.run(_trace(cfg, 6))
    paged = Engine(cfg, qcfg, mcfg, params, num_slots=4, max_len=32,
                   page_size=4, num_pages=14, prefix_cache=False)
    paged.run(_trace(cfg, 6))
    assert _by_rid(dense) == _by_rid(paged)


def test_prefix_hit_skips_prefill_work(smollm_setup):
    """A shared-prefix trace must reuse resident pages: fewer prefill
    tokens processed, same tokens generated."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (13,)).tolist()
    reqs = lambda: [Request(rid=i, prompt=list(prompt), max_new_tokens=5)
                    for i in range(3)]
    buckets = (4, 8, 16, 32)  # fine buckets so the suffix shrinks the shape
    hit = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                 page_size=4, buckets=buckets)
    hit.run(reqs())
    miss = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                  page_size=4, buckets=buckets, prefix_cache=False)
    miss.run(reqs())
    assert _by_rid(hit) == _by_rid(miss)
    assert hit.prefix_hits == 2
    assert hit.prefix_reused_tokens == 2 * 12  # 3 full pages, last tok redone
    assert hit.prefill_tokens < miss.prefill_tokens


def test_prefix_cow_on_page_aligned_prompt(smollm_setup):
    """A fully page-aligned duplicate prompt reuses everything but the
    last token, whose page is copy-on-write — concurrent slots sharing
    the chain must not corrupt each other."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (12,)).tolist()  # 3 pages @4
    e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=32, page_size=4)
    e.run([Request(rid=0, prompt=list(prompt), max_new_tokens=6),
           Request(rid=1, prompt=list(prompt), max_new_tokens=6)])
    ref = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                 page_size=4, prefix_cache=False)
    ref.run([Request(rid=0, prompt=list(prompt), max_new_tokens=6)])
    want = ref.finished[0].generated
    got = _by_rid(e)
    assert got[0] == want and got[1] == want
    assert e.prefix_hits == 1 and e.prefix_reused_tokens == 11


def test_prefix_divergent_suffix(smollm_setup):
    """Reuse only the shared aligned prefix when prompts diverge."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(13)
    p1 = rng.integers(0, cfg.vocab_size, (12,)).tolist()
    p2 = p1[:8] + rng.integers(0, cfg.vocab_size, (7,)).tolist()
    e = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32, page_size=4)
    e.run([Request(rid=0, prompt=p1, max_new_tokens=4),
           Request(rid=1, prompt=p2, max_new_tokens=4)])
    ref = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                 page_size=4, prefix_cache=False)
    ref.run([Request(rid=1, prompt=list(p2), max_new_tokens=4)])
    assert _by_rid(e)[1] == ref.finished[0].generated
    assert e.prefix_reused_tokens == 8  # the two shared full pages


def test_allocator_exhaustion_keeps_request_queued(smollm_setup):
    """Pool pressure: a request the pool can't host yet stays queued (no
    wedge) and is admitted once a finishing slot releases pages."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(2)
    # each request holds ceil((8+7)/4) = 4 pages; the 6-page pool serves
    # only one at a time even though two decode slots exist
    e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
               page_size=4, num_pages=6, prefix_cache=False)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (8,)).tolist(),
                    max_new_tokens=8) for i in range(3)]
    e.run(reqs)
    assert sorted(_by_rid(e)) == [0, 1, 2]
    by = {m.rid: m for m in e.completed}
    admits = sorted(by[r].t_admit for r in by)
    finishes = sorted(by[r].t_finish for r in by)
    assert admits[1] >= finishes[0]  # second admission waited for pages
    # the pool itself is smaller than one dense slot pair, yet nothing
    # leaked: all pages are reclaimable afterwards
    assert e.allocator.available == e.num_pages


def test_prefix_hit_on_exactly_sized_pool_degrades_not_wedges(smollm_setup):
    """Regression: the CoW hold transiently pins one page beyond the
    request's own demand. On a pool sized exactly at the demand, a
    prefix re-hit must forfeit the reuse and proceed — not requeue the
    identical reservation forever."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(19)
    prompt = rng.integers(0, cfg.vocab_size, (8,)).tolist()  # 2 pages @4
    # pages_needed = ceil(min(8 + 9 - 1, 16) / 4) = 4 == num_pages
    e = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16,
               page_size=4, num_pages=4)
    e.run([Request(rid=0, prompt=list(prompt), max_new_tokens=9)])
    e.run([Request(rid=1, prompt=list(prompt), max_new_tokens=9)])
    a, b = sorted(e.finished, key=lambda r: r.request.rid)
    assert a.generated == b.generated  # completed, token-identical
    assert e.prefix_reused_tokens <= 4  # boundary reuse was forfeited


def test_oversized_page_demand_rejected_at_submit(smollm_setup):
    cfg, qcfg, mcfg, params = smollm_setup
    e = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16,
               page_size=4, num_pages=2)
    with pytest.raises(ValueError, match="KV"):
        e.submit(Request(rid=0, prompt=list(range(12)), max_new_tokens=8))
    assert not e.queue and e.scheduler.free_slots == 1


def test_paged_quantized_kv_cache_matches_dense(smollm_setup):
    """kv_cache_bits: the paged pool stores the same packed-LNS wire
    format as the dense cache — tokens must agree."""
    import dataclasses
    cfg, qcfg, mcfg, params = smollm_setup
    qc = dataclasses.replace(cfg, kv_cache_bits=8)
    dense = Engine(qc, qcfg, mcfg, params, num_slots=2, max_len=24)
    dense.run(_trace(qc, 3))
    paged = Engine(qc, qcfg, mcfg, params, num_slots=2, max_len=24,
                   page_size=4)
    paged.run(_trace(qc, 3))
    assert _by_rid(dense) == _by_rid(paged)


def test_recycled_paged_slot_reproduces_fresh_output(smollm_setup):
    """Stale pages from a released request must never leak into a new
    one admitted into the same slot (block tables reset to the null
    page, fresh pages rewritten by prefill)."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(17)
    pa = rng.integers(0, cfg.vocab_size, (10,)).tolist()
    pb = rng.integers(0, cfg.vocab_size, (10,)).tolist()
    e = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
               page_size=4, prefix_cache=False)
    e.run([Request(rid=0, prompt=pa, max_new_tokens=5),
           Request(rid=1, prompt=pb, max_new_tokens=5)])
    fresh = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=32,
                   page_size=4, prefix_cache=False)
    fresh.run([Request(rid=0, prompt=list(pb), max_new_tokens=5)])
    assert _by_rid(e)[1] == fresh.finished[0].generated

# ---------------------------------------------------------------------------
# on-demand allocation policy: decode-time growth + preemption by recompute


def test_alloc_policy_validated(smollm_setup):
    cfg, qcfg, mcfg, params = smollm_setup
    with pytest.raises(ValueError, match="alloc_policy"):
        Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16,
               page_size=4, alloc_policy="lazy")
    dense = Engine(cfg, qcfg, mcfg, params, num_slots=1, max_len=16,
                   alloc_policy="ondemand")
    assert dense.alloc_policy is None  # policy is a paged-mode concept


def test_ondemand_unpressured_matches_reserve_bitwise(smollm_setup):
    """On a roomy pool both policies admit identically, so the page-by-
    page growth path must reproduce the reserve streams token for token
    — while actually exercising decode-time allocation."""
    cfg, qcfg, mcfg, params = smollm_setup
    kw = dict(num_slots=2, max_len=32, page_size=4, num_pages=16,
              prefix_cache=False)
    res = Engine(cfg, qcfg, mcfg, params, **kw)
    res.run(_trace(cfg, 4))
    ond = Engine(cfg, qcfg, mcfg, params, alloc_policy="ondemand", **kw)
    ond.run(_trace(cfg, 4))
    assert _by_rid(res) == _by_rid(ond)
    assert ond.preemptions == 0
    assert ond.decode_page_allocs > 0  # growth, not up-front reservation
    assert ond.decode_compiles == 1   # growth never reshapes the step


def test_ondemand_preempts_under_pressure_and_completes(smollm_setup):
    """A pool too small for both requests' full contexts forces the
    youngest request out mid-decode; it must resume by recompute and
    finish with its delivered prefix intact (no token re-emitted)."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(23)
    # each request grows to ceil((8+8-1)/4) = 4 pages; 6 < 2*4 forces
    # preemption once both slots cross into their third page
    e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
               page_size=4, num_pages=6, prefix_cache=False,
               alloc_policy="ondemand")
    emitted = {}
    e.token_sink = lambda rid, tok: emitted.setdefault(rid, []).append(tok)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (8,)).tolist(),
                    max_new_tokens=8) for i in range(2)]
    e.run(reqs)
    assert e.preemptions > 0
    by = _by_rid(e)
    assert sorted(by) == [0, 1]
    assert all(len(v) == 8 for v in by.values())
    # the stream seen by the sink is exactly the final token list: a
    # preempted request never re-emits or re-draws delivered tokens
    assert emitted == by
    assert e.allocator.available == e.num_pages  # nothing leaked
    assert e.decode_compiles == 1


def test_ondemand_admits_earlier_than_reserve(smollm_setup):
    """The policy's point: reserve serializes the two requests (worst
    case 4 pages each on a 6-page pool), ondemand co-runs them."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(29)
    reqs = lambda: [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, (8,)).tolist(), max_new_tokens=8)
        for i in range(2)]
    overlap = {}
    for pol in ("reserve", "ondemand"):
        e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
                   page_size=4, num_pages=6, prefix_cache=False,
                   alloc_policy=pol)
        e.run(reqs())
        by = {m.rid: m for m in e.completed}
        overlap[pol] = by[1].t_admit < by[0].t_finish
    assert not overlap["reserve"]  # second request waited for pages
    assert overlap["ondemand"]     # both decoded concurrently


def test_ondemand_abort_of_preempted_request(smollm_setup):
    """Aborting a request while it waits out a preemption must drop it
    cleanly: terminal event fires, the survivor finishes, and every
    page returns to the pool."""
    cfg, qcfg, mcfg, params = smollm_setup
    rng = np.random.default_rng(31)
    e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
               page_size=4, num_pages=6, prefix_cache=False,
               alloc_policy="ondemand")
    fins = []
    e.finish_sink = lambda rid, reason, rs: fins.append((rid, reason))
    for i in range(2):
        e.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (8,)).tolist(), max_new_tokens=8))
    while e.preemptions == 0:
        assert e.step()
    assert e.abort(1)  # rid 1 is the youngest, hence the victim
    while e.step():
        pass
    assert (1, "aborted") in fins and (0, "length") in fins
    assert [rs.request.rid for rs in e.aborted] == [1]
    assert e.aborted[0].generated  # delivered prefix retained
    assert sorted(_by_rid(e)) == [0]
    assert e.allocator.available == e.num_pages


def test_ondemand_deterministic_across_runs(smollm_setup):
    """Same trace, same engine config: preemption timing and streams
    must replay identically (reset clears all policy state)."""
    cfg, qcfg, mcfg, params = smollm_setup
    e = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=16,
               page_size=4, num_pages=6, prefix_cache=False,
               alloc_policy="ondemand")
    rng = np.random.default_rng(37)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (8,)).tolist(),
                    max_new_tokens=8) for i in range(2)]
    e.run(reqs)
    first, pre = _by_rid(e), e.preemptions
    e.reset()
    e.run([Request(rid=r.rid, prompt=list(r.prompt),
                   max_new_tokens=r.max_new_tokens) for r in reqs])
    assert _by_rid(e) == first
    assert e.preemptions == pre
