"""The versioned BenchRecord contract and the perf-regression gate.

These tests drive ``emit_bench``/``read_bench`` and ``check_regression``
against a tmp root (the ``root=`` parameter exists for exactly this), so
the repo's committed BENCH_*.json trajectories are never touched.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import (SCHEMA_VERSION, BenchRecord, csv_row,
                               emit_bench, kernel_roofline, read_bench,
                               record)
from benchmarks.check_regression import check


# ---------------------------------------------------------------------------
# record contract


def test_record_csv_line_and_json():
    r = record("paged_tok_s", 1010.25, unit="tok_s", derived="smoke")
    assert str(r) == "paged_tok_s,1010.2,tok_s,smoke"
    assert r.to_json() == {"name": "paged_tok_s", "value": 1010.25,
                           "unit": "tok_s", "derived": "smoke"}


def test_csv_row_is_deprecated_record_alias():
    r = csv_row("qmatmul_256", 12.5, "vs ref 1.0x")
    assert isinstance(r, BenchRecord)
    assert r.unit == "us_per_call" and r.derived == "vs ref 1.0x"


def test_kernel_roofline_attachment():
    rf = kernel_roofline(flops=2.0e12, hbm_bytes=1.0e9)
    assert rf["bound"] in ("memory", "compute")
    assert rf["arithmetic_intensity"] == pytest.approx(2000.0)
    assert rf["ideal_us"] == pytest.approx(
        max(rf["t_compute_s"], rf["t_memory_s"]) * 1e6)


# ---------------------------------------------------------------------------
# trajectory persistence


def test_emit_appends_per_sha_and_merges_same_sha(tmp_path):
    root = str(tmp_path)
    emit_bench("serving", [record("a", 1.0)], root=root, sha="s1")
    emit_bench("serving", [record("a", 2.0), record("b", 5.0)],
               root=root, sha="s2")
    # same sha again: merge by name, not a third entry
    emit_bench("serving", [record("b", 6.0), record("c", 7.0)],
               root=root, sha="s2")
    doc = read_bench("serving", root=root)
    assert doc["schema_version"] == SCHEMA_VERSION
    assert [e["sha"] for e in doc["trajectory"]] == ["s1", "s2"]
    s2 = {r["name"]: r["value"] for r in doc["trajectory"][-1]["records"]}
    assert s2 == {"a": 2.0, "b": 6.0, "c": 7.0}
    # latest = union across entries, last wins per name
    assert doc["latest"] == {"a": 2.0, "b": 6.0, "c": 7.0}
    assert doc["trajectory"][-1]["backend"] in ("pallas", "reference")


def test_latest_unions_across_entries(tmp_path):
    root = str(tmp_path)
    emit_bench("serving", [record("only_old", 3.0)], root=root, sha="s1")
    emit_bench("serving", [record("fresh", 4.0)], root=root, sha="s2")
    doc = read_bench("serving", root=root)
    assert doc["latest"] == {"only_old": 3.0, "fresh": 4.0}


def test_legacy_flat_snapshot_migrates(tmp_path):
    root = str(tmp_path)
    with open(os.path.join(root, "BENCH_serving.json"), "w") as f:
        json.dump({"dense_tok_s": 900.0, "note": "not-a-number"}, f)
    doc = read_bench("serving", root=root)
    assert [e["sha"] for e in doc["trajectory"]] == ["legacy"]
    # appending after migration keeps the legacy entry as history
    emit_bench("serving", [record("dense_tok_s", 950.0, unit="tok_s")],
               root=root, sha="s1")
    doc = read_bench("serving", root=root)
    assert [e["sha"] for e in doc["trajectory"]] == ["legacy", "s1"]
    assert doc["latest"]["dense_tok_s"] == 950.0


# ---------------------------------------------------------------------------
# regression gate


def _seed_green(root, sha):
    emit_bench("serving", [
        record("paged_vs_dense_tok_ratio", 1.10, unit="ratio"),
        record("dense_tok_s", 900.0, unit="tok_s"),
        record("paged_tok_s", 990.0, unit="tok_s"),
    ], root=root, sha=sha)
    emit_bench("train_step", [
        record("fwd_weight_bytes_ratio", 0.20, unit="ratio"),
        record("speedup", 1.5, unit="ratio"),
    ], root=root, sha=sha)


def test_gate_bootstrap_and_green(tmp_path):
    root = str(tmp_path)
    assert check(root) == 2  # no trajectories at all
    _seed_green(root, "s1")
    assert check(root) == 0  # first entry: trend check bootstraps
    _seed_green(root, "s2")
    assert check(root) == 0  # identical numbers: green


def test_gate_invariant_failure_not_marker_waivable(tmp_path):
    root = str(tmp_path)
    _seed_green(root, "s1")
    emit_bench("serving", [
        record("paged_vs_dense_tok_ratio", 0.91, unit="ratio"),
    ], root=root, sha="s2")
    assert check(root) == 1
    # --waive is the only override for invariants
    assert check(root, waive=True) == 0


def test_gate_trend_regression_and_waive(tmp_path):
    root = str(tmp_path)
    _seed_green(root, "s1")
    emit_bench("serving", [
        record("paged_vs_dense_tok_ratio", 1.05, unit="ratio"),
        record("dense_tok_s", 900.0, unit="tok_s"),
        record("paged_tok_s", 300.0, unit="tok_s"),  # -70% > TOL_WALL
    ], root=root, sha="s2")
    emit_bench("train_step", [
        record("fwd_weight_bytes_ratio", 0.20, unit="ratio"),
        record("speedup", 1.5, unit="ratio"),
    ], root=root, sha="s2")
    assert check(root) == 1
    assert check(root, waive=True) == 0


def test_gate_wall_clock_jitter_tolerated(tmp_path):
    root = str(tmp_path)
    _seed_green(root, "s1")
    emit_bench("serving", [
        record("paged_vs_dense_tok_ratio", 1.02, unit="ratio"),
        record("dense_tok_s", 700.0, unit="tok_s"),   # -22%: inside TOL_WALL
        record("paged_tok_s", 730.0, unit="tok_s"),
    ], root=root, sha="s2")
    emit_bench("train_step", [
        record("fwd_weight_bytes_ratio", 0.20, unit="ratio"),
        record("speedup", 1.3, unit="ratio"),  # -13%: inside TOL_RATIO
    ], root=root, sha="s2")
    assert check(root) == 0
