"""Per-architecture smoke tests (assignment requirement): each of the ten
assigned archs instantiates a REDUCED config of the same family and runs one
forward + one LNS-Madam train step on CPU, asserting shapes and no NaNs.
Also checks decode/forward consistency and exact param-count bookkeeping.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, input_specs, SHAPES
from repro.core.quantizer import QuantConfig
from repro.models import (decode_step, forward, init_caches, init_params,
                          lm_loss)
from repro.models.stubs import encodec_tokens_stub, vision_patches_stub
from repro.optim.madam import MadamConfig
from repro.training import build_train_step, init_train_state

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, key, batch=2, seq=24):
    tshape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks \
        else (batch, seq)
    toks = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": toks}
    if cfg.num_patches:
        out["patches"] = vision_patches_stub(jax.random.fold_in(key, 9),
                                             batch, cfg)
    return out


@pytest.mark.slow  # ten archs x jit'd train step: the suite's biggest chunk
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    mcfg = MadamConfig()
    qcfg = QuantConfig.lns_madam()
    state = init_train_state(key, cfg, mcfg)
    batch = _smoke_batch(cfg, jax.random.fold_in(key, 1))
    step = jax.jit(build_train_step(cfg, qcfg, mcfg))
    new_state, metrics = step(state, jax.tree.map(jnp.asarray, batch))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # a second step must also be finite and change the weights
    st3, m2 = step(new_state, jax.tree.map(jnp.asarray, batch))
    assert np.isfinite(float(m2["loss"]))
    codes0 = jax.tree.leaves(state.params)[1]
    codes2 = jax.tree.leaves(st3.params)[1]
    assert codes0.shape == codes2.shape


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_matches_forward(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(key, cfg)
    batch = _smoke_batch(cfg, jax.random.fold_in(key, 1), batch=2, seq=12)
    out = forward(params, batch["tokens"], cfg, None, remat=False,
                  patches=batch.get("patches"))
    assert not bool(jnp.any(jnp.isnan(out.logits)))
    if cfg.num_patches:
        return  # decode-with-patch-prefix exercised via prefill only
    caches = init_caches(2, 32, cfg)
    lg, _ = decode_step(params, caches, batch["tokens"], cfg, None,
                        pos_offset=0)
    diff = float(jnp.max(jnp.abs(out.logits[:, -1] - lg)))
    assert diff < 5e-2, diff  # bf16/f32 path differences only


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_bookkeeping_exact(arch, key):
    """params_count() (used for MODEL_FLOPS) matches the real tree."""
    cfg = get_smoke_config(arch)
    n = sum(x.size for x in jax.tree.leaves(init_params(key, cfg)))
    assert n == cfg.params_count()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                           vocab_size=65536),
        "gemma3-12b": dict(num_layers=48, d_model=3840, num_heads=16,
                           num_kv_heads=8, d_ff=15360, vocab_size=262144),
        "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064),
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "smollm-135m": dict(num_layers=30, d_model=576, num_heads=9,
                            num_kv_heads=3, d_ff=1536, vocab_size=49152),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, moe_d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                experts_per_token=8),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 moe_d_ff=2048, vocab_size=129280,
                                 num_experts=256, experts_per_token=8),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          num_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state_dim=64),
        "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, num_heads=32,
                                  num_kv_heads=32, d_ff=8192,
                                  vocab_size=32064),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048,
                                num_codebooks=4),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_full_param_counts_near_published():
    """Total parameter counts land on the published model sizes."""
    expect_b = {
        "rwkv6-1.6b": (1.4, 1.8), "gemma3-12b": (11.0, 13.5),
        "qwen2.5-32b": (31, 34), "granite-8b": (7.5, 8.5),
        "smollm-135m": (0.125, 0.145), "kimi-k2-1t-a32b": (980, 1080),
        "deepseek-v3-671b": (650, 690), "zamba2-7b": (5.0, 8.0),
        "phi-3-vision-4.2b": (3.5, 4.3), "musicgen-medium": (1.2, 1.6),
    }
    for arch, (lo, hi) in expect_b.items():
        n = get_config(arch).params_count() / 1e9
        assert lo <= n <= hi, (arch, n)


def test_input_specs_cover_cells():
    from repro.configs import cells
    cs = cells()
    assert len(cs) == 33  # 10 archs x 3 shapes + 3 sub-quadratic long_500k
    for arch, shape in cs:
        specs = input_specs(get_config(arch), shape)
        assert "tokens" in specs
        for v in specs.values():
            assert all(d > 0 for d in v.shape)


def test_long_500k_skips_documented():
    from repro.configs import cells, get_config, runs_shape
    skipped = [a for a in ARCHS
               if not runs_shape(get_config(a), "long_500k")]
    assert sorted(skipped) == sorted([
        "qwen2.5-32b", "granite-8b", "smollm-135m", "kimi-k2-1t-a32b",
        "deepseek-v3-671b", "phi-3-vision-4.2b", "musicgen-medium"])
