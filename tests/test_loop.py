"""Fault-tolerant supervisor: recovery, stragglers, NaN handling."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.training.data import SyntheticLM
from repro.training.loop import SupervisorConfig, TrainReport, run_supervised
from repro.models.common import ArchConfig

CFG = ArchConfig(name="t", family="dense", num_layers=1, d_model=8,
                 num_heads=1, num_kv_heads=1, head_dim=8, d_ff=16,
                 vocab_size=32, dtype="float32")


def _toy_step(fail_on=(), nan_on=(), slow_on=(), sleep=0.12):
    """A fake step_fn: state is a scalar counter, loss decreases with it."""
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        s = int(state["count"])
        if s in slow_on:
            time.sleep(sleep)
        if s in nan_on:
            nan_on.discard(s)
            return state, {"loss": float("nan")}
        return ({"count": state["count"] + 1},
                {"loss": 10.0 / (1 + s)})

    return step, calls


def _data():
    return SyntheticLM(CFG, batch=2, seq=8, seed=0)


def test_recovers_from_injected_failure(tmp_path):
    step, _ = _toy_step()
    fails = {5}

    def inject(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("device loss")

    ckpt = CheckpointManager(str(tmp_path))
    rep = run_supervised(step, {"count": jnp.asarray(0)}, _data(), ckpt,
                         SupervisorConfig(max_steps=10, save_every=2),
                         failure_injector=inject)
    assert rep.failures_recovered == 1
    assert rep.losses[-1] == pytest.approx(1.0)  # reached count 9


def test_recovers_from_nan_loss(tmp_path):
    step, _ = _toy_step(nan_on={4})
    ckpt = CheckpointManager(str(tmp_path))
    rep = run_supervised(step, {"count": jnp.asarray(0)}, _data(), ckpt,
                         SupervisorConfig(max_steps=8, save_every=2))
    assert rep.failures_recovered == 1
    assert all(l == l for l in rep.losses)  # no NaN recorded


def test_gives_up_after_max_retries_without_rebuild(tmp_path):
    def always_fail(state, batch):
        raise RuntimeError("persistent fault")

    ckpt = CheckpointManager(str(tmp_path))
    with pytest.raises(RuntimeError):
        run_supervised(always_fail, {"count": jnp.asarray(0)}, _data(), ckpt,
                       SupervisorConfig(max_steps=5, max_retries=2))


def test_rebuild_hook_called_on_persistent_failure(tmp_path):
    attempts = {"n": 0}

    def flaky(state, batch):
        if attempts["n"] < 8 and not state.get("rebuilt"):
            attempts["n"] += 1
            raise RuntimeError("fault")
        return ({"count": state["count"] + 1, "rebuilt": state["rebuilt"]},
                {"loss": 1.0})

    def rebuild(state):
        return {"count": state["count"], "rebuilt": True}

    ckpt = CheckpointManager(str(tmp_path))
    rep = run_supervised(flaky, {"count": jnp.asarray(0), "rebuilt": False},
                         _data(), ckpt,
                         SupervisorConfig(max_steps=4, max_retries=2),
                         on_rebuild=rebuild)
    assert rep.rebuilds == 1
    assert rep.steps_done == 4


def test_straggler_skip_policy(tmp_path):
    step, calls = _toy_step(slow_on={6}, sleep=0.3)
    ckpt = CheckpointManager(str(tmp_path))
    rep = run_supervised(step, {"count": jnp.asarray(0)}, _data(), ckpt,
                         SupervisorConfig(max_steps=10, save_every=100,
                                          straggler_factor=5.0,
                                          straggler_policy="skip"))
    assert rep.straggler_events >= 1
    assert rep.skipped_batches >= 1
    assert rep.steps_done == 10


def test_data_cursor_resumes_with_checkpoint(tmp_path):
    """After a failure the stream rewinds to the checkpointed cursor."""
    seen = []

    def step(state, batch):
        seen.append(int(batch["tokens"][0, 0]))
        return {"count": state["count"] + 1}, {"loss": 1.0}

    fails = {5}  # off the save_every=2 boundary so a replay must happen

    def inject(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("fault")

    ckpt = CheckpointManager(str(tmp_path))
    data = _data()
    run_supervised(step, {"count": jnp.asarray(0)}, data, ckpt,
                   SupervisorConfig(max_steps=8, save_every=2),
                   failure_injector=inject)
    # the batch consumed at the failed step is replayed after restore
    assert len(seen) > len(set(seen))


def test_synthetic_data_deterministic():
    d1 = SyntheticLM(CFG, batch=2, seq=8, seed=3)
    d2 = SyntheticLM(CFG, batch=2, seq=8, seed=3)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert not (d1.batch_at(6)["tokens"] == b1["tokens"]).all()
