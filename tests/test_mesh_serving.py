"""Mesh-native serving: the (data=2, model=2) host mesh must be
token-for-token equal to the single-device engine.

These tests need >= 4 host devices; the CI ``mesh-smoke`` leg provides
them with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set
before jax imports — pytest collection of this file skips cleanly on a
single device).

The equality matrix covers the acceptance criteria: smollm smoke over
dense and paged-ondemand KV, greedy and seeded sampling in one trace,
speculation on and off. The MoE smoke (deepseek: 8 experts sharded 2-way,
MLA dense cache) asserts admit + completion, and the paged tests assert
page-pool refcounts return to baseline after abort/rollback.
"""
import copy

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.lns import LNSFormat
from repro.core.quantizer import QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.optim.madam import MadamConfig
from repro.server.sampling import SamplingParams
from repro.serving import Engine
from repro.serving.request import Request
from repro.training import init_train_state

requires_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices: XLA_FLAGS=--xla_force_host_platform_device_count=4")

MAX_LEN = 32


def _setup(arch: str, seed: int = 0):
    cfg = get_smoke_config(arch)
    qcfg = QuantConfig.lns_madam()
    mcfg = MadamConfig(update_format=LNSFormat(bits=8, gamma=8))
    state = init_train_state(jax.random.PRNGKey(seed), cfg, mcfg)
    return cfg, qcfg, mcfg, state.params


def _trace(cfg, n: int = 6, seed: int = 0):
    """Mixed-length trace, greedy and seeded-sampling rows interleaved."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 10))
        prompt = rng.integers(1, cfg.vocab_size, size=(plen,)).tolist()
        samp = SamplingParams(temperature=0.7, top_k=40,
                              seed=1000 + i) if i % 2 else None
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 10)),
                            sampling=samp))
    return reqs


def _tokens(engine):
    return {rs.request.rid: list(rs.generated)
            for rs in engine.finished + engine.aborted}


def _engines(arch, *, mesh_shape=(2, 2), **kw):
    cfg, qcfg, mcfg, params = _setup(arch)
    base = Engine(cfg, qcfg, mcfg, params, max_len=MAX_LEN, **kw)
    mesh = make_host_mesh(data=mesh_shape[0], model=mesh_shape[1])
    sharded = Engine(cfg, qcfg, mcfg, params, max_len=MAX_LEN, mesh=mesh,
                     **kw)
    return cfg, base, sharded


@requires_mesh
@pytest.mark.parametrize("layout", ["dense", "paged_ondemand"])
@pytest.mark.parametrize("spec_k", [0, 2])
def test_mesh_matches_single_device_tokens(layout, spec_k):
    kw = dict(num_slots=3, speculate_k=spec_k)
    if layout == "paged_ondemand":
        kw.update(page_size=8, alloc_policy="ondemand", num_pages=10)
    cfg, base, sharded = _engines("smollm-135m", **kw)
    # the smollm smoke (3 heads / 1 kv head) cannot head-shard model=2:
    # its equality run exercises the column-parallel mlp + all-gather
    # epilogue and the fully-replicated attention path
    base.run(_trace(cfg))
    sharded.run(_trace(cfg))
    got, want = _tokens(sharded), _tokens(base)
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (
            f"{layout} spec_k={spec_k} rid={rid}: mesh stream diverged")
    if layout == "paged_ondemand":
        # refcounts back to baseline: every page free or cached (ref == 0)
        assert sharded.allocator.available == sharded.num_pages


@requires_mesh
def test_mesh_weights_actually_sharded():
    """Guard against a vacuous pass: the (2,2) mesh engine must hold its
    mlp weights column-parallel over the model axis (d_ff divides)."""
    _, _, sharded = _engines("smollm-135m", num_slots=2)
    up = sharded.params["period"]["pos0"]["mlp"]["up"]
    assert "model" in tuple(up.packed.sharding.spec)
    # the paired second GEMM keeps its contraction dim replicated
    down = sharded.params["period"]["pos0"]["mlp"]["down"]
    assert down.packed.sharding.spec[0] is None


@requires_mesh
def test_mesh_abort_returns_pages_to_baseline():
    cfg, base, sharded = _engines("smollm-135m", num_slots=3, page_size=8,
                                  alloc_policy="ondemand", num_pages=10)
    del base
    reqs = _trace(cfg, n=4)
    for r in reqs:
        sharded.submit(copy.copy(r))
    # admit + decode a little, then cancel one running and one queued rid
    for _ in range(3):
        sharded.step()
    running = [rs.request.rid for rs in sharded.scheduler.running.values()]
    assert running, "nothing admitted — test harness is broken"
    sharded.abort(running[0])
    sharded.run(())
    assert sharded.allocator.available == sharded.num_pages


@requires_mesh
def test_mesh_head_sharded_engine_matches_single_device():
    """gemma3 smoke (4 heads / 2 kv heads) head-shards over model=2: the
    paged global layers drive the shard_map paged-attend path, the local
    ring layers the head-sharded dense cache — streams must still match."""
    cfg, base, sharded = _engines("gemma3-12b", num_slots=2, page_size=8,
                                  num_pages=12)
    base.run(_trace(cfg, n=4))
    sharded.run(_trace(cfg, n=4))
    assert _tokens(sharded) == _tokens(base)


@requires_mesh
@pytest.mark.parametrize("quantized", [False, True])
def test_paged_attend_shard_map_bitwise(quantized):
    """dispatch.paged_attend under a mesh whose model axis divides the KV
    heads: the per-shard head-group path + all-gather epilogue must be
    *bitwise* the no-mesh result (each shard computes exactly the heads a
    single device would, collectives only concatenate)."""
    from repro.distributed.sharding import serving_rules, shard_ctx
    from repro.kernels import dispatch

    B, S, h, kv, hd = 2, 1, 8, 4, 16
    pages, page = 6, 8
    rng = np.random.default_rng(0)
    q = jax.numpy.asarray(rng.standard_normal((B, S, h, hd)), jax.numpy.float32)
    if quantized:
        kp = jax.numpy.asarray(
            rng.integers(0, 255, (pages + 1, page, kv, hd)), jax.numpy.uint8)
        vp = jax.numpy.asarray(
            rng.integers(0, 255, (pages + 1, page, kv, hd)), jax.numpy.uint8)
        ks = jax.numpy.ones((pages + 1, page, kv, 1), jax.numpy.bfloat16)
        vs = jax.numpy.ones((pages + 1, page, kv, 1), jax.numpy.bfloat16)
        fmt = LNSFormat(bits=8, gamma=8)
    else:
        kp = jax.numpy.asarray(
            rng.standard_normal((pages + 1, page, kv, hd)), jax.numpy.float32)
        vp = jax.numpy.asarray(
            rng.standard_normal((pages + 1, page, kv, hd)), jax.numpy.float32)
        ks = vs = None
        fmt = None
    bt = jax.numpy.asarray([[0, 2, pages], [1, 3, pages]], jax.numpy.int32)
    lengths = jax.numpy.asarray([9, 13], jax.numpy.int32)

    kw = dict(fmt=fmt, softcap=None, sm_scale=hd ** -0.5)
    want = dispatch.paged_attend(q, kp, vp, ks, vs, bt, lengths, **kw)

    mesh = make_host_mesh(data=2, model=2)

    class _KV:  # serving_rules duck-typed cfg
        num_heads, num_kv_heads, d_ff, num_experts = h, kv, 0, 0

    with shard_ctx(mesh, serving_rules(_KV, mesh)):
        got = jax.jit(lambda *a: dispatch.paged_attend(*a, **kw))(
            q, kp, vp, ks, vs, bt, lengths) if quantized else \
            jax.jit(lambda q, kp, vp, bt, ln: dispatch.paged_attend(
                q, kp, vp, None, None, bt, ln, **kw))(q, kp, vp, bt, lengths)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@requires_mesh
def test_mesh_sharded_moe_admits_and_completes():
    """deepseek smoke: 8 experts shard 2-way (expert-parallel psum is
    allowed here — MoE equality is not part of the contract), MLA keeps
    the dense cache. The mesh engine must admit and finish every request."""
    cfg, qcfg, mcfg, params = _setup("deepseek-v3-671b")
    mesh = make_host_mesh(data=2, model=2)
    eng = Engine(cfg, qcfg, mcfg, params, num_slots=2, max_len=MAX_LEN,
                 mesh=mesh)
    wup = eng.params["period"]["pos0"]["moe"]["w_up"]
    # ("stack", "experts", "embed", "moe_ff") -> experts carry the model axis
    assert wup.packed.sharding.spec[1] == "model"  # expert-parallel
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(3)]
    eng.run(reqs)
    assert len(eng.finished) == 3
    for rs in eng.finished:
        assert len(rs.generated) == 4
