"""Flash attention vs naive softmax oracle; decode caches; MLA absorption."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention


def naive_attention(q, k, v, *, window=None, softcap=None, scale=None,
                    q_offset=0):
    B, Sq, H, D = q.shape
    scale = scale or 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (16, 50.0)])
@pytest.mark.parametrize("block_k", [32, 128])
def test_flash_matches_naive(key, window, softcap, block_k):
    B, S, H, D = 2, 256, 4, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          block_k=block_k)
    ref = naive_attention(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_mla_value_dim(key):
    """v head width != qk head width (MLA)."""
    B, S, H, D, Dv = 2, 128, 2, 24, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, Dv))
    out = flash_attention(q, k, v)
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_grad_finite(key):
    B, S, H, D = 1, 64, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    g = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v)))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_sliding_window_cache_ring_buffer(key):
    """Window-layer decode with a ring buffer == full-history attention
    restricted to the window."""
    from repro.models import ArchConfig
    from repro.models.attention import attn_init, attn_apply, init_kv_cache

    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, sliding_window=8, dtype="float32")
    p = attn_init(key, cfg)
    S = 24
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 32))
    positions = jnp.arange(S)
    full, _ = attn_apply(p, x, cfg, None, positions=positions,
                         window=cfg.sliding_window)
    # decode one token at a time through the ring cache
    cache = init_kv_cache(1, S, cfg, window=cfg.sliding_window)
    assert cache["k"].shape[1] == 8  # ring capacity = window
    outs = []
    for t in range(S):
        o, cache = attn_apply(p, x[:, t:t + 1], cfg, None,
                              positions=positions[t:t + 1],
                              window=cfg.sliding_window, cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_matches_train(key):
    from repro.models import ArchConfig
    from repro.models.attention import init_mla_cache, mla_apply, mla_init

    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=48,
                     num_heads=3, num_kv_heads=3, head_dim=16, d_ff=64,
                     vocab_size=64, use_mla=True, q_lora_rank=24,
                     kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                     v_head_dim=16, dtype="float32")
    p = mla_init(key, cfg)
    S = 12
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 48))
    positions = jnp.arange(S)
    full, _ = mla_apply(p, x, cfg, None, positions=positions)
    cache = init_mla_cache(2, S, cfg)
    outs = []
    for t in range(S):
        o, cache = mla_apply(p, x[:, t:t + 1], cfg, None,
                             positions=positions[t:t + 1], cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
